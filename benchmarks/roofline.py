"""Roofline derivation from the dry-run artifacts (§Roofline).

Three terms per (arch x shape x mesh), all in seconds per executed step:

  compute    = FLOPs_per_device / 197e12          (bf16 peak, v5e)
  memory     = bytes_per_device / 819e9           (HBM bw)
  collective = wire_bytes_per_device / 50e9       (ICI per-link bw)

CPU-backend caveat (documented in EXPERIMENTS.md): ``cost_analysis`` counts
while-loop bodies ONCE, and our stacks scan over layers — so HLO FLOPs/bytes
undercount by ~n_layers. We therefore report the ANALYTIC FLOPs/bytes model
(formulas below, from the known pass structure of an AdaFBiO step) as the
roofline inputs, plus the raw HLO numbers for reference. Collective bytes are
parsed from the partitioned HLO; collectives inside while bodies are scaled
by the layer count (the dominant trip count).
"""
from __future__ import annotations

import json
import re
from pathlib import Path
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link (ICI)
# per-program host dispatch + launch latency: paid once per compiled round
# program, so the fused scan engine amortizes it over q local steps and the
# mega-scan tier over q*R (docs/megascan.md)
LAUNCH_S = 50e-6

DEVICES = {"16x16": 256, "2x16x16": 512}


def _shape_params(shape_id):
    from repro.configs import INPUT_SHAPES
    return INPUT_SHAPES[shape_id]


def analytic_terms(rec: Dict) -> Dict:
    """Per-device analytic FLOPs & HBM bytes for the executed step."""
    from repro.configs import FedConfig, get_arch
    cfg = get_arch(rec["arch"])
    shape = _shape_params(rec["shape"])
    fed = FedConfig()
    n_dev = DEVICES[rec["mesh"]] if rec["mesh"] in DEVICES else 256
    n_act = cfg.active_param_count()
    n_tot = cfg.param_count()
    out = {}

    if shape.kind == "train":
        m = rec.get("n_clients", 1)
        s = shape.seq_len
        sn = max(s // 4, 64)
        t_ll = max(shape.global_batch // m, 1) * s          # ζ tokens / client
        t_ul = max(int((shape.global_batch // m) * fed.ul_batch_frac), 1) * s
        t_n = fed.neumann_k * fed.neumann_batch * sn
        t_h = fed.neumann_batch * sn
        # v-refresh: 2 forwards over the LL batch (2ND per fwd token)
        fl = 4 * n_act * t_ll
        # w-refresh: 2 evals x [joint (gx,gy) fwd+bwd (6ND) + mixed second-
        # order (~8ND over the single zeta_0 sample) + K Neumann feature fwd]
        fl += 2 * (6 * n_act * t_ul + 8 * n_act * t_h + 2 * n_act * t_n)
        out["flops_per_device"] = fl * m / n_dev
        # bytes: each pass streams the client's param shard; activation HBM
        # traffic ~ flops / d_model (each layer reads+writes [tokens, d]
        # around ~6*d*params worth of MACs -> intensity ~d).
        passes = 2 + 2 * 3.5
        state_bytes = 2 * n_tot * 2            # params + STORM w, bf16
        per_dev_state = state_bytes * m / n_dev
        out["bytes_per_device"] = (passes * per_dev_state
                                   + out["flops_per_device"] / cfg.d_model)
        out["sync_allreduce_bytes"] = 2 * per_dev_state  # x,y,v,w up+down
    elif shape.kind == "prefill":
        s = shape.seq_len if cfg.family != "encdec" else shape.seq_len // 4
        toks = shape.global_batch * s
        fl = 2 * n_act * toks
        if cfg.n_heads:
            hd = cfg.resolved_head_dim
            win = rec["steps"]["prefill"].get("window") or s
            eff = min(win, s)
            fl += 4 * cfg.n_layers * shape.global_batch * s * eff * \
                cfg.n_heads * hd
        out["flops_per_device"] = fl / n_dev
        out["bytes_per_device"] = (n_tot * 2 / min(n_dev, 16)
                                   + 2 * toks * cfg.d_model * 2
                                   * cfg.n_layers / n_dev)
    else:  # decode: one token vs cache
        toks = shape.global_batch
        fl = 2 * n_act * toks
        cache_b = _cache_bytes(cfg, shape, rec)
        out["flops_per_device"] = fl / n_dev
        # weights + the whole cache are streamed once per token step
        out["bytes_per_device"] = (n_tot * 2 + cache_b) / n_dev
    return out


def _cache_bytes(cfg, shape, rec):
    win = rec["steps"][shape.kind].get("window")
    s = min(win or shape.seq_len, shape.seq_len)
    b = shape.global_batch
    total = 0
    if cfg.n_heads:
        n_attn = (cfg.n_layers if cfg.family != "hybrid"
                  else cfg.n_layers // cfg.shared_attn_every)
        total += 2 * n_attn * b * s * cfg.n_kv_heads * cfg.resolved_head_dim * 2
    if cfg.family == "encdec":
        total += 2 * cfg.n_layers * b * shape.seq_len * cfg.n_kv_heads * \
            cfg.resolved_head_dim * 2
    if cfg.ssm is not None:
        di = cfg.ssm.expand * cfg.d_model
        total += cfg.n_layers * b * di * cfg.ssm.state_dim * 4
    return total


def roofline_row(rec: Dict, rounds_per_scan: int = 1) -> Dict:
    from repro.configs import get_arch
    cfg = get_arch(rec["arch"])
    step_key = ("local" if "local" in rec["steps"] else
                list(rec["steps"].keys())[0])
    step = rec["steps"][step_key]
    ana = analytic_terms(rec)
    # collectives: ops inside while(scan-over-layers) bodies appear once in
    # the HLO text; scale them by the layer count (dominant trip count).
    coll = step.get("collectives", {})
    wire = sum(v.get("wire_bytes", 0) for v in coll.values()
               if isinstance(v, dict))
    wire_loop = coll.get("_in_loops_wire_bytes")
    if wire_loop is not None:
        wire = (wire - wire_loop) + wire_loop * cfg.n_layers
    t_compute = ana["flops_per_device"] / PEAK_FLOPS
    t_memory = ana["bytes_per_device"] / HBM_BW
    t_coll = wire / LINK_BW
    # sync collectives amortized over q (the paper's communication saving)
    if step_key == "local" and "sync" in rec["steps"]:
        from repro.configs import FedConfig
        q = FedConfig().q
        R = max(int(rounds_per_scan), 1)
        sync_coll = rec["steps"]["sync"].get("collectives", {})
        sync_wire = sum(v.get("wire_bytes", 0) for v in sync_coll.values()
                        if isinstance(v, dict))
        t_coll += (sync_wire + ana.get("sync_allreduce_bytes", 0)) / LINK_BW / q
        # fused-round term: the scan engine launches ONE program per round
        # (q steps) and the mega-scan tier one per R rounds, so the host
        # dispatch latency amortizes over q*R executed steps
        t_coll += LAUNCH_S / (q * R)
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    total = max(terms.values())
    model_flops = 6 * cfg.active_param_count() * 4096  # per-device-ish ref
    hlo_flops = step.get("cost", {}).get("flops", float("nan"))
    mem = step.get("memory", {})
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "hlo_flops_raw": hlo_flops,
        "flops_analytic": ana["flops_per_device"],
        "bytes_analytic": ana["bytes_per_device"],
        "arg_gib": mem.get("argument_bytes", 0) / 2 ** 30,
        "temp_gib": mem.get("temp_bytes", 0) / 2 ** 30,
        "temp_tpu_adj_gib": mem.get("temp_bytes_tpu_adj",
                                    mem.get("temp_bytes", 0)) / 2 ** 30,
        # fit uses the TPU-adjusted temp (CPU f32-upcast copies removed)
        "fits_16g": (mem.get("argument_bytes", 0)
                     + mem.get("temp_bytes_tpu_adj", mem.get("temp_bytes", 0))
                     ) / 2 ** 30 <= 16.0,
    }


def synth_records(mesh="single", n_clients=8):
    """Analytic records for every (arch x shape) straight off the real
    ``repro.configs`` surface — no dry-run artifacts needed. Train shapes
    get the local+sync step pair (so the q / q*R amortization terms apply);
    prefill/decode get their single step. HLO-derived fields (collectives,
    cost, memory) are absent, so those roofline inputs read as zero and the
    row is purely the analytic model."""
    from repro.configs import INPUT_SHAPES, get_shape, list_arch_ids
    recs = []
    for arch in list_arch_ids():
        for shape_id in INPUT_SHAPES:
            kind = get_shape(shape_id).kind
            steps = ({"local": {}, "sync": {}} if kind == "train"
                     else {kind: {}})
            recs.append({"arch": arch, "shape": shape_id, "mesh": mesh,
                         "n_clients": n_clients, "ok": True, "steps": steps})
    return recs


def load_rows(dryrun_dir="results/dryrun", mesh="single",
              rounds_per_scan=1):
    """Roofline rows from the dry-run artifacts when they exist, else from
    the analytic model over the full configs matrix (the artifacts only
    add measured HLO collective/memory numbers on top)."""
    recs = [json.loads(f.read_text())
            for f in sorted(Path(dryrun_dir).glob(f"*__{mesh}.json"))]
    if not recs:
        recs = synth_records(mesh=mesh)
    return [roofline_row(rec, rounds_per_scan=rounds_per_scan)
            for rec in recs if rec.get("ok")]


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--rounds-per-scan", type=int, default=1,
                    help="amortize the per-program dispatch latency over "
                         "q*R steps (the mega-scan tier, docs/megascan.md)")
    args = ap.parse_args()
    rows = load_rows(args.dryrun_dir, args.mesh,
                     rounds_per_scan=args.rounds_per_scan)
    hdr = ("arch", "shape", "dominant", "t_compute_s", "t_memory_s",
           "t_collective_s", "arg_gib", "temp_gib", "fits_16g")
    print(",".join(hdr))
    for r in rows:
        print(",".join(
            f"{r[h]:.4g}" if isinstance(r[h], float) else str(r[h])
            for h in hdr))


if __name__ == "__main__":
    main()
