"""Benchmark harness — one function per paper table/figure, printing
``name,us_per_call,derived`` CSV rows.

  table1_complexity   — samples & comm rounds to reach eps-stationarity on the
                        analytic quadratic bilevel problem, per algorithm
                        (verifies the ORDERING of paper Table 1).
  fig1_hyperrep       — federated hyper-representation learning: val loss vs
                        algorithm at fixed sample budget (paper Section 6.1).
  fig2_hyperclean     — federated data hyper-cleaning: exact E||∇F(x̄)|| + val
                        loss per algorithm (paper Section 6.2).
  ablation_adaptive   — AdaFBiO vs non-adaptive (Theorem 2) vs AdaBelief
                        matrices (Eq. 8-9): adaptive-matrix choice matters.
  topology_wallclock  — star vs gossip sync layers: steady per-round
                        wall-clock, spectral gap, and per-edge wire bytes
                        per mixing topology (docs/topology.md).
  kernel_micro        — wall-time of the jnp reference ops on this CPU
                        (Pallas kernels are TPU-target; us_per_call here is
                        the oracle path).
  roofline_summary    — dominant roofline term per (arch x shape) from the
                        dry-run artifacts (if present).
"""
from __future__ import annotations

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

ENGINE = "eager"        # set by --engine; drivers below inherit it
SEED = 0                # set by --seed; every driver run key derives from it
TELE = None             # set by --metrics-out; mirrors rows as bench_row


def _row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}", flush=True)
    if TELE is not None:
        TELE.emit({"kind": "bench_row", "name": name,
                   "us_per_call": round(float(us), 1), "derived": derived})


def _key():
    return jax.random.PRNGKey(SEED)


# ---------------------------------------------------------------- table 1

def table1_complexity(eps=0.35, max_steps=400):
    from tests.test_system import _quad_driver  # reuse the calibrated setup
    for alg in ("adafbio", "adafbio_na", "fedbioacc", "localbsgvrm",
                "fednest", "fedavg_sgd"):
        d = _quad_driver(alg)
        d.engine = ENGINE
        t0 = time.time()
        r = d.run(max_steps, key=_key(), eval_every=10)
        us = (time.time() - t0) / max(r.steps[-1], 1) * 1e6
        hit = next(((s, smp, c) for s, smp, c, g in
                    zip(r.steps, r.samples, r.comms, r.grad_norm)
                    if g < eps), None)
        if hit:
            _row(f"table1/{alg}", us,
                 f"steps_to_eps={hit[0]};samples={hit[1]};comms={hit[2]}")
        else:
            _row(f"table1/{alg}", us,
                 f"not_reached;final_grad={r.grad_norm[-1]:.3f}")


# ---------------------------------------------------------------- fig 6.1

def fig1_hyperrep(steps=150):
    from repro.configs.paper_tasks import HyperRepConfig
    from repro.tasks.driver import FedDriver
    from repro.tasks.hyperrep import build_hyperrep
    cfg = HyperRepConfig(n_clients=8)
    hr = build_hyperrep(cfg)
    for alg in ("adafbio", "fedbioacc", "localbsgvrm", "fednest",
                "fedavg_sgd"):
        d = FedDriver(hr["problem"], cfg.fed, cfg.n_clients, hr["batch_fn"],
                      hr["init_xy"], metric_fn=hr["val_loss"], algorithm=alg,
                      engine=ENGINE)
        t0 = time.time()
        r = d.run(steps, key=_key(), eval_every=max(steps - 1, 1))
        us = (time.time() - t0) / steps * 1e6
        _row(f"fig_hyperrep/{alg}", us,
             f"val0={r.metric[0]:.4f};valT={r.metric[-1]:.4f};"
             f"samples={r.samples[-1]};comms={r.comms[-1]}")


# ---------------------------------------------------------------- fig 6.2

def fig2_hyperclean(steps=150):
    from repro.configs.paper_tasks import HyperCleanConfig
    from repro.tasks.driver import FedDriver
    from repro.tasks.hyperclean import build_hyperclean
    cfg = HyperCleanConfig(n_clients=8)
    hc = build_hyperclean(cfg)
    for alg in ("adafbio", "fedbioacc", "localbsgvrm", "fednest",
                "fedavg_sgd"):
        d = FedDriver(hc["problem"], cfg.fed, cfg.n_clients, hc["batch_fn"],
                      hc["init_xy"], metric_fn=hc["val_loss"],
                      grad_norm_fn=hc["true_grad_norm"], algorithm=alg,
                      engine=ENGINE)
        t0 = time.time()
        r = d.run(steps, key=_key(), eval_every=max(steps - 1, 1))
        us = (time.time() - t0) / steps * 1e6
        _row(f"fig_hyperclean/{alg}", us,
             f"gnorm0={r.grad_norm[0]:.4f};gnormT={r.grad_norm[-1]:.4f};"
             f"valT={r.metric[-1]:.4f};comms={r.comms[-1]}")


# ---------------------------------------------------------------- ablation

def ablation_adaptive(steps=150):
    import dataclasses
    from repro.configs.paper_tasks import HyperRepConfig
    from repro.tasks.driver import FedDriver
    from repro.tasks.hyperrep import build_hyperrep
    for kind in ("adam", "adabelief", "none"):
        cfg = HyperRepConfig(n_clients=8)
        cfg = dataclasses.replace(
            cfg, fed=dataclasses.replace(cfg.fed, adaptive=kind))
        hr = build_hyperrep(cfg)
        d = FedDriver(hr["problem"], cfg.fed, cfg.n_clients, hr["batch_fn"],
                      hr["init_xy"], metric_fn=hr["val_loss"],
                      algorithm="adafbio", engine=ENGINE)
        t0 = time.time()
        r = d.run(steps, key=_key(), eval_every=max(steps - 1, 1))
        us = (time.time() - t0) / steps * 1e6
        _row(f"ablation_adaptive/{kind}", us,
             f"valT={r.metric[-1]:.4f}")


# ---------------------------------------------------------------- engines

def engine_wallclock(rounds=12):
    """Eager vs fused-scan round engine: per-round wall-clock on the analytic
    quadratic problem (dispatch overhead is the whole difference — same math,
    same results; the scan engine compiles q local steps + sync as ONE
    program). Reported per engine so the win is measurable on any host."""
    from tests.test_system import _quad_driver
    q = None
    stats = {}
    for engine in ("eager", "scan"):
        d = _quad_driver("adafbio")
        d.engine = engine
        q = d.fed.q
        steps = rounds * q
        t0 = time.time()
        r = d.run(steps, key=_key(), eval_every=steps - 1)
        total = time.time() - t0
        # round_seconds already excludes the first (compile-including) round
        # — reported as RunResult.compile_seconds — but the sync variant of
        # the program still compiles in the SECOND round for both engines,
        # so drop one more for a steady-state comparison
        timed = d.round_seconds[1:] or d.round_seconds
        per_round = sum(timed) / len(timed) if timed else total / rounds
        stats[engine] = per_round
        _row(f"engine/{engine}", per_round * 1e6,
             f"q={q};rounds={rounds};total_s={total:.2f};"
             f"gnormT={r.grad_norm[-1]:.3f}")
    if stats.get("scan") and stats.get("eager"):
        _row("engine/speedup_eager_over_scan", 0.0,
             f"x{stats['eager'] / max(stats['scan'], 1e-12):.2f}")


def topology_wallclock(n=8, rounds=12):
    """Star vs gossip sync layers (repro.fed.topology) on the analytic
    quadratic: full-participation rounds, same per-node math — what varies
    is ONE aggregator step per round (exact average vs a Metropolis mixing
    step over the graph). Rows report the steady per-round wall-clock plus
    each topology's spectral gap, directed edge count, and per-edge wire
    bytes; the complete graph's row is the parity anchor (uniform mixing
    ≡ star averaging, tests/test_topology.py)."""
    from repro.configs.base import PopulationConfig
    from tests.test_system import _quad_driver

    def steady(d):
        timed = d.round_seconds[1:] or d.round_seconds
        return sum(timed) / max(len(timed), 1)

    for topo in ("star", "ring", "torus2d", "complete"):
        d = _quad_driver("adafbio", m=n)
        if topo == "star":
            d.population = PopulationConfig(n=n, cohort=n)
        else:
            d.population = PopulationConfig(n=n, cohort=n, topology=topo)
            d.engine = "gossip"
        q = d.fed.q
        steps = rounds * q
        r = d.run(steps, key=_key(), eval_every=steps - 1)
        extra = ""
        if topo != "star":
            agg = d.gossip_agg
            syncs = max(rounds - 1, 1)   # the mix opening round r closes
            extra = (f";gap={agg.gap:.4f};edges={int(agg.edges(0))}"
                     f";bytes_per_edge="
                     f"{int(r.bytes_up[-1] // (syncs * agg.edges(0)))}")
        _row(f"topology/{topo}", steady(d) * 1e6,
             f"q={q};rounds={rounds};gnormT={r.grad_norm[-1]:.3f}"
             f";bytes_up={int(r.bytes_up[-1])}{extra}")


# ---------------------------------------------------------------- population

def population_scale(n=256, c=16, rounds=8, sampler="uniform",
                     max_staleness=0.0, max_delay=1, delay_eta=0.0,
                     delay_model="uniform", tiers=None, delay_mu=0.0,
                     delay_sigma=0.5, codec="none", codec_bits=8,
                     topk_frac=0.1, ef=True, rounds_per_scan=1):
    """Cohort-sampled population vs the same-size plain run: population mode
    keeps N client states banked and computes only the C sampled clients per
    round (gather → fused scan round → scatter), so a round costs what a
    plain M=C round costs — compute and host data-building scale with the
    cohort, not the population. The legacy masked path at M=N is the
    pay-O(N)-for-C-clients baseline the subsystem replaces."""
    import dataclasses
    from repro.configs.base import PopulationConfig
    from repro.core.baselines import make_algorithm
    from tests.test_system import _quad_driver

    def driver(m):
        # recalibrate the step sizes for the bigger quadratic (the defaults
        # are tuned for d=8 and diverge at d=96)
        d = _quad_driver("adafbio", m=m, d=96, p=64)
        d.fed = dataclasses.replace(d.alg.fed, lr_x=0.05, lr_y=0.2)
        d.alg = make_algorithm("adafbio", d.fed, d.problem)
        return d

    def steady(d):
        timed = d.round_seconds[1:] or d.round_seconds
        return sum(timed) / max(len(timed), 1)

    if max_staleness == 0 and (delay_model != "uniform" or tiers):
        raise ValueError("--delay-model / --tiers are async knobs: set "
                         "--max-staleness != 0 to enable the async "
                         "population variant")

    stats = {}
    tag = f";R={rounds_per_scan}" if rounds_per_scan > 1 else ""

    dp = driver(c)
    dp.engine = "scan"
    dp.rounds_per_scan = rounds_per_scan
    q = dp.fed.q
    steps = rounds * q
    rp = dp.run(steps, key=_key(), eval_every=steps - 1)
    stats["plain"] = steady(dp)
    _row(f"population/plain_m{c}", stats["plain"] * 1e6,
         f"q={q};rounds={rounds};gnormT={rp.grad_norm[-1]:.3f}{tag}")

    dn = driver(n)
    dn.rounds_per_scan = rounds_per_scan
    dn.population = PopulationConfig(n=n, cohort=c, sampler=sampler)
    rn = dn.run(steps, key=_key(), eval_every=steps - 1)
    stats["pop"] = steady(dn)
    _row(f"population/pop_n{n}_c{c}_{sampler}", stats["pop"] * 1e6,
         f"q={q};rounds={rounds};gnormT={rn.grad_norm[-1]:.3f};"
         f"bytes_up={rn.bytes_up[-1]};bytes_down={rn.bytes_down[-1]};"
         f"compile_s={rn.compile_seconds:.2f}{tag}")

    if codec != "none":
        # compressed variant of the same cohort rounds: the wire saving
        # (exact bytes via repro.fed.compress formulas) vs the convergence
        # cost, on identical cohorts
        dc = driver(n)
        dc.rounds_per_scan = rounds_per_scan
        dc.fed = dataclasses.replace(
            dc.fed, codec=codec, codec_bits=codec_bits,
            topk_frac=topk_frac, error_feedback=ef)
        dc.alg = make_algorithm("adafbio", dc.fed, dc.problem)
        dc.population = PopulationConfig(n=n, cohort=c, sampler=sampler)
        rc = dc.run(steps, key=_key(), eval_every=steps - 1)
        level = codec_bits if codec == "int8" else topk_frac
        _row(f"population/codec_{codec}_{level}", steady(dc) * 1e6,
             f"q={q};rounds={rounds};gnormT={rc.grad_norm[-1]:.3f};"
             f"ef={int(ef)};bytes_up={rc.bytes_up[-1]};"
             f"bytes_down={rc.bytes_down[-1]};"
             f"up_ratio=x{rn.bytes_up[-1] / max(rc.bytes_up[-1], 1):.1f}")

    dm = driver(n)
    dm.engine = "scan"
    dm.participation = c / n
    rm = dm.run(steps, key=_key(), eval_every=steps - 1)
    stats["masked"] = steady(dm)
    _row(f"population/masked_m{n}", stats["masked"] * 1e6,
         f"q={q};rounds={rounds};gnormT={rm.grad_norm[-1]:.3f}")

    _row("population/pop_over_plain", 0.0,
         f"x{stats['pop'] / max(stats['plain'], 1e-12):.2f}")
    _row("population/masked_over_pop", 0.0,
         f"x{stats['masked'] / max(stats['pop'], 1e-12):.2f}")

    if max_staleness != 0:
        # asynchronous variant: overlapping cohorts with delayed arrivals
        # (per-client delays from the pluggable delay model), bounded-
        # staleness gating, delay-adaptive server steps — reports the
        # accepted-staleness histogram alongside the round cost
        from repro.fed.population import parse_tier_spec
        pop_kw = {}
        if tiers:
            if delay_model != "tiers":
                raise ValueError("--tiers only applies to --delay-model "
                                 f"tiers (got --delay-model {delay_model})")
            fr, td = parse_tier_spec(tiers)
            pop_kw = {"tier_fracs": fr, "tier_delays": td}
        da = driver(n)
        da.rounds_per_scan = rounds_per_scan
        da.population = PopulationConfig(
            n=n, cohort=c, sampler=sampler, max_staleness=max_staleness,
            max_delay=max_delay, delay_eta=delay_eta,
            delay_model=delay_model, delay_mu=delay_mu,
            delay_sigma=delay_sigma, **pop_kw)
        ra = da.run(steps, key=_key(), eval_every=steps - 1)
        hist = "|".join(f"{s}:{int(k)}" for s, k in
                        enumerate(da.staleness_hist) if k)
        dropped = sum(s["dropped"] for s in da.staleness_log)
        _row(f"population/async_n{n}_c{c}_d{max_delay}", steady(da) * 1e6,
             f"q={q};rounds={rounds};gnormT={ra.grad_norm[-1]:.3f};"
             f"delay_model={delay_model};stale_hist={hist};"
             f"dropped={dropped};max_staleness={max_staleness}")
        for ti, h in sorted(da.staleness_hist_by_tier.items()):
            _row(f"population/async_tier{ti}", 0.0,
                 "stale_hist=" + ("|".join(f"{s}:{int(k)}" for s, k in
                                           enumerate(h) if k) or "-"))


# ---------------------------------------------------------------- kernels

def kernel_micro():
    from repro.kernels import ref
    key = _key()
    b, h, kv, s, d = 2, 8, 2, 512, 64
    q = jax.random.normal(key, (b, h, s, d), jnp.bfloat16)
    k = jax.random.normal(key, (b, kv, s, d), jnp.bfloat16)
    v = jax.random.normal(key, (b, kv, s, d), jnp.bfloat16)
    fa = jax.jit(lambda *a: ref.flash_attention_ref(*a))
    fa(q, k, v).block_until_ready()
    t0 = time.time()
    for _ in range(5):
        fa(q, k, v).block_until_ready()
    _row("kernel/attention_ref_cpu", (time.time() - t0) / 5 * 1e6,
         f"B{b}xH{h}xS{s}xD{d}")
    n = 1 << 20
    gn = jax.random.normal(key, (n,), jnp.bfloat16)
    st = jax.jit(lambda a, b_, c: ref.storm_update_ref(a, b_, c, 0.3))
    st(gn, gn, gn).block_until_ready()
    t0 = time.time()
    for _ in range(20):
        st(gn, gn, gn).block_until_ready()
    _row("kernel/storm_ref_cpu", (time.time() - t0) / 20 * 1e6, f"n={n}")


# ---------------------------------------------------------------- roofline

def roofline_summary():
    try:
        from benchmarks.roofline import load_rows
        rows = load_rows()
    except Exception as e:
        _row("roofline/unavailable", 0.0, repr(e)[:60])
        return
    for r in rows:
        _row(f"roofline/{r['arch']}/{r['shape']}", 0.0,
             f"dominant={r['dominant']};tc={r['t_compute_s']:.2e};"
             f"tm={r['t_memory_s']:.2e};tx={r['t_collective_s']:.2e};"
             f"fits16g={r['fits_16g']}")


def main() -> None:
    global ENGINE, SEED, TELE
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", default="eager", choices=["eager", "scan"],
                    help="local-step engine for the driver-based benchmarks "
                         "(engine_wallclock always measures both)")
    ap.add_argument("--seed", type=int, default=0,
                    help="run PRNG seed: every driver-based benchmark "
                         "derives its run key from it")
    ap.add_argument("--population", type=int, default=256,
                    help="population size N for the population benchmark")
    ap.add_argument("--cohort", type=int, default=16,
                    help="cohort size C for the population benchmark")
    ap.add_argument("--sampler", default="uniform",
                    choices=["uniform", "roundrobin", "trace"],
                    help="cohort sampler for the population benchmark")
    ap.add_argument("--rounds", type=int, default=8,
                    help="timed rounds for the population benchmark")
    ap.add_argument("--rounds-per-scan", type=int, default=1,
                    help="population benchmark: fuse R whole rounds into "
                         "ONE compiled program per chunk (the mega-scan "
                         "tier, docs/megascan.md; benchmarks/sweep.py "
                         "--bench megascan sweeps the R grid)")
    ap.add_argument("--max-staleness", type=float, default=0.0,
                    help="population benchmark: > 0 adds an async variant "
                         "dropping arrivals staler than this many rounds "
                         "(reports the staleness histogram)")
    ap.add_argument("--max-delay", type=int, default=1,
                    help="population benchmark async variant: dispatch "
                         "return delays are uniform over [1, max-delay]")
    ap.add_argument("--delay-eta", type=float, default=0.0,
                    help="population benchmark async variant: delay-"
                         "adaptive server step coefficient")
    ap.add_argument("--delay-model", default="uniform",
                    choices=["uniform", "tiers", "lognormal"],
                    help="population benchmark async variant: per-client "
                         "delay model (trace needs a file; use "
                         "launch/train.py or benchmarks/sweep.py)")
    ap.add_argument("--tiers", default=None,
                    help="tiers delay model spec frac:lo:hi[,frac:lo:hi"
                         "...], e.g. 0.2:1:1,0.6:2:4,0.2:4:8")
    ap.add_argument("--delay-mu", type=float, default=0.0,
                    help="lognormal delay model log-latency location")
    ap.add_argument("--delay-sigma", type=float, default=0.5,
                    help="lognormal delay model log-latency scale")
    ap.add_argument("--codec", default="none",
                    choices=["none", "int8", "topk"],
                    help="population benchmark: adds a compressed variant "
                         "(client→server update codec) reporting exact "
                         "wire bytes next to the full-precision run")
    ap.add_argument("--codec-bits", type=int, default=8,
                    help="int8 codec quantization bit width (2..8)")
    ap.add_argument("--topk-frac", type=float, default=0.1,
                    help="topk codec: fraction of entries transmitted")
    ap.add_argument("--ef", default="on", choices=["on", "off"],
                    help="error feedback for the compressed variant")
    ap.add_argument("--metrics-out", default=None, metavar="FILE",
                    help="also write the rows as telemetry JSONL: one "
                         "manifest record then one bench_row per CSV row "
                         "(render/validate with scripts/report.py)")
    benches = {
        "table1": table1_complexity,
        "fig_hyperrep": fig1_hyperrep,
        "fig_hyperclean": fig2_hyperclean,
        "ablation_adaptive": ablation_adaptive,
        "engine": engine_wallclock,
        "topology": topology_wallclock,
        "population": None,     # bound to CLI args below
        "kernel": kernel_micro,
        "roofline": roofline_summary,
    }
    ap.add_argument("--only", default=None, choices=sorted(benches),
                    help="run a single benchmark by name (e.g. engine)")
    args = ap.parse_args()
    benches["population"] = lambda: population_scale(
        args.population, args.cohort, rounds=args.rounds,
        sampler=args.sampler, max_staleness=args.max_staleness,
        max_delay=args.max_delay, delay_eta=args.delay_eta,
        delay_model=args.delay_model, tiers=args.tiers,
        delay_mu=args.delay_mu, delay_sigma=args.delay_sigma,
        codec=args.codec, codec_bits=args.codec_bits,
        topk_frac=args.topk_frac, ef=args.ef == "on",
        rounds_per_scan=args.rounds_per_scan)
    ENGINE = args.engine
    SEED = args.seed
    if args.metrics_out:
        from repro.obs import make_telemetry
        TELE = make_telemetry(args.metrics_out)
        TELE.manifest(config=vars(args), seed=args.seed)
    print("name,us_per_call,derived")
    try:
        if args.only:
            benches[args.only]()
        else:
            for fn in benches.values():
                fn()
    finally:
        if TELE is not None:
            TELE.close()


if __name__ == "__main__":
    main()
