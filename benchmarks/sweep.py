"""Convergence-vs-staleness sweep harness (``BENCH_async_sweep.json``).

Runs AdaFBiO on the paper's two tasks — federated hyper-representation
learning (Section 6.1) and federated data hyper-cleaning (Section 6.2) —
over a grid of asynchronous-execution settings

    max_staleness  x  delay model  x  delay_eta

plus one synchronous baseline per task, and writes a machine-readable JSON
record per cell: final task metric and grad norm, the paper's cost counters
(#samples with the async masked-dispatch convention, #communication
rounds), the accepted-staleness histogram (split by speed tier for the
``tiers`` delay model), and wall-clock. The output is the repo's
convergence-vs-staleness trajectory artifact: CI runs one tiny cell per PR
and uploads it, and full sweeps accumulate how much staleness each task
tolerates under each device-heterogeneity regime (docs/async.md).

    PYTHONPATH=src:. python benchmarks/sweep.py --task hyperclean \
        --steps 64 --population 8 --cohort 2 --staleness-grid 2,4,inf \
        --delay-models uniform,tiers --delay-eta-grid 0,0.5
"""
from __future__ import annotations

import argparse
import json
import math
import sys
import time

sys.path.insert(0, "src")

import jax

TASKS = ("hyperclean", "hyperrep")


def build_task(name: str, n_clients: int):
    """(FedConfig, FedDriver kwargs) for one paper task at population size
    ``n_clients`` (sizes reduced from the paper's so a sweep cell costs
    seconds on CPU)."""
    if name == "hyperclean":
        from repro.configs.paper_tasks import HyperCleanConfig
        from repro.tasks.hyperclean import build_hyperclean
        cfg = HyperCleanConfig(n_clients=n_clients, n_train_per_client=64,
                               n_val_per_client=32)
        t = build_hyperclean(cfg)
        return cfg.fed, dict(problem=t["problem"], batch_fn=t["batch_fn"],
                             init_xy=t["init_xy"], metric_fn=t["val_loss"],
                             grad_norm_fn=t["true_grad_norm"])
    if name == "hyperrep":
        from repro.configs.paper_tasks import HyperRepConfig
        from repro.tasks.hyperrep import build_hyperrep
        cfg = HyperRepConfig(n_clients=n_clients)
        t = build_hyperrep(cfg)
        return cfg.fed, dict(problem=t["problem"], batch_fn=t["batch_fn"],
                             init_xy=t["init_xy"], metric_fn=t["val_loss"])
    raise KeyError(f"unknown task {name!r}; known: {TASKS}")


def json_safe(x):
    """inf -> "inf", nan -> null so the output stays spec-valid JSON
    (json.dump would emit bare Infinity/NaN tokens, which strict RFC 8259
    parsers reject)."""
    if isinstance(x, float):
        if math.isnan(x):
            return None
        if math.isinf(x):
            return "inf"
    return x


def run_cell(task: str, pcfg, steps: int, seed: int) -> dict:
    """One sweep cell: a full FedDriver run, returning the JSON record."""
    from repro.tasks.driver import FedDriver
    fed, kw = build_task(task, pcfg.n)
    d = FedDriver(kw.pop("problem"), fed, pcfg.n, kw.pop("batch_fn"),
                  kw.pop("init_xy"), algorithm="adafbio", **kw)
    d.population = pcfg
    t0 = time.time()
    r = d.run(steps, key=jax.random.PRNGKey(seed),
              eval_every=max(steps - 1, 1))
    cell = {
        "task": task,
        "delay_model": pcfg.delay_model,
        "max_staleness": json_safe(pcfg.max_staleness),
        "max_delay": pcfg.max_delay,
        "delay_eta": pcfg.delay_eta,
        "sampler": pcfg.sampler,
        "steps": int(r.steps[-1] + 1),
        "metric0": json_safe(float(r.metric[0])),
        "metricT": json_safe(float(r.metric[-1])),
        # hyperrep has no exact-gradient oracle: NaN -> null
        "grad_normT": json_safe(float(r.grad_norm[-1])),
        "samples": int(r.samples[-1]),
        "comms": int(r.comms[-1]),
        "seconds": round(time.time() - t0, 3),
    }
    if pcfg.asynchronous:
        log = d.staleness_log
        cell.update({
            "rounds": len(log),
            "arrived": sum(s["arrived"] for s in log),
            "accepted": sum(s["accepted"] for s in log),
            "dropped": sum(s["dropped"] for s in log),
            "dispatched": sum(s["dispatched"] for s in log),
            "staleness_hist": d.staleness_hist.tolist(),
        })
        if d.staleness_hist_by_tier:
            cell["staleness_hist_by_tier"] = {
                str(ti): h.tolist()
                for ti, h in sorted(d.staleness_hist_by_tier.items())}
            cell["tier_fracs"] = list(pcfg.tier_fracs)
            cell["tier_delays"] = [list(td) for td in pcfg.tier_delays]
    return cell


def parse_grid(spec: str, cast):
    return tuple(cast(v) for v in spec.split(",") if v)


def run_sweep(args) -> dict:
    """The full grid: per task, one sync baseline + every
    (max_staleness, delay_model, delay_eta) combination."""
    from repro.configs.base import DELAY_MODELS, PopulationConfig
    from repro.fed.population import parse_tier_spec
    tasks = parse_grid(args.task, str)
    staleness = parse_grid(args.staleness_grid, float)
    models = parse_grid(args.delay_models, str)
    etas = parse_grid(args.delay_eta_grid, float)
    # fail fast on a bad grid — a mid-sweep ValueError would throw away
    # every already-computed cell
    for task in tasks:
        if task not in TASKS:
            raise SystemExit(f"unknown task {task!r}; known: {TASKS}")
    for model in models:
        if model not in DELAY_MODELS:
            raise SystemExit(f"unknown delay model {model!r}; "
                             f"known: {DELAY_MODELS}")
    if "trace" in models and not args.trace_file:
        raise SystemExit("delay model 'trace' needs --trace-file "
                         "(format: docs/async.md)")
    if args.sampler == "trace-file" and not args.trace_file:
        raise SystemExit("sampler 'trace-file' needs --trace-file "
                         "(format: docs/async.md)")
    if "lognormal" in models and args.max_delay < 2:
        raise SystemExit("lognormal delays are clipped to [1, max-delay]: "
                         "set --max-delay >= 2")
    if any(s <= 0 for s in staleness):
        raise SystemExit("staleness grid values must be > 0 (a sync "
                         "baseline cell is added automatically per task)")
    tier_kw = {}
    if args.tiers is not None:
        fr, td = parse_tier_spec(args.tiers)
        tier_kw = {"tier_fracs": fr, "tier_delays": td}
    cells = []
    total = len(tasks) * (1 + len(staleness) * len(models) * len(etas))
    for task in tasks:
        print(f"[{len(cells) + 1}/{total}] {task} sync baseline",
              flush=True)
        cells.append(run_cell(
            task, PopulationConfig(n=args.population, cohort=args.cohort,
                                   sampler=args.sampler,
                                   trace_file=args.trace_file),
            args.steps, args.seed))
        for model in models:
            for ms in staleness:
                for eta in etas:
                    print(f"[{len(cells) + 1}/{total}] {task} "
                          f"delay_model={model} max_staleness={ms} "
                          f"delay_eta={eta}", flush=True)
                    pcfg = PopulationConfig(
                        n=args.population, cohort=args.cohort,
                        sampler=args.sampler, max_staleness=ms,
                        max_delay=args.max_delay, delay_eta=eta,
                        delay_model=model, delay_mu=args.delay_mu,
                        delay_sigma=args.delay_sigma,
                        trace_file=args.trace_file,
                        **(tier_kw if model == "tiers" else {}))
                    cells.append(run_cell(task, pcfg, args.steps,
                                          args.seed))
    return {
        "bench": "async_sweep",
        "meta": {
            "tasks": list(tasks),
            "steps": args.steps,
            "population": args.population,
            "cohort": args.cohort,
            "sampler": args.sampler,
            "staleness_grid": [json_safe(s) for s in staleness],
            "delay_models": list(models),
            "delay_eta_grid": list(etas),
            "max_delay": args.max_delay,
            "tiers": args.tiers,
            "delay_mu": args.delay_mu,
            "delay_sigma": args.delay_sigma,
            "seed": args.seed,
        },
        "cells": cells,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="convergence-vs-staleness sweep over the paper's tasks")
    ap.add_argument("--task", default="hyperclean,hyperrep",
                    help="comma list of tasks: hyperclean, hyperrep")
    ap.add_argument("--steps", type=int, default=64,
                    help="local steps per cell (q=8 per task config)")
    ap.add_argument("--population", type=int, default=8,
                    help="population size N (= the task's client count)")
    ap.add_argument("--cohort", type=int, default=2,
                    help="per-round compute cohort size C")
    ap.add_argument("--sampler", default="uniform",
                    help="cohort sampler (repro.fed.sampling.SAMPLERS)")
    ap.add_argument("--staleness-grid", default="2,4,inf",
                    help="comma list of max_staleness values (inf = async "
                         "with no gating)")
    ap.add_argument("--delay-models", default="uniform,tiers",
                    help="comma list of delay models: uniform, tiers, "
                         "lognormal, trace")
    ap.add_argument("--delay-eta-grid", default="0,0.5",
                    help="comma list of delay-adaptive eta coefficients")
    ap.add_argument("--max-delay", type=int, default=4,
                    help="uniform/lognormal delay bound (rounds)")
    ap.add_argument("--tiers", default=None,
                    help="tiers delay model spec frac:lo:hi[,frac:lo:hi"
                         "...], e.g. 0.2:1:1,0.6:2:4,0.2:4:8")
    ap.add_argument("--delay-mu", type=float, default=0.0,
                    help="lognormal delay model log-latency location")
    ap.add_argument("--delay-sigma", type=float, default=0.5,
                    help="lognormal delay model log-latency scale")
    ap.add_argument("--trace-file", default=None,
                    help="JSONL trace for the trace delay model / sampler")
    ap.add_argument("--seed", type=int, default=0,
                    help="run key seed (one key per cell, shared)")
    ap.add_argument("--out", default="BENCH_async_sweep.json",
                    help="output JSON path")
    args = ap.parse_args(argv)
    out = run_sweep(args)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, allow_nan=False)
        f.write("\n")
    print(f"wrote {len(out['cells'])} cells to {args.out}", flush=True)


if __name__ == "__main__":
    main()
