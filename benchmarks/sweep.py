"""Sweep harness for the paper's two tasks: convergence-vs-staleness
(``--bench async`` → ``BENCH_async_sweep.json``) and bytes-vs-convergence
(``--bench compression`` → ``BENCH_compression.json``).

Both benches run AdaFBiO on federated hyper-representation learning
(Section 6.1) and federated data hyper-cleaning (Section 6.2) over a grid
of settings, writing one machine-readable JSON record per cell through the
shared :func:`run_cell` helper — final task metric and grad norm, the
paper's cost counters (#samples with the async masked-dispatch convention,
#communication rounds), exact wire bytes (``bytes_up``/``bytes_down``, the
per-codec formulas of ``repro.fed.compress``), and wall-clock. The two
artifacts share a ``schema`` version field.

  async        — max_staleness x delay model x delay_eta, plus one
                 synchronous baseline per task; cells add arrival counts
                 and the accepted-staleness histogram (split by speed tier
                 for the ``tiers`` delay model). See docs/async.md.
  compression  — codec x compression level x task over synchronous
                 population rounds: one cell per ``--codec-grid`` entry
                 (``none`` = the full-precision baseline; ``int8:<bits>``
                 = stochastic uniform quantization; ``topk:<frac>`` =
                 magnitude sparsification), error feedback per ``--ef``.
                 See docs/compression.md.
  topology     — sync-layer grid (``--bench topology`` →
                 ``BENCH_topology.json``): the star baseline plus every
                 ``--topology-grid`` gossip topology, crossed with
                 ``--codec-grid``, over full-participation synchronous
                 rounds on hyper-representation. Cells add the mixing
                 matrix's spectral gap, the directed edge count, and the
                 exact per-edge wire bytes. See docs/topology.md.

    PYTHONPATH=src:. python benchmarks/sweep.py --task hyperclean \
        --steps 64 --population 8 --cohort 2 --staleness-grid 2,4,inf \
        --delay-models uniform,tiers --delay-eta-grid 0,0.5
    PYTHONPATH=src:. python benchmarks/sweep.py --bench compression \
        --task hyperclean --steps 64 --population 8 --cohort 2 \
        --codec-grid none,int8:8,int8:4,topk:0.25,topk:0.05
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import sys
import time

sys.path.insert(0, "src")

import jax

TASKS = ("hyperclean", "hyperrep")
BENCHES = ("async", "compression", "bank_scale", "obs_overhead",
           "megascan", "topology", "serve")
# bumped whenever a cell/meta field changes shape; shared by ALL artifacts
# so downstream consumers can gate on one number
# 3: every artifact gains a top-level "manifest" header (repro.obs)
SCHEMA = 3
DEFAULT_OUT = {"async": "BENCH_async_sweep.json",
               "compression": "BENCH_compression.json",
               "bank_scale": "BENCH_bank_scale.json",
               "obs_overhead": "BENCH_obs_overhead.json",
               "megascan": "BENCH_megascan.json",
               "topology": "BENCH_topology.json",
               "serve": "BENCH_serve.json"}
MEGASCAN_ENGINES = ("scan", "population", "async")


def build_task(name: str, n_clients: int):
    """(FedConfig, FedDriver kwargs) for one paper task at population size
    ``n_clients`` (sizes reduced from the paper's so a sweep cell costs
    seconds on CPU)."""
    if name == "hyperclean":
        from repro.configs.paper_tasks import HyperCleanConfig
        from repro.tasks.hyperclean import build_hyperclean
        cfg = HyperCleanConfig(n_clients=n_clients, n_train_per_client=64,
                               n_val_per_client=32)
        t = build_hyperclean(cfg)
        return cfg.fed, dict(problem=t["problem"], batch_fn=t["batch_fn"],
                             init_xy=t["init_xy"], metric_fn=t["val_loss"],
                             grad_norm_fn=t["true_grad_norm"])
    if name == "hyperrep":
        from repro.configs.paper_tasks import HyperRepConfig
        from repro.tasks.hyperrep import build_hyperrep
        cfg = HyperRepConfig(n_clients=n_clients)
        t = build_hyperrep(cfg)
        return cfg.fed, dict(problem=t["problem"], batch_fn=t["batch_fn"],
                             init_xy=t["init_xy"], metric_fn=t["val_loss"])
    raise KeyError(f"unknown task {name!r}; known: {TASKS}")


def json_safe(x):
    """inf -> "inf", nan -> null so the output stays spec-valid JSON
    (json.dump would emit bare Infinity/NaN tokens, which strict RFC 8259
    parsers reject)."""
    if isinstance(x, float):
        if math.isnan(x):
            return None
        if math.isinf(x):
            return "inf"
    return x


def run_cell(task: str, pcfg, steps: int, seed: int,
             fed_overrides: dict = None, engine: str = None) -> tuple:
    """One sweep cell — the run/record core shared by the grid benches:
    build the task, apply any FedConfig overrides (the compression bench's
    codec fields), run the FedDriver (``engine`` overrides the driver's
    default — the topology bench's gossip cells), and return ``(cell,
    driver)`` where ``cell`` carries the schema fields every bench records
    (task, metrics, the paper's cost counters, exact wire bytes,
    wall-clock)."""
    from repro.tasks.driver import FedDriver
    fed, kw = build_task(task, pcfg.n)
    if fed_overrides:
        fed = dataclasses.replace(fed, **fed_overrides)
    d = FedDriver(kw.pop("problem"), fed, pcfg.n, kw.pop("batch_fn"),
                  kw.pop("init_xy"), algorithm="adafbio", **kw)
    d.population = pcfg
    if engine is not None:
        d.engine = engine
    t0 = time.time()
    r = d.run(steps, key=jax.random.PRNGKey(seed),
              eval_every=max(steps - 1, 1))
    cell = {
        "task": task,
        "sampler": pcfg.sampler,
        "steps": int(r.steps[-1] + 1),
        "metric0": json_safe(float(r.metric[0])),
        "metricT": json_safe(float(r.metric[-1])),
        # hyperrep has no exact-gradient oracle: NaN -> null
        "grad_normT": json_safe(float(r.grad_norm[-1])),
        "samples": int(r.samples[-1]),
        "comms": int(r.comms[-1]),
        "bytes_up": int(r.bytes_up[-1]),
        "bytes_down": int(r.bytes_down[-1]),
        "seconds": round(time.time() - t0, 3),
    }
    return cell, d


def run_async_cell(task: str, pcfg, steps: int, seed: int) -> dict:
    """An async-bench cell: the shared record plus the delay-model grid
    coordinates and the arrival/staleness statistics."""
    cell, d = run_cell(task, pcfg, steps, seed)
    cell.update({
        "delay_model": pcfg.delay_model,
        "max_staleness": json_safe(pcfg.max_staleness),
        "max_delay": pcfg.max_delay,
        "delay_eta": pcfg.delay_eta,
    })
    if pcfg.asynchronous:
        log = d.staleness_log
        cell.update({
            "rounds": len(log),
            "arrived": sum(s["arrived"] for s in log),
            "accepted": sum(s["accepted"] for s in log),
            "dropped": sum(s["dropped"] for s in log),
            "dispatched": sum(s["dispatched"] for s in log),
            "staleness_hist": d.staleness_hist.tolist(),
        })
        if d.staleness_hist_by_tier:
            cell["staleness_hist_by_tier"] = {
                str(ti): h.tolist()
                for ti, h in sorted(d.staleness_hist_by_tier.items())}
            cell["tier_fracs"] = list(pcfg.tier_fracs)
            cell["tier_delays"] = [list(td) for td in pcfg.tier_delays]
    return cell


def parse_grid(spec: str, cast):
    return tuple(cast(v) for v in spec.split(",") if v)


def parse_codec_grid(spec: str):
    """Parse a ``--codec-grid`` spec — comma list of ``none``,
    ``int8:<bits>`` or ``topk:<frac>`` — into FedConfig override dicts,
    e.g. ``none,int8:8,topk:0.25`` → ``[{"codec": "none"}, {"codec":
    "int8", "codec_bits": 8}, {"codec": "topk", "topk_frac": 0.25}]``."""
    from repro.configs.base import CODECS, validate_codec
    out = []
    for part in spec.split(","):
        if not part:
            continue
        name, _, level = part.partition(":")
        if name not in CODECS:
            raise SystemExit(f"unknown codec {name!r} in --codec-grid; "
                             f"known: {CODECS}")
        ov = {"codec": name}
        try:
            if name == "int8":
                ov["codec_bits"] = int(level) if level else 8
            elif name == "topk":
                ov["topk_frac"] = float(level) if level else 0.1
            elif level:
                raise ValueError("codec 'none' takes no level")
            validate_codec(ov["codec"], ov.get("codec_bits", 8),
                           ov.get("topk_frac", 0.1))
        except ValueError as e:
            raise SystemExit(f"bad --codec-grid entry {part!r}: {e}")
        out.append(ov)
    if not out:
        raise SystemExit("--codec-grid is empty")
    return out


def run_compression_sweep(args) -> dict:
    """The bytes-vs-convergence grid: per task, one cell per --codec-grid
    entry over synchronous population rounds (``none`` is the
    full-precision baseline the compressed cells are compared against)."""
    from repro.configs.base import PopulationConfig
    tasks = parse_grid(args.task, str)
    for task in tasks:
        if task not in TASKS:
            raise SystemExit(f"unknown task {task!r}; known: {TASKS}")
    grid = parse_codec_grid(args.codec_grid)
    ef = args.ef == "on"
    cells = []
    total = len(tasks) * len(grid)
    for task in tasks:
        for ov in grid:
            level = ov.get("codec_bits", ov.get("topk_frac"))
            print(f"[{len(cells) + 1}/{total}] {task} codec={ov['codec']}"
                  f"{'' if level is None else f' level={level}'}",
                  flush=True)
            pcfg = PopulationConfig(n=args.population, cohort=args.cohort,
                                    sampler=args.sampler,
                                    trace_file=args.trace_file)
            cell, _ = run_cell(task, pcfg, args.steps, args.seed,
                               fed_overrides={**ov, "error_feedback": ef})
            cell.update({"codec": ov["codec"], "level": level,
                         "ef": ef if ov["codec"] != "none" else None})
            cells.append(cell)
    return {
        "bench": "compression",
        "schema": SCHEMA,
        "meta": {
            "tasks": list(tasks),
            "steps": args.steps,
            "population": args.population,
            "cohort": args.cohort,
            "sampler": args.sampler,
            "codec_grid": args.codec_grid,
            "ef": ef,
            "seed": args.seed,
        },
        "cells": cells,
    }


def _per_edge(total_bytes: int, crossings: int):
    """Exact bytes one directed edge carries per sync (None when nothing
    was billed — a 0-sync run)."""
    return int(round(total_bytes / crossings)) if crossings else None


def run_topology(args) -> dict:
    """The sync-layer grid (``--bench topology`` → ``BENCH_topology.json``):
    the star baseline plus every ``--topology-grid`` gossip topology, each
    crossed with ``--codec-grid``, over full-participation synchronous
    rounds. Cells record the shared convergence/cost fields plus the
    aggregator's mixing-matrix spectral gap, the directed edge count, and
    the exact per-edge message bytes (``GossipAggregator.wire_round``
    prices per directed edge; the star rows price per uplink message and
    per broadcast-downlink receiver). Expectation (docs/topology.md):
    convergence orders with the spectral gap — complete ≈ star, then
    torus2d, then ring — and int8 cells ship ~4x fewer bytes per edge at
    a small metric cost."""
    from repro.configs.base import TOPOLOGIES, PopulationConfig
    tasks = parse_grid(args.task, str)
    for task in tasks:
        if task not in TASKS:
            raise SystemExit(f"unknown task {task!r}; known: {TASKS}")
    topos = parse_grid(args.topology_grid, str)
    for t in topos:
        if t not in TOPOLOGIES:
            raise SystemExit(f"unknown topology {t!r} in --topology-grid; "
                             f"known: {TOPOLOGIES}")
    grid = parse_codec_grid(args.codec_grid)
    ef = args.ef == "on"
    n = args.population
    cells = []
    total = len(tasks) * (1 + len(topos)) * len(grid)
    for task in tasks:
        for topo in ("star",) + tuple(topos):
            for ov in grid:
                level = ov.get("codec_bits", ov.get("topk_frac"))
                print(f"[{len(cells) + 1}/{total}] {task} topology={topo} "
                      f"codec={ov['codec']}"
                      f"{'' if level is None else f' level={level}'}",
                      flush=True)
                pcfg = PopulationConfig(
                    n=n, cohort=n, sampler=args.sampler,
                    **({} if topo == "star"
                       else {"topology": topo, "er_p": args.er_p,
                             "topology_seed": args.seed}))
                cell, d = run_cell(task, pcfg, args.steps, args.seed,
                                   fed_overrides={**ov,
                                                  "error_feedback": ef},
                                   engine=None if topo == "star"
                                   else "gossip")
                cell.update({"topology": topo, "codec": ov["codec"],
                             "level": level,
                             "ef": ef if ov["codec"] != "none" else None})
                syncs = cell["comms"]
                if topo == "star":
                    # exact averaging — no mixing matrix; the downlink is
                    # one broadcast priced per receiving node
                    cell.update({
                        "spectral_gap": None,
                        "edges_per_sync": n,
                        "bytes_per_edge_up":
                            _per_edge(cell["bytes_up"], syncs * n),
                        "bytes_per_edge_down":
                            _per_edge(cell["bytes_down"], syncs * n),
                    })
                else:
                    agg = d.gossip_agg
                    crossings = sum(int(agg.edges(rid))
                                    for rid in range(syncs))
                    cell.update({
                        "spectral_gap": round(float(agg.gap), 6),
                        "edges_per_sync": int(agg.edges(0)),
                        "bytes_per_edge_up":
                            _per_edge(cell["bytes_up"], crossings),
                        "bytes_per_edge_down":
                            _per_edge(cell["bytes_down"], crossings),
                    })
                cells.append(cell)
    return {
        "bench": "topology",
        "schema": SCHEMA,
        "meta": {
            "tasks": list(tasks),
            "steps": args.steps,
            "population": n,
            "topology_grid": list(topos),
            "codec_grid": args.codec_grid,
            "ef": ef,
            "er_p": args.er_p,
            "sampler": args.sampler,
            "seed": args.seed,
        },
        "cells": cells,
    }


def run_bank_scale(args) -> dict:
    """The bank-sharding scaling grid (``--bench bank_scale`` →
    ``BENCH_bank_scale.json``): per population size N in ``--n-grid``, run
    C-cohort synchronous population rounds with the [N, ...] state bank
    PARTITIONED over a ``--devices``-way client mesh and record steady
    per-round wall-clock plus measured per-device bank bytes (from the
    final bank's ``addressable_shards``). Targets (docs/sharding.md):
    per-round time flat in N at fixed C — compute is O(C), the cohort
    gather is the only cross-shard op — and per-device bank bytes
    ∝ N/devices."""
    from repro.configs.base import PopulationConfig
    from repro.core.baselines import make_algorithm
    from tests.test_system import _quad_driver

    devices = min(args.devices, len(jax.devices()))
    if devices < args.devices:
        print(f"only {devices} device(s) visible (asked for "
              f"{args.devices}); set XLA_FLAGS="
              f"--xla_force_host_platform_device_count or run --bench "
              f"bank_scale before any other jax use", flush=True)
    mesh = jax.make_mesh((devices, 1), ("data", "model"))
    grid = parse_grid(args.n_grid, int)
    cells = []
    for i, n in enumerate(grid):
        if n % devices:
            print(f"skip N={n}: not divisible by {devices} devices "
                  f"(the bank would replicate)", flush=True)
            continue
        print(f"[{i + 1}/{len(grid)}] N={n} C={args.cohort} "
              f"devices={devices}", flush=True)
        # the population_scale recalibration: defaults are tuned for d=8
        # and diverge at the bigger quadratic
        d = _quad_driver("adafbio", m=n, d=96, p=64)
        d.fed = dataclasses.replace(d.alg.fed, lr_x=0.05, lr_y=0.2)
        d.alg = make_algorithm("adafbio", d.fed, d.problem)
        d.population = PopulationConfig(n=n, cohort=args.cohort,
                                        sampler=args.sampler)
        d.mesh = mesh
        steps = args.rounds * d.fed.q
        t0 = time.time()
        r = d.run(steps, key=jax.random.PRNGKey(args.seed),
                  eval_every=max(steps - 1, 1))
        timed = d.round_seconds[1:] or d.round_seconds
        leaves = jax.tree.leaves(d.final_bank)
        per_dev = {}
        for leaf in leaves:
            for s in leaf.addressable_shards:
                per_dev[s.device.id] = (per_dev.get(s.device.id, 0)
                                        + s.data.nbytes)
        cells.append({
            "n": n,
            "cohort": args.cohort,
            "devices": devices,
            "rounds": args.rounds,
            "round_seconds": round(sum(timed) / max(len(timed), 1), 6),
            "compile_seconds": round(r.compile_seconds, 3),
            "grad_normT": json_safe(float(r.grad_norm[-1])),
            "bytes_up": int(r.bytes_up[-1]),
            "bank_bytes_total": int(sum(l.nbytes for l in leaves)),
            "bank_bytes_per_device_max": int(max(per_dev.values())),
            "seconds": round(time.time() - t0, 3),
        })
    return {
        "bench": "bank_scale",
        "schema": SCHEMA,
        "meta": {
            "n_grid": list(grid),
            "cohort": args.cohort,
            "devices": devices,
            "rounds": args.rounds,
            "sampler": args.sampler,
            "seed": args.seed,
        },
        "cells": cells,
    }


def run_obs_overhead(args) -> dict:
    """Telemetry overhead guardrail (``--bench obs_overhead`` →
    ``BENCH_obs_overhead.json``): the SAME population-engine run with
    telemetry off vs on (a live ``Telemetry`` bus + MemorySink + the
    on-device stat accumulator, drained every ``--metrics-every`` rounds)
    and the steady per-round wall-clock of each. Records ``overhead_frac``
    = on/off - 1; the budget (docs/observability.md) is <= 5%. Each mode
    runs ``--reps`` times and keeps its best mean — per-round means on a
    busy CPU host are noisy and the overhead is a property of the code
    path, not of scheduler luck. Also records ``parity``: the final grad
    norms of the two modes must be bit-identical (telemetry is strictly
    observational; tests/test_obs.py pins the full trajectory)."""
    from repro.configs.base import PopulationConfig
    from repro.core.baselines import make_algorithm
    from repro.obs import MemorySink, Telemetry
    from tests.test_system import _quad_driver

    def build():
        # the population_scale recalibration: defaults are tuned for d=8
        d = _quad_driver("adafbio", m=args.population, d=96, p=64)
        d.fed = dataclasses.replace(d.alg.fed, lr_x=0.05, lr_y=0.2)
        d.alg = make_algorithm("adafbio", d.fed, d.problem)
        d.population = PopulationConfig(n=args.population,
                                        cohort=args.cohort,
                                        sampler=args.sampler)
        return d

    def measure(with_tele):
        best, result = None, None
        for _ in range(max(args.reps, 1)):
            d = build()
            tele = None
            if with_tele:
                tele = Telemetry([MemorySink()],
                                 metrics_every=args.metrics_every)
                d.telemetry = tele
            steps = args.rounds * d.fed.q
            r = d.run(steps, key=jax.random.PRNGKey(args.seed),
                      eval_every=max(steps - 1, 1))
            if tele is not None:
                tele.close()
            timed = d.round_seconds[1:] or d.round_seconds
            mean = sum(timed) / max(len(timed), 1)
            if best is None or mean < best:
                best, result = mean, r
        return best, result

    print(f"[1/2] baseline (telemetry off): N={args.population} "
          f"C={args.cohort} rounds={args.rounds} reps={args.reps}",
          flush=True)
    off, r_off = measure(False)
    print(f"[2/2] telemetry on: metrics_every={args.metrics_every}",
          flush=True)
    on, r_on = measure(True)
    overhead = on / max(off, 1e-12) - 1.0
    print(f"baseline {off * 1e3:.2f}ms/round, telemetry {on * 1e3:.2f}"
          f"ms/round: overhead {overhead * 100:+.2f}%", flush=True)
    cells = [
        {"mode": "baseline",
         "round_seconds": round(off, 6),
         "rounds_per_sec": round(1.0 / max(off, 1e-12), 3),
         "grad_normT": json_safe(float(r_off.grad_norm[-1]))},
        {"mode": "telemetry",
         "round_seconds": round(on, 6),
         "rounds_per_sec": round(1.0 / max(on, 1e-12), 3),
         "metrics_every": args.metrics_every,
         "grad_normT": json_safe(float(r_on.grad_norm[-1]))},
    ]
    return {
        "bench": "obs_overhead",
        "schema": SCHEMA,
        "meta": {
            "population": args.population,
            "cohort": args.cohort,
            "rounds": args.rounds,
            "reps": args.reps,
            "metrics_every": args.metrics_every,
            "sampler": args.sampler,
            "seed": args.seed,
            "overhead_frac": round(overhead, 4),
            "target_frac": 0.05,
            "parity": bool(float(r_off.grad_norm[-1])
                           == float(r_on.grad_norm[-1])),
        },
        "cells": cells,
    }


def run_megascan(args) -> dict:
    """The mega-scan speedup grid (``--bench megascan`` →
    ``BENCH_megascan.json``): per engine in ``--engines`` and R in
    ``--r-grid``, run the same quadratic AdaFBiO problem with
    ``rounds_per_scan=R`` and record the steady per-round wall-clock.
    The R=1 cell of each engine is the in-run baseline the ``speedup``
    meta compares against; the acceptance target (docs/megascan.md) is
    >= 3x steady-state rounds/sec on the population engine. Cells run
    the small quadratic at ``--q`` local steps per round (default 1 —
    sync every step, the communication-heaviest setting): that is the
    dispatch-bound regime the mega-scan tier exists for, where per-round
    program execution is small next to the per-program host dispatch the
    fused R-round program amortizes away. Each cell runs 1 + R warm-up
    rounds (the single-round peel + the first, compiling, R-chunk) plus
    at least ``--rounds`` steady rounds, so the R-length chunk repeats
    and ``round_seconds`` is populated."""
    from repro.configs.base import PopulationConfig
    from repro.core.baselines import make_algorithm
    from tests.test_system import _quad_driver

    grid = parse_grid(args.r_grid, int)
    engines = parse_grid(args.engines, str)
    for e in engines:
        if e not in MEGASCAN_ENGINES:
            raise SystemExit(f"unknown engine {e!r} in --engines; "
                             f"known: {MEGASCAN_ENGINES}")
    if 1 not in grid:
        raise SystemExit("--r-grid must include 1 (the per-engine "
                         "baseline cell the speedup meta divides by)")
    if any(r < 1 for r in grid):
        raise SystemExit("--r-grid values must be >= 1")
    cells = []
    total = len(engines) * len(grid)
    for engine in engines:
        for R in grid:
            print(f"[{len(cells) + 1}/{total}] engine={engine} R={R} "
                  f"N={args.population} C={args.cohort} q={args.q}",
                  flush=True)
            d = _quad_driver("adafbio", m=args.population)
            d.fed = dataclasses.replace(d.alg.fed, q=args.q)
            d.alg = make_algorithm("adafbio", d.fed, d.problem)
            if engine != "scan":
                kw = ({} if engine == "population"
                      else {"max_staleness": 4.0, "max_delay": 4})
                d.population = PopulationConfig(n=args.population,
                                                cohort=args.cohort,
                                                sampler=args.sampler, **kw)
            d.rounds_per_scan = R
            # 1 peeled round + 1 compiling R-chunk + ceil(rounds/R) steady
            # R-chunks (the only ones _log_chunk counts)
            rounds_total = 1 + R + R * -(-args.rounds // R)
            steps = rounds_total * d.fed.q
            t0 = time.time()
            r = d.run(steps, key=jax.random.PRNGKey(args.seed),
                      eval_every=max(steps - 1, 1))
            timed = d.round_seconds[1:] or d.round_seconds
            mean = sum(timed) / max(len(timed), 1)
            cells.append({
                "engine": engine,
                "rounds_per_scan": R,
                "rounds_total": rounds_total,
                "rounds_timed": len(timed),
                "round_seconds": round(mean, 6),
                "rounds_per_sec": round(1.0 / max(mean, 1e-12), 3),
                "compile_seconds": round(r.compile_seconds, 3),
                "grad_normT": json_safe(float(r.grad_norm[-1])),
                "samples": int(r.samples[-1]),
                "bytes_up": int(r.bytes_up[-1]),
                "seconds": round(time.time() - t0, 3),
            })
    speedup = {}
    for engine in engines:
        mine = [c for c in cells if c["engine"] == engine]
        base = next(c for c in mine if c["rounds_per_scan"] == 1)
        speedup[engine] = {
            str(c["rounds_per_scan"]):
                round(c["rounds_per_sec"] / base["rounds_per_sec"], 3)
            for c in mine if c["rounds_per_scan"] != 1}
    best_pop = max(speedup.get("population", {"": 0.0}).values())
    return {
        "bench": "megascan",
        "schema": SCHEMA,
        "meta": {
            "engines": list(engines),
            "r_grid": list(grid),
            "population": args.population,
            "cohort": args.cohort,
            "q": args.q,
            "rounds": args.rounds,
            "sampler": args.sampler,
            "seed": args.seed,
            "speedup": speedup,
            "target_speedup": 3.0,
            "population_speedup_best": round(best_pop, 3),
            "population_target_met": best_pop >= 3.0,
        },
        "cells": cells,
    }


def run_serve(args) -> dict:
    """Continuous-batching throughput grid (``--bench serve`` →
    ``BENCH_serve.json``): the SAME synthetic workload served at every
    ``--slots-grid`` pool size x ``--kv-quant-grid`` cache layout, on a
    seed-initialized reduced ``--serve-arch`` model. Each cell records
    requests/sec, tokens/sec, and p50/p99 latency; meta derives the
    speedup of the largest slot pool over the slots=1 one-at-a-time
    baseline per quant mode (the continuous-batching win — docs/serving.md
    targets >= 2x at >= 8 slots). Every cell runs the workload twice and
    measures the second pass: each Engine jits fresh programs, so the
    first pass is compile-dominated and would drown the scheduling
    signal."""
    from repro.configs import get_arch, reduced
    from repro.models import init_params, model_specs
    from repro.serve import Engine, LoadSpec, generate_requests

    cfg = reduced(get_arch(args.serve_arch))
    params = init_params(model_specs(cfg), jax.random.PRNGKey(args.seed),
                         cfg.dtype)
    slots_grid = parse_grid(args.slots_grid, int)
    quant_grid = []
    for v in parse_grid(args.kv_quant_grid, str):
        if v not in ("off", "on"):
            raise SystemExit(f"--kv-quant-grid entries must be off/on, "
                             f"got {v!r}")
        quant_grid.append(v == "on")
    prompt_lens = parse_grid(args.serve_prompt_lens, int)
    # capacity covers the longest prompt plus the full budget: every
    # request retires on eos/length, so cells differ only in scheduling
    max_len = max(prompt_lens) + args.serve_max_new + 1
    spec = LoadSpec(n_requests=args.serve_requests, rate=0.0,
                    prompt_lens=prompt_lens,
                    mean_new_tokens=max(args.serve_max_new / 2.0, 1.0),
                    max_new_cap=args.serve_max_new, seed=args.seed)
    enc = ((max_len, cfg.d_model) if cfg.family == "encdec" else None)
    pre = ((cfg.n_prefix_embeds, cfg.d_model) if cfg.n_prefix_embeds
           else None)
    reqs = generate_requests(spec, cfg.vocab, enc_shape=enc,
                             prefix_shape=pre)
    total = len(quant_grid) * len(slots_grid)
    cells = []
    for kvq in quant_grid:
        for slots in slots_grid:
            i = len(cells) + 1
            print(f"[{i}/{total}] slots={slots} "
                  f"kv_quant={'on' if kvq else 'off'}: "
                  f"{len(reqs)} requests", flush=True)
            eng = Engine(cfg, params, slots=slots, max_len=max_len,
                         kv_quant=kvq)
            eng.run(reqs)                      # warmup: pays the compiles
            eng.start_clock()                  # latencies measure from here
            t0 = time.time()
            done = eng.run(reqs)
            wall = time.time() - t0
            toks = sum(len(c.tokens) for c in done)
            lats = sorted(c.latency_s for c in done)
            p = lambda q: lats[min(int(q * len(lats)), len(lats) - 1)]
            cells.append({
                "slots": slots,
                "kv_quant": kvq,
                "requests": len(done),
                "new_tokens": toks,
                "wall_s": round(wall, 4),
                "requests_per_s": round(len(done) / wall, 3),
                "tokens_per_s": round(toks / wall, 2),
                "p50_s": round(p(0.5), 5),
                "p99_s": round(p(0.99), 5),
            })
            print(f"    {cells[-1]['requests_per_s']} req/s  "
                  f"{cells[-1]['tokens_per_s']} tok/s  "
                  f"p50 {cells[-1]['p50_s']}s", flush=True)
    speedup = {}
    for kvq in quant_grid:
        mine = {c["slots"]: c["requests_per_s"] for c in cells
                if c["kv_quant"] == kvq}
        base = mine.get(1) or mine[min(mine)]
        top = max(mine)
        speedup["on" if kvq else "off"] = {
            "slots": top, "vs_slots": 1 if 1 in mine else min(mine),
            "requests_per_s_ratio": round(mine[top] / base, 3)}
    best = max(s["requests_per_s_ratio"] for s in speedup.values())
    print(f"best continuous-batching speedup: {best}x req/s", flush=True)
    return {
        "bench": "serve",
        "schema": SCHEMA,
        "meta": {
            "arch": args.serve_arch,
            "reduced": True,
            "requests": args.serve_requests,
            "prompt_lens": list(prompt_lens),
            "max_new": args.serve_max_new,
            "max_len": max_len,
            "slots_grid": list(slots_grid),
            "kv_quant_grid": ["on" if q else "off" for q in quant_grid],
            "seed": args.seed,
            "speedup": speedup,
            "target_ratio": 2.0,
            "target_met": best >= 2.0,
        },
        "cells": cells,
    }


def run_sweep(args) -> dict:
    """The full grid: per task, one sync baseline + every
    (max_staleness, delay_model, delay_eta) combination."""
    from repro.configs.base import DELAY_MODELS, PopulationConfig
    from repro.fed.population import parse_tier_spec
    tasks = parse_grid(args.task, str)
    staleness = parse_grid(args.staleness_grid, float)
    models = parse_grid(args.delay_models, str)
    etas = parse_grid(args.delay_eta_grid, float)
    # fail fast on a bad grid — a mid-sweep ValueError would throw away
    # every already-computed cell
    for task in tasks:
        if task not in TASKS:
            raise SystemExit(f"unknown task {task!r}; known: {TASKS}")
    for model in models:
        if model not in DELAY_MODELS:
            raise SystemExit(f"unknown delay model {model!r}; "
                             f"known: {DELAY_MODELS}")
    if "trace" in models and not args.trace_file:
        raise SystemExit("delay model 'trace' needs --trace-file "
                         "(format: docs/async.md)")
    if args.sampler == "trace-file" and not args.trace_file:
        raise SystemExit("sampler 'trace-file' needs --trace-file "
                         "(format: docs/async.md)")
    if "lognormal" in models and args.max_delay < 2:
        raise SystemExit("lognormal delays are clipped to [1, max-delay]: "
                         "set --max-delay >= 2")
    if any(s <= 0 for s in staleness):
        raise SystemExit("staleness grid values must be > 0 (a sync "
                         "baseline cell is added automatically per task)")
    tier_kw = {}
    if args.tiers is not None:
        fr, td = parse_tier_spec(args.tiers)
        tier_kw = {"tier_fracs": fr, "tier_delays": td}
    cells = []
    total = len(tasks) * (1 + len(staleness) * len(models) * len(etas))
    for task in tasks:
        print(f"[{len(cells) + 1}/{total}] {task} sync baseline",
              flush=True)
        cells.append(run_async_cell(
            task, PopulationConfig(n=args.population, cohort=args.cohort,
                                   sampler=args.sampler,
                                   trace_file=args.trace_file),
            args.steps, args.seed))
        for model in models:
            for ms in staleness:
                for eta in etas:
                    print(f"[{len(cells) + 1}/{total}] {task} "
                          f"delay_model={model} max_staleness={ms} "
                          f"delay_eta={eta}", flush=True)
                    pcfg = PopulationConfig(
                        n=args.population, cohort=args.cohort,
                        sampler=args.sampler, max_staleness=ms,
                        max_delay=args.max_delay, delay_eta=eta,
                        delay_model=model, delay_mu=args.delay_mu,
                        delay_sigma=args.delay_sigma,
                        trace_file=args.trace_file,
                        **(tier_kw if model == "tiers" else {}))
                    cells.append(run_async_cell(task, pcfg, args.steps,
                                                args.seed))
    return {
        "bench": "async_sweep",
        "schema": SCHEMA,
        "meta": {
            "tasks": list(tasks),
            "steps": args.steps,
            "population": args.population,
            "cohort": args.cohort,
            "sampler": args.sampler,
            "staleness_grid": [json_safe(s) for s in staleness],
            "delay_models": list(models),
            "delay_eta_grid": list(etas),
            "max_delay": args.max_delay,
            "tiers": args.tiers,
            "delay_mu": args.delay_mu,
            "delay_sigma": args.delay_sigma,
            "seed": args.seed,
        },
        "cells": cells,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="convergence-vs-staleness / bytes-vs-convergence "
                    "sweeps over the paper's tasks")
    ap.add_argument("--bench", default="async", choices=list(BENCHES),
                    help="async: convergence-vs-staleness grid; "
                         "compression: bytes-vs-convergence codec grid; "
                         "bank_scale: sharded-bank round time and "
                         "per-device bytes vs population size N; "
                         "obs_overhead: telemetry-on vs -off steady "
                         "round time (budget: <= 5%%); "
                         "megascan: steady rounds/sec vs rounds_per_scan "
                         "R per engine (target: >= 3x on population); "
                         "topology: star vs gossip sync layers x codec "
                         "(spectral gap, per-edge bytes); "
                         "serve: continuous-batching requests/sec over "
                         "slot-pool size x kv_quant (target: >= 2x at "
                         ">= 8 slots)")
    ap.add_argument("--task", default=None,
                    help="comma list of tasks: hyperclean, hyperrep "
                         "(default: both; topology bench: hyperrep)")
    ap.add_argument("--steps", type=int, default=64,
                    help="local steps per cell (q=8 per task config)")
    ap.add_argument("--population", type=int, default=8,
                    help="population size N (= the task's client count)")
    ap.add_argument("--cohort", type=int, default=2,
                    help="per-round compute cohort size C")
    ap.add_argument("--sampler", default="uniform",
                    help="cohort sampler (repro.fed.sampling.SAMPLERS)")
    ap.add_argument("--staleness-grid", default="2,4,inf",
                    help="comma list of max_staleness values (inf = async "
                         "with no gating)")
    ap.add_argument("--delay-models", default="uniform,tiers",
                    help="comma list of delay models: uniform, tiers, "
                         "lognormal, trace")
    ap.add_argument("--delay-eta-grid", default="0,0.5",
                    help="comma list of delay-adaptive eta coefficients")
    ap.add_argument("--max-delay", type=int, default=4,
                    help="uniform/lognormal delay bound (rounds)")
    ap.add_argument("--tiers", default=None,
                    help="tiers delay model spec frac:lo:hi[,frac:lo:hi"
                         "...], e.g. 0.2:1:1,0.6:2:4,0.2:4:8")
    ap.add_argument("--delay-mu", type=float, default=0.0,
                    help="lognormal delay model log-latency location")
    ap.add_argument("--delay-sigma", type=float, default=0.5,
                    help="lognormal delay model log-latency scale")
    ap.add_argument("--trace-file", default=None,
                    help="JSONL trace for the trace delay model / sampler")
    ap.add_argument("--codec-grid", default=None,
                    help="compression/topology bench: comma list of none / "
                         "int8:<bits> / topk:<frac> cells (default: "
                         "none,int8:8,int8:4,topk:0.25,topk:0.05; topology "
                         "bench: none,int8:8)")
    ap.add_argument("--topology-grid", default="ring,torus2d,complete",
                    help="topology bench: comma list of gossip topologies "
                         "to grid against the star baseline "
                         "(repro.configs.base.TOPOLOGIES)")
    ap.add_argument("--er-p", type=float, default=0.4,
                    help="topology bench: Erdős–Rényi edge probability for "
                         "'erdos' grid entries")
    ap.add_argument("--ef", default="on", choices=["on", "off"],
                    help="compression bench: error feedback for the lossy "
                         "cells")
    ap.add_argument("--n-grid", default="256,1024,4096",
                    help="bank_scale bench: comma list of population sizes "
                         "N (each must divide --devices)")
    ap.add_argument("--devices", type=int, default=2,
                    help="bank_scale bench: client-mesh device count (CPU "
                         "hosts are split via "
                         "--xla_force_host_platform_device_count, set "
                         "automatically when possible)")
    ap.add_argument("--rounds", type=int, default=6,
                    help="bank_scale / obs_overhead / megascan bench: "
                         "timed rounds per cell")
    ap.add_argument("--r-grid", default="1,4,16,32",
                    help="megascan bench: comma list of rounds_per_scan "
                         "values R (must include the R=1 baseline)")
    ap.add_argument("--q", type=int, default=1,
                    help="megascan bench: local steps per round (1 = sync "
                         "every step, the dispatch-bound regime the fused "
                         "program amortizes)")
    ap.add_argument("--engines", default="scan,population,async",
                    help="megascan bench: comma list of engines to grid "
                         "over: scan, population, async")
    ap.add_argument("--metrics-every", type=int, default=8,
                    help="obs_overhead bench: stat drain / flush cadence "
                         "of the telemetry-on run")
    ap.add_argument("--reps", type=int, default=3,
                    help="obs_overhead bench: repetitions per mode (the "
                         "best mean round time wins — wall-clock noise)")
    ap.add_argument("--serve-arch", default="qwen1.5-4b",
                    help="serve bench: architecture to serve (reduced "
                         "smoke-size variant, seed-initialized params)")
    ap.add_argument("--slots-grid", default="1,2,4,8",
                    help="serve bench: comma list of slot-pool sizes "
                         "(include 1 — the one-at-a-time baseline the "
                         "speedup derives against)")
    ap.add_argument("--kv-quant-grid", default="off,on",
                    help="serve bench: comma list of off/on int8 KV-cache "
                         "cells")
    ap.add_argument("--serve-requests", type=int, default=16,
                    help="serve bench: synthetic requests per cell (all "
                         "arrive at t=0: max-throughput drain)")
    ap.add_argument("--serve-prompt-lens", default="8,16",
                    help="serve bench: comma list of prompt-length buckets")
    ap.add_argument("--serve-max-new", type=int, default=16,
                    help="serve bench: per-request generation budget cap "
                         "(geometric draw with mean cap/2)")
    ap.add_argument("--seed", type=int, default=0,
                    help="run key seed (one key per cell, shared)")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: BENCH_async_sweep.json"
                         " / BENCH_compression.json per --bench)")
    args = ap.parse_args(argv)
    if args.out is None:
        args.out = DEFAULT_OUT[args.bench]
    if args.task is None:
        args.task = ("hyperrep" if args.bench == "topology"
                     else "hyperclean,hyperrep")
    if args.codec_grid is None:
        args.codec_grid = ("none,int8:8" if args.bench == "topology"
                           else "none,int8:8,int8:4,topk:0.25,topk:0.05")
    if args.bench == "bank_scale":
        # must land before the first jax backend touch: a CPU host splits
        # into N devices only via this env flag at initialization
        flags = os.environ.get("XLA_FLAGS", "")
        if (args.devices > 1
                and "xla_force_host_platform_device_count" not in flags):
            os.environ["XLA_FLAGS"] = (
                flags + " --xla_force_host_platform_device_count="
                + str(args.devices))
        out = run_bank_scale(args)
    elif args.bench == "obs_overhead":
        out = run_obs_overhead(args)
    elif args.bench == "megascan":
        out = run_megascan(args)
    elif args.bench == "topology":
        out = run_topology(args)
    elif args.bench == "serve":
        out = run_serve(args)
    else:
        out = (run_compression_sweep(args) if args.bench == "compression"
               else run_sweep(args))
    # schema 3: every artifact carries the run manifest (repro.obs) — what
    # produced it: config, git SHA, jax version, device topology, seed
    from repro.obs import run_manifest
    out["manifest"] = run_manifest(config=vars(args), seed=args.seed)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, allow_nan=False)
        f.write("\n")
    print(f"wrote {len(out['cells'])} cells to {args.out}", flush=True)


if __name__ == "__main__":
    main()
