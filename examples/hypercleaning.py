"""Federated data hyper-cleaning (paper Section 6.2, Problem (4)).

Trains per-sample weights x so the shared classifier y ignores corrupted
labels; reports the paper's exact stationarity metric E‖∇F(x̄)‖ and shows the
learned weights separating clean from corrupted samples.

    PYTHONPATH=src python examples/hypercleaning.py [algorithm]
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_tasks import HyperCleanConfig
from repro.core.tree_util import tree_mean_axis0
from repro.tasks.driver import FedDriver
from repro.tasks.hyperclean import build_hyperclean


def main(algorithm="adafbio", steps=120):
    cfg = HyperCleanConfig(n_clients=8)
    hc = build_hyperclean(cfg)
    driver = FedDriver(hc["problem"], cfg.fed, cfg.n_clients, hc["batch_fn"],
                       hc["init_xy"], metric_fn=hc["val_loss"],
                       grad_norm_fn=hc["true_grad_norm"], algorithm=algorithm)
    r = driver.run(steps, eval_every=20)
    print(f"algorithm={algorithm}")
    print(f"{'step':>6} {'comms':>6} {'val_loss':>9} {'|∇F|':>9}")
    for s, cm, v, g in zip(r.steps, r.comms, r.metric, r.grad_norm):
        print(f"{s:6d} {cm:6d} {v:9.4f} {g:9.4f}")

    # do the learned weights down-rank the corrupted samples?
    x_bar = np.asarray(r.final_avg_state["x"])         # [M, n_train] logits
    weights = 1.0 / (1.0 + np.exp(-x_bar))             # sigma(x_i)
    corrupted = np.asarray(hc["data"]["corrupted"])    # [M, n_train] bool
    w_clean = weights[~corrupted].mean()
    w_bad = weights[corrupted].mean()
    print(f"\nmean sigma(x_i): clean={w_clean:.3f}  corrupted={w_bad:.3f}  "
          f"({'OK: corrupted down-weighted' if w_bad < w_clean else 'no separation yet'})")


if __name__ == "__main__":
    main(*(sys.argv[1:2] or ["adafbio"]))
