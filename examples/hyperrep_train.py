"""End-to-end driver: federated hyper-representation training of an assigned
LM architecture (reduced size on CPU) with AdaFBiO — x = backbone, y = head,
q local steps per sync, K-term Neumann hypergradients, adaptive matrices.

    PYTHONPATH=src python examples/hyperrep_train.py [arch] [steps]

This is the same code path the production mesh uses (repro.launch.train);
full-size configs are exercised by the multi-pod dry-run.
"""
import sys

sys.path.insert(0, "src")

import jax

from repro.configs import FedConfig, get_arch, reduced
from repro.configs.base import ShapeConfig
from repro.data.synthetic import FederatedLMData, make_client_batch
from repro.fed.runtime import FederatedTrainer, client_batch_specs


def main(arch="qwen1.5-4b", steps=24):
    cfg = reduced(get_arch(arch))
    fed = FedConfig(q=4, neumann_k=2, lr_x=2e-2, lr_y=2e-1)
    shape = ShapeConfig("example", 64, 8, "train")
    tr = FederatedTrainer(cfg, fed, shape, mesh=None, algorithm="adafbio")
    specs, _ = client_batch_specs(cfg, shape, tr.m, fed)
    data = FederatedLMData(vocab=cfg.vocab, n_clients=tr.m)

    key = jax.random.PRNGKey(0)
    states, server = tr.init_states(key, make_client_batch(data, cfg, specs, 0))
    local = jax.jit(tr.local_step_fn())
    sync = jax.jit(tr.sync_step_fn())
    ev = jax.jit(tr.eval_fn())

    print(f"arch={arch} (reduced: {cfg.n_layers}L d={cfg.d_model}) "
          f"family={cfg.family} clients={tr.m} q={fed.q} K={fed.neumann_k}")
    for t in range(steps):
        if t > 0 and t % fed.q == 0:
            states, server = sync(states, server)
        batch = make_client_batch(data, cfg, specs, t)
        states, server = local(states, server, batch, key)
        if t % 8 == 0 or t == steps - 1:
            print(f"step {t:4d}  UL val loss f(x̄,ȳ) = "
                  f"{float(ev(states, batch)):.4f}")


if __name__ == "__main__":
    args = sys.argv[1:]
    main(args[0] if args else "qwen1.5-4b",
         int(args[1]) if len(args) > 1 else 24)
