"""Quickstart: AdaFBiO (paper Algorithm 1) on the analytic quadratic bilevel
problem, where the true hypergradient is available in closed form.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import FedConfig
from repro.core.bilevel import quadratic_bilevel_problem, quadratic_true_grad
from repro.tasks.driver import FedDriver


def main():
    d, p, m = 8, 6, 4
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    A = jax.random.normal(k1, (p, p))
    H = A @ A.T / p + 0.5 * jnp.eye(p)          # LL strongly convex (Assm. 1)
    Bm = jax.random.normal(k2, (p, d)) * 0.3
    c = jax.random.normal(k3, (p,))
    Q = jnp.eye(d) * 0.2
    problem = quadratic_bilevel_problem(H, Bm, c, Q)

    fed = FedConfig(q=4, neumann_k=8, lr_x=0.3, lr_y=0.3,
                    theta=float(1.0 / jnp.linalg.eigvalsh(H)[-1]))

    driver = FedDriver(
        problem, fed, n_clients=m,
        batch_fn=lambda client, step: {"f": 0.0, "g": 0.0, "g0": 0.0,
                                       "gi": jnp.zeros((fed.neumann_k,))},
        init_xy=lambda k: (jnp.ones((d,)) * 2.0, jnp.zeros((p,))),
        grad_norm_fn=lambda x, y: jnp.linalg.norm(
            quadratic_true_grad(H, Bm, c, Q, x)),
        algorithm="adafbio",
        engine="scan")           # each q-step round + sync is ONE program

    r = driver.run(120, eval_every=20)
    print(f"{'step':>6} {'samples':>8} {'comms':>6} {'|∇F(x̄)|':>10}")
    for s, smp, cm, g in zip(r.steps, r.samples, r.comms, r.grad_norm):
        print(f"{s:6d} {smp:8d} {cm:6d} {g:10.4f}")
    # round_seconds excludes the compile round (RunResult.compile_seconds);
    # drop one more entry — the sync variant compiles in round 1
    rounds_timed = driver.round_seconds[1:]
    per_round = (sum(rounds_timed) / len(rounds_timed) * 1e3
                 if rounds_timed else float("nan"))
    print(f"\nAdaFBiO: q={fed.q} local steps per communication round, "
          f"K={fed.neumann_k} Neumann terms; "
          f"grad norm {r.grad_norm[0]:.3f} -> {r.grad_norm[-1]:.3f}; "
          f"{per_round:.2f} ms/round (fused scan engine)")


if __name__ == "__main__":
    main()
