"""Serving demo: prefill a batch of prompts against a (reduced) assigned
architecture, then greedy-decode new tokens from the KV/SSM cache — the same
prefill_step/serve_step the decode dry-run shapes lower at production scale.

    PYTHONPATH=src python examples/serve_demo.py [arch] [new_tokens]
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.models import (ModelCtx, decode_step, init_cache, init_params,
                          model_specs, prefill)


def main(arch="falcon-mamba-7b", new_tokens=8):
    cfg = reduced(get_arch(arch))
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0), cfg.dtype)
    B, S = 2, 16
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(
            key, (B, S, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.n_prefix_embeds:
        batch["prefix_embeds"] = jnp.zeros(
            (B, cfg.n_prefix_embeds, cfg.d_model), jnp.bfloat16)

    cache = init_cache(cfg, B, S + new_tokens,
                       enc_len=S if cfg.family == "encdec" else 0)
    pctx = ModelCtx(kind="prefill")
    dctx = ModelCtx(kind="decode")
    prefill_jit = jax.jit(lambda p, b, c: prefill(cfg, p, b, c, pctx))
    decode_jit = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos,
                                                          dctx))

    logits, cache = prefill_jit(params, batch, cache)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    pos = S
    for i in range(new_tokens - 1):
        logits, cache = decode_jit(params, cache, tok, jnp.int32(pos))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
        pos += 1
    gen = jnp.concatenate(out, axis=1)
    print(f"arch={arch} family={cfg.family}")
    for b in range(B):
        print(f"  prompt[{b}] -> generated token ids: {gen[b].tolist()}")


if __name__ == "__main__":
    args = sys.argv[1:]
    main(args[0] if args else "falcon-mamba-7b",
         int(args[1]) if len(args) > 1 else 8)
