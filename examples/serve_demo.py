"""Serving demo: prefill a batch of prompts against a (reduced) assigned
architecture through ``build_serve_fns`` — the exact jitted prefill/decode
pair the serve engine (``repro.serve``) and the decode dry-run shapes lower
— then greedy-decode new tokens from the KV/SSM cache.

    PYTHONPATH=src python examples/serve_demo.py [arch] [new_tokens]
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.configs.base import ShapeConfig
from repro.fed.serve import build_serve_fns
from repro.models import init_params, model_specs


def main(arch="falcon-mamba-7b", new_tokens=8):
    cfg = reduced(get_arch(arch))
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0), cfg.dtype)
    B, S = 2, 16
    max_len = S + new_tokens
    pre = build_serve_fns(
        cfg, ShapeConfig("demo_prefill", max_len, B, "prefill"), None)
    dec = build_serve_fns(
        cfg, ShapeConfig("demo_decode", max_len, B, "decode"), None)

    # one independent key per random tensor — a shared key would correlate
    # the prompt tokens with the encoder activations
    key_tok, key_enc = jax.random.split(jax.random.PRNGKey(1))
    batch = {"tokens": jax.random.randint(key_tok, (B, S), 0, cfg.vocab)}
    if "enc_embeds" in pre["batch_specs"]:
        spec = pre["batch_specs"]["enc_embeds"]
        batch["enc_embeds"] = jax.random.normal(
            key_enc, spec.shape).astype(spec.dtype)
    if "prefix_embeds" in pre["batch_specs"]:
        spec = pre["batch_specs"]["prefix_embeds"]
        batch["prefix_embeds"] = jnp.zeros(spec.shape, spec.dtype)

    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         pre["cache_abs"])
    logits, cache = pre["prefill"](params, batch, cache)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    pos = S
    for _ in range(new_tokens - 1):
        logits, cache = dec["decode"](params, cache, tok, jnp.int32(pos))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
        pos += 1
    gen = jnp.concatenate(out, axis=1)
    print(f"arch={arch} family={cfg.family}")
    for b in range(B):
        print(f"  prompt[{b}] -> generated token ids: {gen[b].tolist()}")


if __name__ == "__main__":
    args = sys.argv[1:]
    main(args[0] if args else "falcon-mamba-7b",
         int(args[1]) if len(args) > 1 else 8)
