#!/usr/bin/env python
"""README CLI-flag-table ⇔ argparse parity check (the docs CI gate).

The README's "CLI flag reference" section documents every flag of
``launch/train.py`` and ``benchmarks/run.py`` in one table per tool. This
script asserts the two stay in lockstep, in BOTH directions:

  * every flag the argparse parsers define appears in the README table;
  * every flag the README table documents exists in the parsers.

Flags are extracted from the sources with a regex (no imports — the check
must run without jax installed), and from the README by section heading.
Run from the repo root: ``python scripts/check_docs.py``. Exit code 0 on
parity, 1 with a per-tool diff otherwise. Wired into the fast-tier CI job
and ``tests/test_docs.py``.
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

# tool name -> (source path, README section heading)
TOOLS = {
    "train": ("src/repro/launch/train.py",
              "### `python -m repro.launch.train`"),
    "bench": ("benchmarks/run.py", "### `python benchmarks/run.py`"),
    "sweep": ("benchmarks/sweep.py", "### `python benchmarks/sweep.py`"),
    "report": ("scripts/report.py", "### `python scripts/report.py`"),
    "serve": ("src/repro/launch/serve.py",
              "### `python -m repro.launch.serve`"),
}

ARG_RE = re.compile(r"""add_argument\(\s*["'](--[a-z0-9-]+)["']""")
ROW_RE = re.compile(r"^\|\s*`(--[a-z0-9-]+)`\s*\|")


def source_flags(path: pathlib.Path) -> set:
    return set(ARG_RE.findall(path.read_text()))


def readme_sections(readme: pathlib.Path) -> dict:
    """heading -> set of flags documented in that section's table."""
    sections, current = {}, None
    for line in readme.read_text().splitlines():
        if line.startswith("#"):
            current = line.strip()
            sections.setdefault(current, set())
            continue
        m = ROW_RE.match(line.strip())
        if m and current is not None:
            sections[current].add(m.group(1))
    return sections


DOCS = ("docs/ARCHITECTURE.md", "docs/async.md", "docs/compression.md",
        "docs/sharding.md", "docs/observability.md", "docs/megascan.md",
        "docs/topology.md", "docs/serving.md")


def main() -> int:
    readme = ROOT / "README.md"
    text = readme.read_text()
    sections = readme_sections(readme)
    failures = []
    for doc in DOCS:
        if not (ROOT / doc).is_file():
            failures.append(f"docs: {doc} is missing")
        elif doc not in text:
            failures.append(f"docs: README does not link to {doc}")
    for tool, (src, heading) in TOOLS.items():
        in_src = source_flags(ROOT / src)
        if heading not in sections:
            failures.append(f"{tool}: README section {heading!r} not found")
            continue
        in_doc = sections[heading]
        undocumented = sorted(in_src - in_doc)
        stale = sorted(in_doc - in_src)
        if undocumented:
            failures.append(f"{tool}: flags missing from the README table: "
                            f"{', '.join(undocumented)}")
        if stale:
            failures.append(f"{tool}: README documents flags the parser "
                            f"does not define: {', '.join(stale)}")
    if failures:
        print("check_docs: README CLI flag table out of sync "
              "(README.md 'CLI flag reference' section):", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    n = sum(len(sections[h]) for _, h in TOOLS.values())
    print(f"check_docs: OK — {n} flags documented, parsers and README "
          f"agree")
    return 0


if __name__ == "__main__":
    sys.exit(main())
