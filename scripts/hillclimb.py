"""§Perf Phase-2 hillclimbs: measure baseline vs optimized variants for the
three chosen (arch x shape) pairs on the single-pod mesh.

  A. deepseek-67b x train_4k   (paper-representative; memory/compute)
     variant: microbatch_per_shard 1 -> 2 (halves FSDP weight re-gathers,
     costs ~1 activation-buffer of memory)
  B. qwen3-moe-30b-a3b x prefill_32k (most collective-bound)
     variant: capacity_factor 1.25 -> 1.0 + measured collective breakdown
  C. qwen1.5-4b x decode_32k   (memory-bound: KV-cache bandwidth)
     variant: int8 KV cache (+ fused-dequant Pallas kernel for the TPU build)

Writes results/hillclimb/<name>.json.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import dataclasses
import json
import sys
from pathlib import Path

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.configs import FedConfig, INPUT_SHAPES, get_arch
from repro.fed.runtime import FederatedTrainer, client_batch_specs
from repro.fed.serve import build_serve_fns
from repro.launch.dryrun import _cost_stats, _mem_stats, parse_collectives
from repro.launch.mesh import make_production_mesh

OUT = Path("results/hillclimb")
OUT.mkdir(parents=True, exist_ok=True)


def record(name, compiled):
    txt = compiled.as_text()
    rec = {"memory": _mem_stats(compiled, txt), "cost": _cost_stats(compiled),
           "collectives": parse_collectives(txt)}
    (OUT / f"{name}.json").write_text(json.dumps(rec, indent=1))
    m = rec["memory"]
    coll = {k: round(v["wire_bytes"] / 2**20, 1)
            for k, v in rec["collectives"].items() if isinstance(v, dict)}
    print(f"{name}: arg {m['argument_bytes']/2**30:.2f} GiB, "
          f"temp {m['temp_bytes']/2**30:.2f} (tpu-adj "
          f"{m.get('temp_bytes_tpu_adj',0)/2**30:.2f}), "
          f"wire MiB {coll}", flush=True)
    return rec


def pair_a():
    cfg = get_arch("deepseek-67b")
    shape = INPUT_SHAPES["train_4k"]
    mesh = make_production_mesh()
    with mesh:
        for mb in (1, 2):
            fed = FedConfig(microbatch_per_shard=mb)
            tr = FederatedTrainer(cfg, fed, shape, mesh=mesh)
            bspecs, baxes = client_batch_specs(cfg, shape, tr.m, fed)
            fn = tr.jitted("local", bspecs, baxes, donate=False)
            c = fn.lower(tr.abstract_client_states(),
                         tr.abstract_server_state(), bspecs,
                         jax.ShapeDtypeStruct((2,), jnp.uint32)).compile()
            record(f"A_deepseek_train_mb{mb}", c)


def pair_b():
    cfg = get_arch("qwen3-moe-30b-a3b")
    shape = INPUT_SHAPES["prefill_32k"]
    mesh = make_production_mesh()
    with mesh:
        for cf, tag in ((1.25, "base"), (1.0, "cf1.0")):
            cfg2 = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=cf))
            fns = build_serve_fns(cfg2, shape, mesh)
            c = fns["prefill"].lower(*fns["in_abs"]).compile()
            record(f"B_qwen3moe_prefill_{tag}", c)


def pair_c():
    cfg = get_arch("qwen1.5-4b")
    shape = INPUT_SHAPES["decode_32k"]
    mesh = make_production_mesh()
    with mesh:
        for quant, tag in ((False, "bf16"), (True, "int8")):
            fns = build_serve_fns(cfg, shape, mesh, kv_quant=quant)
            c = fns["decode"].lower(*fns["in_abs"]).compile()
            record(f"C_qwen15_decode_{tag}", c)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "a"):
        pair_a()
    if which in ("all", "b"):
        pair_b()
    if which in ("all", "c"):
        pair_c()
