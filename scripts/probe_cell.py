"""Dev tool: dump the biggest HLO buffers of one dry-run cell.

PYTHONPATH=src python scripts/probe_cell.py <arch> <shape> [minMB]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import re
import sys
from collections import Counter

import jax
import jax.numpy as jnp

from repro.configs import FedConfig, INPUT_SHAPES, get_arch
from repro.fed.runtime import FederatedTrainer, client_batch_specs
from repro.fed.serve import build_serve_fns
from repro.launch.mesh import make_production_mesh

arch, shape_id = sys.argv[1], sys.argv[2]
min_mb = float(sys.argv[3]) if len(sys.argv) > 3 else 256
cfg = get_arch(arch)
shape = INPUT_SHAPES[shape_id]
mesh = make_production_mesh()
with mesh:
    if shape.kind == "train":
        tr = FederatedTrainer(cfg, FedConfig(), shape, mesh=mesh)
        bspecs, baxes = client_batch_specs(cfg, shape, tr.m, FedConfig())
        fn = tr.jitted("local", bspecs, baxes, donate=False)
        compiled = fn.lower(tr.abstract_client_states(),
                            tr.abstract_server_state(), bspecs,
                            jax.ShapeDtypeStruct((2,), jnp.uint32)).compile()
    else:
        fns = build_serve_fns(cfg, shape, mesh)
        fn = fns["prefill"] if shape.kind == "prefill" else fns["decode"]
        compiled = fn.lower(*fns["in_abs"]).compile()

ma = compiled.memory_analysis()
print(f"arg {ma.argument_size_in_bytes/2**30:.2f} temp "
      f"{ma.temp_size_in_bytes/2**30:.2f} out {ma.output_size_in_bytes/2**30:.2f} "
      f"alias {ma.alias_size_in_bytes/2**30:.2f} GiB")
DT = {"bf16": 2, "f16": 2, "f32": 4, "s32": 4, "u32": 4, "pred": 1, "s8": 1}
pat = re.compile(r"= ([a-z0-9]+)\[([0-9,]+)\]")
sizes = Counter()
for line in compiled.as_text().splitlines():
    m = pat.search(line)
    if not m:
        continue
    dt, dims = m.groups()
    n = DT.get(dt, 4)
    for d in dims.split(","):
        n *= int(d)
    if n > min_mb * 2**20:
        op = line.split("=", 2)[1].strip().split("(")[0]
        sizes[(round(n / 2**30, 2), dt, dims, op[:40])] += 1
for k, c in sorted(sizes.items(), reverse=True)[:15]:
    print(c, "x", k)
