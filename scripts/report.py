#!/usr/bin/env python
"""Render (or validate) a telemetry metrics JSONL stream.

Usage:
  python scripts/report.py RUN.jsonl            # human-readable run summary
  python scripts/report.py RUN.jsonl --check    # schema validation, exit != 0
                                                # on a malformed stream

The stream is whatever ``--metrics-out`` wrote (``launch/train.py``,
``benchmarks/run.py``) or a ``repro.obs.JsonlSink`` captured from a
``FedDriver`` run: one manifest record, then round / stats / bench_row
records, then one summary (docs/observability.md has the schema spec).
The rendered report covers rounds/sec (steady state — the first round
carries the compile), the phase span breakdown, wire totals and the
staleness histogram when the run recorded one.

Stdlib-only on purpose: CI validates artifacts with it before upload, and
it must run anywhere the JSONL lands.
"""
from __future__ import annotations

import argparse
import json
import sys

KNOWN_KINDS = {"manifest", "round", "stats", "summary", "bench_row",
               "request", "tick"}

# fields every record of the kind must carry (schema 1)
REQUIRED = {
    "manifest": ("schema", "run_id", "jax_version", "platform",
                 "device_count", "git_sha", "seed", "argv"),
    "round": ("round",),
    "stats": ("round_start",),
    "summary": ("rounds", "phases"),
    "bench_row": ("name", "us_per_call"),
    "request": ("rid", "prompt_len", "new_tokens", "finish_reason"),
    "tick": ("tick", "active"),
}


def load(path):
    records = []
    with open(path) as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                records.append((i, json.loads(line)))
            except json.JSONDecodeError as e:
                raise SystemExit(f"{path}:{i}: not valid JSON ({e})")
    if not records:
        raise SystemExit(f"{path}: empty stream")
    return records


def check(path, records):
    """Validate the stream; returns the list of problems (empty = OK)."""
    problems = []
    first = records[0][1]
    if first.get("kind") != "manifest":
        problems.append(f"line {records[0][0]}: first record must be the "
                        f"manifest, got kind={first.get('kind')!r}")
    for ln, rec in records:
        kind = rec.get("kind")
        if kind not in KNOWN_KINDS:
            problems.append(f"line {ln}: unknown kind {kind!r}")
            continue
        missing = [k for k in REQUIRED.get(kind, ()) if k not in rec]
        if missing:
            problems.append(f"line {ln}: {kind} record missing "
                            f"{missing}")
    rounds = [rec for _, rec in records if rec.get("kind") == "round"]
    ids = [r.get("round") for r in rounds if isinstance(r.get("round"), int)]
    if ids != sorted(ids):
        problems.append("round records out of order")
    ticks = [rec.get("tick") for _, rec in records
             if rec.get("kind") == "tick" and isinstance(rec.get("tick"), int)]
    if ticks != sorted(ticks):
        problems.append("tick records out of order")
    for ln, rec in records:
        if rec.get("kind") != "stats":
            continue
        cols = {k: v for k, v in rec.items()
                if isinstance(v, list)}
        lens = {len(v) for v in cols.values()}
        if len(lens) > 1:
            problems.append(f"line {ln}: stats columns have unequal "
                            f"lengths {sorted(lens)}")
    summaries = [rec for _, rec in records if rec.get("kind") == "summary"]
    if len(summaries) > 1:
        problems.append(f"{len(summaries)} summary records (want <= 1)")
    if summaries and rounds:
        if summaries[0].get("rounds") != len(rounds):
            problems.append(
                f"summary.rounds={summaries[0].get('rounds')} but stream "
                f"has {len(rounds)} round records")
    return problems


def _fmt_bytes(b):
    if b is None:
        return "-"
    for unit, div in (("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if b >= div:
            return f"{b / div:.2f}{unit}"
    return f"{b}B"


def render(path, records):
    by_kind = {}
    for _, rec in records:
        by_kind.setdefault(rec.get("kind"), []).append(rec)
    man = by_kind.get("manifest", [{}])[0]
    rounds = by_kind.get("round", [])
    stats = by_kind.get("stats", [])
    summary = by_kind.get("summary", [{}])[-1]
    bench = by_kind.get("bench_row", [])

    print(f"run {man.get('run_id', '?')}  ({path})")
    print(f"  created      {man.get('created', '?')}  "
          f"git {str(man.get('git_sha'))[:12]}")
    print(f"  jax {man.get('jax_version', '?')}  "
          f"{man.get('platform', '?')} x{man.get('device_count', '?')}  "
          f"seed={man.get('seed')}")
    if man.get("argv"):
        print(f"  argv         {' '.join(man['argv'])}")

    if rounds:
        dts = [r["round_seconds"] for r in rounds
               if r.get("round_seconds") is not None]
        # the first recorded round carries the compile — steady state
        # excludes it (same convention as RunResult.compile_seconds)
        steady = dts[1:] or dts
        print(f"\nrounds: {len(rounds)}"
              + (f"  (first/compile {dts[0]*1e3:.1f}ms)" if dts else ""))
        if steady:
            mean = sum(steady) / len(steady)
            print(f"  steady-state {mean*1e3:.2f}ms/round  "
                  f"= {1.0/mean:.2f} rounds/sec")
        last = rounds[-1]
        if last.get("bytes_up") is not None:
            print(f"  wire totals  up={_fmt_bytes(last['bytes_up'])}  "
                  f"down={_fmt_bytes(last['bytes_down'])}")
        if last.get("samples") is not None:
            print(f"  cost         samples={last['samples']}  "
                  f"comms={last.get('comms')}")

    phases = summary.get("phases") or {}
    if phases:
        print("\nphase breakdown:")
        total = sum(p["seconds"] for p in phases.values()) or 1.0
        for name, p in sorted(phases.items(), key=lambda kv: -kv[1]["seconds"]):
            print(f"  {name:<16} {p['seconds']*1e3:9.1f}ms  "
                  f"x{p['count']:<5d} {100 * p['seconds'] / total:5.1f}%")

    if stats:
        cols = {}
        for s in stats:
            for k, v in s.items():
                if isinstance(v, list):
                    cols.setdefault(k, []).extend(v)
        print(f"\non-device stats ({len(stats)} drain(s), "
              f"{len(next(iter(cols.values()), []))} rounds):")
        for k, vs in cols.items():
            if vs:
                print(f"  {k:<16} last={vs[-1]:.4g}  "
                      f"mean={sum(vs)/len(vs):.4g}  max={max(vs):.4g}")

    reqs = by_kind.get("request", [])
    ticks = by_kind.get("tick", [])
    if reqs:
        lats = sorted(r["latency_s"] for r in reqs
                      if r.get("latency_s") is not None)
        toks = sum(r.get("new_tokens", 0) for r in reqs)
        reasons = {}
        for r in reqs:
            reasons[r["finish_reason"]] = reasons.get(r["finish_reason"], 0) + 1
        print(f"\nserve: {len(reqs)} requests, {toks} generated tokens, "
              f"{len(ticks)} ticks")
        if lats:
            p = lambda q: lats[min(int(q * len(lats)), len(lats) - 1)]
            print(f"  latency      p50={p(0.5)*1e3:.1f}ms  "
                  f"p99={p(0.99)*1e3:.1f}ms  max={lats[-1]*1e3:.1f}ms")
        print("  finish       "
              + "  ".join(f"{k}:{v}" for k, v in sorted(reasons.items())))
        if summary.get("requests_per_s") is not None:
            print(f"  throughput   {summary['requests_per_s']:.2f} req/s  "
                  f"{summary.get('tokens_per_s', 0):.1f} tok/s  "
                  f"(wall {summary.get('wall_s', 0):.2f}s)")
        if ticks:
            occ = [t.get("active", 0) for t in ticks]
            print(f"  occupancy    mean {sum(occ)/len(occ):.2f} / "
                  f"{max(occ)} max active slots")

    hist = summary.get("staleness_hist")
    if hist:
        print("\naccepted-staleness histogram (rounds): "
              + (" ".join(f"{s}:{int(k)}" for s, k in enumerate(hist) if k)
                 or "-"))

    if bench:
        print(f"\nbench rows ({len(bench)}):")
        for b in bench:
            print(f"  {b['name']:<28} {b['us_per_call']:12.1f} us/call"
                  + (f"  {b['derived']}" if b.get("derived") else ""))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("jsonl", help="metrics JSONL stream (--metrics-out)")
    ap.add_argument("--check", action="store_true",
                    help="validate the stream instead of rendering it; "
                         "nonzero exit on any schema problem")
    args = ap.parse_args(argv)
    records = load(args.jsonl)
    problems = check(args.jsonl, records)
    if args.check:
        if problems:
            for p in problems:
                print(f"report: {p}", file=sys.stderr)
            return 1
        kinds = {}
        for _, rec in records:
            kinds[rec.get("kind")] = kinds.get(rec.get("kind"), 0) + 1
        print(f"report: OK — {len(records)} records "
              + " ".join(f"{k}:{v}" for k, v in sorted(kinds.items())))
        return 0
    if problems:
        for p in problems:
            print(f"report: WARNING: {p}", file=sys.stderr)
    render(args.jsonl, records)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
