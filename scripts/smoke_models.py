"""Dev smoke: every arch (reduced) forward + prefill + decode on CPU."""
import jax
import jax.numpy as jnp

from repro.configs import get_arch, list_arch_ids, reduced
from repro.models import (ModelCtx, forward, init_params, model_specs,
                          init_cache, prefill, decode_step)

key = jax.random.PRNGKey(0)
B, S = 2, 32

for aid in list_arch_ids():
    cfg = reduced(get_arch(aid))
    specs = model_specs(cfg)
    params = init_params(specs, key, cfg.dtype)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens}
    if cfg.n_prefix_embeds:
        batch["prefix_embeds"] = jnp.zeros((B, cfg.n_prefix_embeds, cfg.d_model),
                                           jnp.bfloat16)
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(
            key, (B, S, cfg.d_model)).astype(jnp.bfloat16)
    ctx = ModelCtx(kind="train")
    logits = forward(cfg, params, batch, ctx)
    assert logits.shape == (B, S, cfg.vocab), (aid, logits.shape)
    assert jnp.isfinite(logits.astype(jnp.float32)).all(), aid
    # prefill + decode
    ctx_p = ModelCtx(kind="prefill")
    cache = init_cache(cfg, B, S + 8, enc_len=S if cfg.family == "encdec" else 0)
    lg, cache = prefill(cfg, params, batch, cache, ctx_p)
    assert lg.shape == (B, 1, cfg.vocab), (aid, lg.shape)
    ctx_d = ModelCtx(kind="decode")
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    lg2, cache = decode_step(cfg, params, cache, tok, jnp.int32(S), ctx_d)
    assert lg2.shape == (B, 1, cfg.vocab), (aid, lg2.shape)
    assert jnp.isfinite(lg2.astype(jnp.float32)).all(), aid
    print(f"OK {aid}")
print("all models OK")
