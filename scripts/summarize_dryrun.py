"""Generate results/dryrun_summary.md + the §Roofline table from the per-cell
dry-run JSONs. Pure file-munging (no jax)."""
import json
import sys
from pathlib import Path

sys.path.insert(0, "src")
sys.path.insert(0, ".")


def gib(x):
    return x / 2**30


def main(dirpath="results/dryrun", out="results/dryrun_summary.md"):
    from benchmarks.roofline import roofline_row
    lines = ["# Dry-run + roofline summary", ""]
    for mesh in ("single", "multi"):
        files = sorted(Path(dirpath).glob(f"*__{mesh}.json"))
        if not files:
            continue
        lines += [f"## mesh = {'16x16 (256 chips)' if mesh=='single' else '2x16x16 (512 chips)'}",
                  "",
                  "| arch | shape | step | arg GiB | temp GiB | temp(TPU-adj) | fits16G | dominant | t_comp s | t_mem s | t_coll s |",
                  "|---|---|---|---|---|---|---|---|---|---|---|"]
        n_ok = n_fail = 0
        for f in files:
            r = json.loads(f.read_text())
            if not r.get("ok"):
                n_fail += 1
                lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                             f"FAILED: {r.get('error','')[:60]} | | | | |")
                continue
            n_ok += 1
            try:
                rr = roofline_row(r)
            except Exception:
                rr = None
            for step, v in r["steps"].items():
                m = v["memory"]
                arg = gib(m.get("argument_bytes", 0))
                temp = gib(m.get("temp_bytes", 0))
                adj = gib(m.get("temp_bytes_tpu_adj", m.get("temp_bytes", 0)))
                fits = "✓" if arg + adj <= 16.0 else "OVER"
                if rr and step in ("local", r["shape"].split("_")[0], "prefill",
                                   "decode"):
                    dom, tc, tm, tx = (rr["dominant"], rr["t_compute_s"],
                                       rr["t_memory_s"], rr["t_collective_s"])
                else:
                    dom, tc, tm, tx = "", float("nan"), float("nan"), float("nan")
                lines.append(
                    f"| {r['arch']} | {r['shape']} | {step} | {arg:.2f} | "
                    f"{temp:.2f} | {adj:.2f} | {fits} | {dom} | "
                    f"{tc:.2e} | {tm:.2e} | {tx:.2e} |")
        lines += ["", f"cells ok={n_ok} failed={n_fail}", ""]
    Path(out).write_text("\n".join(lines))
    print(f"wrote {out}")
    print("\n".join(lines[:60]))


if __name__ == "__main__":
    main(*sys.argv[1:])
