"""Minimal dependency-free pytree checkpointing (npz + json treedef).

Saves client states / server state / step for the training loop. With
``shards=1`` (the default) every leaf gathers to host and lands in one
``<path>.npz`` — the legacy format, byte-compatible with older runs. With
``shards=K`` each leaf whose leading axis holds at least K rows is split
row-contiguously (``np.array_split`` bounds) across ``<path>.shard{k}.npz``
files and only one shard's rows are resident on host at a time; leaves too
small to split stay in the base ``<path>.npz``. The ``.json`` sidecar
records the layout, so :func:`load_checkpoint` reassembles either format
transparently — sharded and dense runs resume from each other's files.

:class:`LazyRows` lets a caller hand ``save_checkpoint`` a leaf that
FETCHES row ranges on demand instead of a dense array — the host-spill
bank (``repro.fed.spill``) checkpoints shard-by-shard without ever
materializing the full [N, ...] bank.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class LazyRows:
    """A checkpoint leaf that yields row ranges on demand.

    ``fetch(lo, hi)`` must return the dense rows ``[lo:hi]`` as a numpy
    array; ``shape``/``dtype`` describe the FULL leaf. ``save_checkpoint``
    pulls one shard's range at a time, so peak host memory is one shard,
    not the whole leaf. Opaque to ``jax.tree`` (no registered flattening),
    so it travels through pytrees as a leaf.
    """

    def __init__(self, fetch: Callable[[int, int], np.ndarray],
                 shape: Tuple[int, ...], dtype) -> None:
        self.fetch = fetch
        self.shape = tuple(shape)
        self.dtype = np.dtype(dtype)


def _to_np(x) -> np.ndarray:
    # numpy has no native bfloat16: store as f32 (lossless widening); the
    # loader casts back to the reference dtype.
    if hasattr(x, "dtype") and x.dtype == jnp.bfloat16:
        return np.asarray(x.astype(jnp.float32))
    return np.asarray(x)


def _leaf_shape(x) -> Tuple[int, ...]:
    return x.shape if isinstance(x, LazyRows) else tuple(jnp.shape(x))


def _dense(x) -> np.ndarray:
    if isinstance(x, LazyRows):
        return _to_np(x.fetch(0, x.shape[0]))
    return _to_np(x)


def _rows(x, lo: int, hi: int) -> np.ndarray:
    if isinstance(x, LazyRows):
        return _to_np(x.fetch(lo, hi))
    return _to_np(x)[lo:hi]


def shard_bounds(n: int, shards: int) -> List[Tuple[int, int]]:
    """Row-contiguous (lo, hi) ranges matching ``np.array_split(arange(n),
    shards)``: the first ``n % shards`` shards get one extra row."""
    sizes = [n // shards + (1 if i < n % shards else 0)
             for i in range(shards)]
    off = [0]
    for s in sizes:
        off.append(off[-1] + s)
    return [(off[i], off[i + 1]) for i in range(shards)]


def save_checkpoint(path, tree, step: int = 0, shards: int = 1) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    leaves, treedef = jax.tree.flatten(
        tree, is_leaf=lambda x: isinstance(x, LazyRows))
    names = [f"leaf_{i}" for i in range(len(leaves))]
    if shards <= 1:
        arrays = {nm: _dense(x) for nm, x in zip(names, leaves)}
        np.savez(str(path) + ".npz", **arrays)
        meta = {"step": step, "treedef": str(treedef),
                "n_leaves": len(arrays),
                "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
                "shapes": {k: list(v.shape) for k, v in arrays.items()}}
        Path(str(path) + ".json").write_text(json.dumps(meta))
        return
    # a leaf shards when its leading axis can feed every shard at least
    # one row; everything else (scalars, short vectors, server leaves)
    # stays dense in the base file
    shapes = [_leaf_shape(x) for x in leaves]
    sharded = [i for i, s in enumerate(shapes)
               if len(s) >= 1 and s[0] >= shards]
    sharded_set = set(sharded)
    base = {names[i]: _dense(x) for i, x in enumerate(leaves)
            if i not in sharded_set}
    np.savez(str(path) + ".npz", **base)
    dtypes: Dict[str, str] = {k: str(v.dtype) for k, v in base.items()}
    for k in range(shards):
        arrays = {}
        for i in sharded:
            lo, hi = shard_bounds(shapes[i][0], shards)[k]
            arrays[names[i]] = _rows(leaves[i], lo, hi)
            dtypes[names[i]] = str(arrays[names[i]].dtype)
        np.savez(f"{path}.shard{k}.npz", **arrays)
    meta = {"step": step, "treedef": str(treedef), "n_leaves": len(leaves),
            "dtypes": dtypes,
            "shapes": {names[i]: list(shapes[i])
                       for i in range(len(leaves))},
            "shards": shards, "sharded_leaves": sharded}
    Path(str(path) + ".json").write_text(json.dumps(meta))


def load_checkpoint(path, like_tree) -> Tuple[Any, int]:
    """Restore into the structure of ``like_tree``.

    Validates the leaf count, every leaf's stored shape against the target
    structure, and the stored arrays against the checkpoint's own recorded
    dtype/shape metadata (a mismatch means a corrupt or mixed-up
    .npz/.json pair). All checks raise ``ValueError`` naming the offending
    leaf path — not ``assert``, which vanishes under ``python -O``.
    Handles both the dense single-file layout and the sharded layout
    (``meta["shards"] > 1``) transparently, so sharded and dense runs
    resume from each other's files.
    """
    meta = json.loads(Path(str(path) + ".json").read_text())
    data = dict(np.load(str(path) + ".npz"))
    shards = int(meta.get("shards", 1))
    if shards > 1:
        pieces = [np.load(f"{path}.shard{k}.npz") for k in range(shards)]
        for i in meta.get("sharded_leaves", []):
            name = f"leaf_{i}"
            data[name] = np.concatenate([p[name] for p in pieces], axis=0)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    if len(leaves) != meta["n_leaves"]:
        raise ValueError(
            f"checkpoint {path} holds {meta['n_leaves']} leaves but the "
            f"target structure has {len(leaves)}")
    new = []
    for i, (kp, ref) in enumerate(leaves):
        name = f"leaf_{i}"
        where = jax.tree_util.keystr(kp) or "<root>"
        arr = data[name]
        ref_shape = tuple(jnp.shape(ref))
        if tuple(arr.shape) != ref_shape:
            raise ValueError(
                f"checkpoint {path} leaf {i} at {where}: stored shape "
                f"{tuple(arr.shape)} != expected {ref_shape}")
        want_dtype = meta.get("dtypes", {}).get(name)
        if want_dtype is not None and str(arr.dtype) != want_dtype:
            raise ValueError(
                f"checkpoint {path} leaf {i} at {where}: stored dtype "
                f"{arr.dtype} != recorded metadata {want_dtype} (corrupt "
                f"or mismatched .npz/.json pair)")
        want_shape = meta.get("shapes", {}).get(name)
        if want_shape is not None and tuple(want_shape) != tuple(arr.shape):
            raise ValueError(
                f"checkpoint {path} leaf {i} at {where}: stored shape "
                f"{tuple(arr.shape)} != recorded metadata "
                f"{tuple(want_shape)} (corrupt or mismatched .npz/.json "
                f"pair)")
        new.append(jnp.asarray(arr, dtype=ref.dtype))
    return jax.tree.unflatten(treedef, new), meta["step"]
