"""Minimal dependency-free pytree checkpointing (npz + json treedef).

Saves client states / server state / step for the training loop. Leaves are
gathered to host (fine at the scales this container trains; a production TPU
deployment would swap in per-shard async writes behind the same interface).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _to_np(x) -> np.ndarray:
    # numpy has no native bfloat16: store as f32 (lossless widening); the
    # loader casts back to the reference dtype.
    if hasattr(x, "dtype") and x.dtype == jnp.bfloat16:
        return np.asarray(x.astype(jnp.float32))
    return np.asarray(x)


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    arrays = {f"leaf_{i}": _to_np(x) for i, x in enumerate(leaves)}
    return arrays, treedef


def save_checkpoint(path, tree, step: int = 0) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    arrays, treedef = _flatten(tree)
    np.savez(str(path) + ".npz", **arrays)
    meta = {"step": step, "treedef": str(treedef),
            "n_leaves": len(arrays),
            "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
            "shapes": {k: list(v.shape) for k, v in arrays.items()}}
    Path(str(path) + ".json").write_text(json.dumps(meta))


def load_checkpoint(path, like_tree) -> Tuple[Any, int]:
    """Restore into the structure of ``like_tree``.

    Validates the leaf count, every leaf's stored shape against the target
    structure, and the stored arrays against the checkpoint's own recorded
    dtype/shape metadata (a mismatch means a corrupt or mixed-up
    .npz/.json pair). All checks raise ``ValueError`` naming the offending
    leaf path — not ``assert``, which vanishes under ``python -O``.
    """
    data = np.load(str(path) + ".npz")
    meta = json.loads(Path(str(path) + ".json").read_text())
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    if len(leaves) != meta["n_leaves"]:
        raise ValueError(
            f"checkpoint {path} holds {meta['n_leaves']} leaves but the "
            f"target structure has {len(leaves)}")
    new = []
    for i, (kp, ref) in enumerate(leaves):
        name = f"leaf_{i}"
        where = jax.tree_util.keystr(kp) or "<root>"
        arr = data[name]
        ref_shape = tuple(jnp.shape(ref))
        if tuple(arr.shape) != ref_shape:
            raise ValueError(
                f"checkpoint {path} leaf {i} at {where}: stored shape "
                f"{tuple(arr.shape)} != expected {ref_shape}")
        want_dtype = meta.get("dtypes", {}).get(name)
        if want_dtype is not None and str(arr.dtype) != want_dtype:
            raise ValueError(
                f"checkpoint {path} leaf {i} at {where}: stored dtype "
                f"{arr.dtype} != recorded metadata {want_dtype} (corrupt "
                f"or mismatched .npz/.json pair)")
        want_shape = meta.get("shapes", {}).get(name)
        if want_shape is not None and tuple(want_shape) != tuple(arr.shape):
            raise ValueError(
                f"checkpoint {path} leaf {i} at {where}: stored shape "
                f"{tuple(arr.shape)} != recorded metadata "
                f"{tuple(want_shape)} (corrupt or mismatched .npz/.json "
                f"pair)")
        new.append(jnp.asarray(arr, dtype=ref.dtype))
    return jax.tree.unflatten(treedef, new), meta["step"]
