"""Minimal dependency-free pytree checkpointing (npz + json treedef).

Saves client states / server state / step for the training loop. Leaves are
gathered to host (fine at the scales this container trains; a production TPU
deployment would swap in per-shard async writes behind the same interface).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _to_np(x) -> np.ndarray:
    # numpy has no native bfloat16: store as f32 (lossless widening); the
    # loader casts back to the reference dtype.
    if hasattr(x, "dtype") and x.dtype == jnp.bfloat16:
        return np.asarray(x.astype(jnp.float32))
    return np.asarray(x)


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    arrays = {f"leaf_{i}": _to_np(x) for i, x in enumerate(leaves)}
    return arrays, treedef


def save_checkpoint(path, tree, step: int = 0) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    arrays, treedef = _flatten(tree)
    np.savez(str(path) + ".npz", **arrays)
    meta = {"step": step, "treedef": str(treedef),
            "n_leaves": len(arrays),
            "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
            "shapes": {k: list(v.shape) for k, v in arrays.items()}}
    Path(str(path) + ".json").write_text(json.dumps(meta))


def load_checkpoint(path, like_tree) -> Tuple[Any, int]:
    """Restore into the structure of ``like_tree`` (dtype/shape-checked)."""
    data = np.load(str(path) + ".npz")
    meta = json.loads(Path(str(path) + ".json").read_text())
    leaves, treedef = jax.tree.flatten(like_tree)
    assert len(leaves) == meta["n_leaves"], (len(leaves), meta["n_leaves"])
    new = []
    for i, ref in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        assert tuple(arr.shape) == tuple(ref.shape), (i, arr.shape, ref.shape)
        new.append(jnp.asarray(arr, dtype=ref.dtype))
    return jax.tree.unflatten(treedef, new), meta["step"]
