from repro.configs.base import (
    ArchConfig,
    EncoderConfig,
    FedConfig,
    INPUT_SHAPES,
    MoEConfig,
    PopulationConfig,
    ShapeConfig,
    SSMConfig,
    get_arch,
    get_shape,
    list_arch_ids,
    reduced,
)

__all__ = [
    "ArchConfig", "EncoderConfig", "FedConfig", "INPUT_SHAPES", "MoEConfig",
    "PopulationConfig", "ShapeConfig", "SSMConfig", "get_arch", "get_shape",
    "list_arch_ids", "reduced",
]
