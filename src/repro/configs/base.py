"""Config dataclasses + registry for architectures, input shapes, and federation.

Every assigned architecture gets one module ``src/repro/configs/<id>.py`` (dashes
mapped to underscores) exporting ``CONFIG: ArchConfig``. The registry below resolves
``--arch <id>`` strings.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    # if >0, a shared (always-on) dense ffn of this width runs alongside experts
    d_ff_shared: int = 0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int
    expand: int = 2            # d_inner = expand * d_model
    conv_width: int = 4
    # mamba2 multi-head state layout
    head_dim: int = 64
    version: int = 1           # 1 = mamba1 (falcon-mamba), 2 = mamba2 (zamba2)


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec (whisper) architectures."""
    n_layers: int
    # frontends (conv/mel, ViT) are stubbed: input_specs provides embeddings.


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                # dense | moe | ssm | hybrid | encdec | vlm
    source: str                # citation from the assignment
    n_layers: int
    d_model: int
    n_heads: int               # 0 for attention-free
    n_kv_heads: int
    d_ff: int
    vocab: int
    qkv_bias: bool = False
    head_dim: int = 0          # 0 -> d_model // n_heads
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    # hybrid: apply the shared attention block every `shared_attn_every` layers
    shared_attn_every: int = 0
    # sliding-window attention width used for the long_500k serve variant; dense
    # archs fall back to this window there (see DESIGN.md §5).
    long_context_window: int = 4096
    # multimodal early-fusion stub: number of prefix positions replaced by
    # precomputed patch/frame embeddings ([vlm]/[audio]/llama4 early fusion).
    n_prefix_embeds: int = 0
    # federated placement: "replica" (M = pods*data clients, full per-client copy)
    # or "zero" (M = pods clients, state FSDP over data axis).
    fed_mode: str = "replica"
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        assert self.n_heads > 0
        return self.d_model // self.n_heads

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    def param_count(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d = self.d_model
        n = 0
        n += self.vocab * d            # embed
        n += self.vocab * d + d        # head (untied) + final norm
        attn = mlp = ssm = 0
        if self.n_heads:
            hd = self.resolved_head_dim
            attn = (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                    + self.n_heads * hd * d + 2 * d)
            if self.qkv_bias:
                attn += (self.n_heads + 2 * self.n_kv_heads) * hd
        if self.moe is not None:
            e = self.moe
            mlp = d * e.n_experts + e.n_experts * 3 * d * e.d_ff_expert
            if e.d_ff_shared:
                mlp += 3 * d * e.d_ff_shared
        elif self.d_ff:
            mlp = 3 * d * self.d_ff
        if self.ssm is not None:
            s = self.ssm
            di = s.expand * d
            # in_proj (x,z), conv, dt/B/C projections, out_proj (approx)
            ssm = (2 * d * di + s.conv_width * di
                   + di * s.state_dim * 2 + di + di * d)
        if self.shared_attn_every:
            # hybrid (zamba2-style): SSM per layer + ONE weight-tied attn+mlp block
            n += ssm * self.n_layers + attn + mlp
        elif self.family == "ssm":
            n += ssm * self.n_layers
        else:
            n += (attn + mlp) * self.n_layers
        if self.encoder is not None:
            n += (attn + mlp) * self.encoder.n_layers
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE discounts inactive experts)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        inactive = (e.n_experts - e.top_k) * 3 * self.d_model * e.d_ff_expert
        return self.param_count() - inactive * self.n_layers


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


CODECS = ("none", "int8", "topk")

TOPOLOGIES = ("ring", "torus2d", "complete", "erdos")


def validate_topology(name: str, er_p: float, time_varying: bool) -> None:
    """Shared gossip-topology validation — ``PopulationConfig`` and
    ``repro.fed.topology.GossipAggregator`` both call this, so the two
    construction paths can never drift apart. Raises ``ValueError``."""
    if name not in TOPOLOGIES:
        raise ValueError(f"topology must be one of {TOPOLOGIES}, "
                         f"got {name!r}")
    if not 0.0 <= er_p <= 1.0:
        raise ValueError(f"er_p must be in [0, 1], got {er_p}")
    if time_varying and name != "erdos":
        raise ValueError("time_varying resamples an Erdős–Rényi graph every "
                         "round: the fixed topologies (ring/torus2d/"
                         "complete) are static by definition — set "
                         "topology='erdos'")


def validate_codec(name: str, bits: int, topk_frac: float) -> None:
    """Shared codec validation — ``FedConfig`` and
    ``repro.fed.compress.make_codec`` both call this, so the two
    construction paths can never drift apart. Raises ``ValueError``."""
    if name not in CODECS:
        raise ValueError(f"codec must be one of {CODECS}, got {name!r}")
    if not 2 <= bits <= 8:
        raise ValueError(f"codec_bits must be in [2, 8] (levels are shipped "
                         f"bit-packed, one f32 scale per tensor), "
                         f"got {bits}")
    if not 0.0 < topk_frac <= 1.0:
        raise ValueError(f"topk_frac must be in (0, 1] (1 = keep every "
                         f"entry), got {topk_frac}")


@dataclasses.dataclass(frozen=True)
class FedConfig:
    """AdaFBiO hyper-parameters (Algorithm 1)."""
    q: int = 8                  # local steps between syncs
    neumann_k: int = 8          # K in Eq. (15)
    lr_x: float = 1e-3          # gamma
    lr_y: float = 1e-2          # lambda
    eta: float = 0.5            # eta_t (momentum interpolation); schedule in core
    alpha_c1: float = 4.0       # alpha_{t+1} = c1 * eta_t^2
    beta_c2: float = 4.0        # beta_{t+1}  = c2 * eta_t^2
    rho: float = 1e-4           # adaptive-matrix regularizer
    varrho: float = 0.9         # EMA for adaptive matrices
    nu: float = 1e-3            # LL strong-convexity regularizer
    theta: float = 1.0          # Neumann step (vartheta in paper, <= 1/L_g)
    adaptive: str = "adam"      # adam | adabelief | none
    eta_k: float = 1.0          # k in eta_t = k M^{1/3} / (n+t)^{1/3}
    eta_n: float = 64.0         # n in the eta_t schedule
    # UL (f) batch and Neumann batch sizes as fractions of the LL batch
    ul_batch_frac: float = 0.125
    neumann_batch: int = 1
    # gradient-accumulation bound: sequences per microbatch per data shard
    microbatch_per_shard: int = 1
    # fused flat-buffer update path (STORM refresh + Eq. 14) — "auto" uses the
    # Pallas kernels on TPU and the per-leaf jnp path elsewhere; "on" forces
    # the flat-buffer path (jnp reference math off-TPU); "off" disables it.
    fused: str = "auto"
    # ---- communication compression (repro.fed.compress) ----
    # client→server update codec: "none" (full-precision, bit-identical to
    # the uncompressed path), "int8" (stochastic uniform quantization to
    # codec_bits-bit levels, Pallas-fused on TPU), "topk" (magnitude
    # sparsification keeping a topk_frac fraction of each tensor)
    codec: str = "none"
    # int8 codec: quantization bit width b; levels span [-(2^(b-1)-1),
    # 2^(b-1)-1], shipped bit-packed with one f32 scale per tensor
    codec_bits: int = 8
    # topk codec: fraction of each tensor's entries transmitted (1.0 keeps
    # everything — matches codec="none" up to float rounding)
    topk_frac: float = 0.1
    # error feedback: keep the per-client compression residual and fold it
    # into the next transmission (EF-SGD; lossy codecs only)
    error_feedback: bool = True

    def __post_init__(self):
        validate_codec(self.codec, self.codec_bits, self.topk_frac)


DELAY_MODELS = ("uniform", "tiers", "lognormal", "trace")


def validate_delay_model(name: str, max_delay: int, tier_fracs, tier_delays,
                         delay_sigma: float) -> None:
    """Shared delay-model validation — ``PopulationConfig`` and
    ``repro.fed.population.make_delay_model`` both call this, so the two
    construction paths can never drift apart. Raises ``ValueError``."""
    if name not in DELAY_MODELS:
        raise ValueError(f"delay_model must be one of {DELAY_MODELS}, "
                         f"got {name!r}")
    if max_delay < 1:
        raise ValueError(f"max_delay must be >= 1 round, got {max_delay}")
    if name == "tiers":
        if len(tier_fracs) != len(tier_delays) or not tier_fracs:
            raise ValueError(
                f"tiers need matching non-empty tier_fracs/tier_delays, "
                f"got {len(tier_fracs)} fracs, {len(tier_delays)} delay "
                f"ranges")
        if (any(f <= 0 for f in tier_fracs)
                or abs(sum(tier_fracs) - 1.0) > 1e-6):
            raise ValueError(f"tier_fracs must be positive and sum to 1, "
                             f"got {tier_fracs}")
        if any(not 1 <= lo <= hi for lo, hi in tier_delays):
            raise ValueError(f"each tier delay range needs 1 <= lo <= hi "
                             f"rounds, got {tier_delays}")
    if name == "lognormal":
        if delay_sigma < 0:
            raise ValueError(f"delay_sigma must be >= 0, got {delay_sigma}")
        if max_delay < 2:
            raise ValueError(
                "lognormal delays are clipped to [1, max_delay]: "
                "max_delay=1 makes every delay 1 (the degenerate "
                "no-heterogeneity case) — set max_delay >= 2")


@dataclasses.dataclass(frozen=True)
class PopulationConfig:
    """Client population ≫ per-round cohort (repro.fed.population).

    ``n`` persistent client states live in a bank; each round a sampler
    picks a ``cohort`` of C clients, and only those C are computed (gather →
    fused scan round → scatter), so per-round compute is O(C) not O(n).
    """
    n: int                          # population size N
    cohort: int                     # per-round compute cohort C
    sampler: str = "uniform"        # uniform | roundrobin | trace | trace-file
    sync_mode: str = "broadcast"    # broadcast | participants (fed.population)
    # staleness-aware aggregation: weight ∝ (1 + rounds_since_sync)^-decay;
    # 0 = plain uniform cohort average (only meaningful with participants sync)
    staleness_decay: float = 0.0
    # availability-trace sampler schedule (sampler == "trace")
    trace_period: int = 8
    trace_duty: float = 0.5
    # recorded-trace replay (sampler == "trace-file"): JSONL of per-client
    # up intervals, see docs/async.md for the format spec
    trace_file: Optional[str] = None
    # ---- asynchronous execution (fed.population.make_async_round) ----
    # 0 = synchronous rounds (today's path, bit-identical); > 0 enables
    # async execution and drops arriving updates staler than this many
    # rounds (float("inf") = async with no gating)
    max_staleness: float = 0.0
    # per-dispatch return delay is uniform over [1, max_delay] rounds;
    # > 1 makes cohorts genuinely overlap (a client can be sampled while
    # still in flight)
    max_delay: int = 1
    # delay-adaptive server step à la Jiao et al. (arXiv:2212.10048):
    # the model movement scales by 1 / (1 + delay_eta * (mean_tau - 1));
    # 0 disables
    delay_eta: float = 0.0
    # ---- heterogeneous per-client delay model (fed.population.DelayModel):
    #   uniform   — delay ~ U[1, max_delay] per dispatch (the default;
    #               bit-identical to the plain async path)
    #   tiers     — each client permanently assigned to a speed tier
    #               (tier_fracs) with per-tier delay ranges (tier_delays)
    #   lognormal — permanent per-client latency exp(delay_mu +
    #               delay_sigma * z_i) quantized to rounds, clipped to
    #               [1, max_delay]
    #   trace     — per-round delays replayed from trace_file's optional
    #               per-client "delay" field (docs/async.md)
    delay_model: str = "uniform"
    # tiers model: population fraction per tier (largest-remainder split)
    # and the [lo, hi] per-dispatch delay range of each tier, default
    # 20/60/20 fast/medium/straggler
    tier_fracs: Tuple[float, ...] = (0.2, 0.6, 0.2)
    tier_delays: Tuple[Tuple[int, int], ...] = ((1, 1), (2, 4), (4, 8))
    # lognormal model: log-latency location/scale (in rounds)
    delay_mu: float = 0.0
    delay_sigma: float = 0.5
    # ---- gossip engine (repro.fed.topology) ----
    # mixing topology of the decentralized rounds; the star engines ignore
    # these. "erdos" draws a seeded static graph (ring backbone unioned in
    # for connectivity) unless time_varying resamples it every round.
    topology: str = "ring"
    er_p: float = 0.4               # Erdős–Rényi edge probability
    time_varying: bool = False      # resample the graph each round (erdos)
    topology_seed: int = 0

    def __post_init__(self):
        if not 1 <= self.cohort <= self.n:
            raise ValueError(f"need 1 <= cohort <= n, got cohort="
                             f"{self.cohort}, n={self.n}")
        if self.sync_mode not in ("broadcast", "participants"):
            raise ValueError(f"sync_mode must be 'broadcast' or "
                             f"'participants', got {self.sync_mode!r}")
        if self.sampler not in ("uniform", "roundrobin", "trace",
                                "trace-file"):
            raise ValueError(f"sampler must be one of uniform/roundrobin/"
                             f"trace/trace-file, got {self.sampler!r}")
        if self.sampler == "trace-file" and not self.trace_file:
            raise ValueError("sampler='trace-file' needs trace_file=<path>")
        if self.max_staleness < 0:
            raise ValueError(f"max_staleness must be >= 0 (0 = synchronous),"
                             f" got {self.max_staleness}")
        if self.max_delay < 1:
            raise ValueError(f"max_delay must be >= 1 round, "
                             f"got {self.max_delay}")
        if self.delay_eta < 0:
            raise ValueError(f"delay_eta must be >= 0, got {self.delay_eta}")
        validate_delay_model(self.delay_model, self.max_delay,
                             self.tier_fracs, self.tier_delays,
                             self.delay_sigma)
        validate_topology(self.topology, self.er_p, self.time_varying)
        if self.delay_model == "trace" and not self.trace_file:
            raise ValueError("delay_model='trace' replays the trace_file's "
                             "per-client 'delay' field: set "
                             "trace_file=<path> (format: docs/async.md)")
        if self.max_staleness == 0 and (self.max_delay > 1
                                        or self.delay_eta > 0
                                        or self.delay_model != "uniform"):
            raise ValueError("max_delay > 1 / delay_eta > 0 / a non-uniform"
                             " delay_model are async knobs: set "
                             "max_staleness > 0 (or float('inf')) to "
                             "enable asynchronous execution")

    @property
    def asynchronous(self) -> bool:
        """True when rounds run the async path (overlapping cohorts,
        delayed arrivals, bounded-staleness gating)."""
        return self.max_staleness != 0


_ARCH_IDS = [
    "whisper-tiny",
    "zamba2-1.2b",
    "qwen2.5-14b",
    "internvl2-76b",
    "qwen3-moe-30b-a3b",
    "falcon-mamba-7b",
    "deepseek-67b",
    "granite-20b",
    "llama4-scout-17b-a16e",
    "qwen1.5-4b",
]


def _module_name(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "p")


def list_arch_ids() -> Tuple[str, ...]:
    return tuple(_ARCH_IDS)


def get_arch(arch_id: str) -> ArchConfig:
    if arch_id not in _ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {_ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_module_name(arch_id)}")
    return mod.CONFIG


def get_shape(shape_id: str) -> ShapeConfig:
    return INPUT_SHAPES[shape_id]


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A smoke-test-sized variant of the same family (<=2 layers, d_model<=512)."""
    d = min(cfg.d_model, 256)
    heads = min(cfg.n_heads, 4) if cfg.n_heads else 0
    kv = min(cfg.n_kv_heads, heads) if heads else 0
    if heads and cfg.n_kv_heads == cfg.n_heads:
        kv = heads                           # keep MHA archs MHA
    if heads and cfg.n_kv_heads == 1:
        kv = 1                               # keep MQA archs MQA
    changes = dict(
        n_layers=2,
        d_model=d,
        n_heads=heads,
        n_kv_heads=kv,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab=min(cfg.vocab, 512),
        head_dim=(d // heads if heads else 0),
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=min(cfg.moe.d_ff_expert, 128),
            d_ff_shared=min(cfg.moe.d_ff_shared, 128) if cfg.moe.d_ff_shared else 0)
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(
            cfg.ssm, state_dim=min(cfg.ssm.state_dim, 16),
            head_dim=min(cfg.ssm.head_dim, 32))
    if cfg.encoder is not None:
        changes["encoder"] = EncoderConfig(n_layers=2)
    if cfg.shared_attn_every:
        changes["shared_attn_every"] = 2
    if cfg.n_prefix_embeds:
        changes["n_prefix_embeds"] = 8
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)
