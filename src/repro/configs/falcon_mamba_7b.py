"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attention-free) vocab=65024,
ssm_state=16 — Mamba1 architecture. [arXiv:2410.05355]
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    source="arXiv:2410.05355",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=65024,
    ssm=SSMConfig(state_dim=16, expand=2, version=1),
    fed_mode="replica",
)
