"""granite-20b [dense]: 52L d_model=6144 48H (GQA kv=1, MQA) d_ff=24576 vocab=49152 —
llama-arch, code model. [arXiv:2405.04324]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b",
    family="dense",
    source="arXiv:2405.04324",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab=49152,
    fed_mode="zero",          # 28-30B + STORM + adaptive state: client = pod,
)
