"""internvl2-76b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256 —
InternViT + InternLM2. The vision encoder + projector is STUBBED (early-fusion
patch embeddings via input_specs); this config is the language backbone.
[arXiv:2404.16821]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    source="arXiv:2404.16821",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    n_prefix_embeds=256,
    fed_mode="zero",            # 76B: client = pod, FSDP over data axis
)
