"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E]
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=0,
    vocab=202048,
    moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192, d_ff_shared=8192),
    n_prefix_embeds=256,        # early-fusion multimodal stub
    fed_mode="zero",            # 107B total params: client = pod, FSDP over data
)
