"""Configs for the paper's own experiments (Section 6).

1. Federated hyper-representation learning (Problem (3)): an MLP/transformer
   backbone ``x`` shared across clients, per-client linear head ``y^m``.
2. Federated data hyper-cleaning (Problem (4)): per-sample weights ``x``
   (UL variable), a linear classifier ``y`` (LL variable) trained on weighted,
   label-corrupted client data.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import FedConfig


@dataclasses.dataclass(frozen=True)
class HyperRepConfig:
    n_clients: int = 8
    in_dim: int = 32
    hidden: int = 64
    rep_dim: int = 32
    n_classes: int = 10
    batch: int = 32
    fed: FedConfig = dataclasses.field(default_factory=lambda: FedConfig(
        q=8, neumann_k=4, lr_x=0.01, lr_y=0.1, nu=1e-3))


@dataclasses.dataclass(frozen=True)
class HyperCleanConfig:
    n_clients: int = 8
    n_train_per_client: int = 128     # dim(x^m) = per-sample weights
    n_val_per_client: int = 64
    feat_dim: int = 32
    n_classes: int = 10
    corrupt_frac: float = 0.3
    nu: float = 1e-2                  # LL l2 regulariser (strong convexity)
    batch: int = 32
    fed: FedConfig = dataclasses.field(default_factory=lambda: FedConfig(
        q=8, neumann_k=4, lr_x=0.05, lr_y=0.1, nu=1e-2))
