"""qwen2.5-14b [dense]: 48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064 —
GQA with QKV bias. [hf:Qwen/Qwen2.5-0.5B]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-14b",
    family="dense",
    source="hf:Qwen/Qwen2.5-0.5B",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab=152064,
    qkv_bias=True,
    fed_mode="zero",  # 14.8B x (params+STORM+adaptive) exceeds a 16-client replica
)
