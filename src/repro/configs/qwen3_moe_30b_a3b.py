"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) d_ff=768 vocab=151936,
MoE 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B]
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=0,                     # all-MoE MLPs
    vocab=151936,
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=768),
    fed_mode="zero",          # 28-30B + STORM + adaptive state: client = pod,
)
