"""whisper-tiny [audio]: 4L d_model=384 6H (GQA kv=6) d_ff=1536 vocab=51865.

Encoder-decoder transformer backbone; the mel-spectrogram + conv feature extractor
frontend is STUBBED — ``input_specs`` provides precomputed frame embeddings.
[arXiv:2212.04356]
"""
from repro.configs.base import ArchConfig, EncoderConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="encdec",
    source="arXiv:2212.04356",
    n_layers=4,                 # decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    encoder=EncoderConfig(n_layers=4),
    fed_mode="replica",
)
