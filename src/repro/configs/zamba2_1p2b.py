"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000,
ssm_state=64 — Mamba2 layers + a weight-tied shared attention block. [arXiv:2411.15242]
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    source="arXiv:2411.15242",
    n_layers=38,
    d_model=2048,
    n_heads=32,                 # shared attention block
    n_kv_heads=32,
    d_ff=8192,                  # shared attention block MLP
    vocab=32000,
    ssm=SSMConfig(state_dim=64, expand=2, head_dim=64, version=2),
    shared_attn_every=6,
    fed_mode="replica",
)
