"""The paper's primary contribution: adaptive federated bilevel optimization
(AdaFBiO) — bilevel problem abstraction, stochastic Neumann hypergradient,
STORM variance reduction, unified adaptive matrices, Algorithm 1 steps, and
the Table-1 baselines."""
from repro.core.bilevel import (BilevelProblem, lm_bilevel_problem,
                                quadratic_bilevel_problem, quadratic_true_grad,
                                softmax_xent)
from repro.core.hypergrad import hypergrad_factored, hypergrad_fn
from repro.core import adafbio, adaptive, baselines, tree_util
# NOTE: the bare `hypergrad` function is intentionally NOT re-exported here —
# it would shadow the `repro.core.hypergrad` submodule attribute.

__all__ = [
    "BilevelProblem", "lm_bilevel_problem", "quadratic_bilevel_problem",
    "quadratic_true_grad", "softmax_xent", "hypergrad_factored",
    "hypergrad_fn", "adafbio", "adaptive", "baselines", "tree_util",
]
