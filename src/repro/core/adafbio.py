"""AdaFBiO — Algorithm 1 of the paper, as pure per-client/server step
functions.

What this module owns: the paper's per-iteration math — the eta_t /
alpha / beta schedules (§4), the STORM variance-reduced estimator refreshes
(Eqs. 10-11), the adaptive-matrix local update (Eq. 14), and the sync-step
server update with adaptive regeneration (Eqs. 8-9, lines 4-9). How it
composes with its neighbours: hypergradient estimates come from
``repro.core.hypergrad`` (Eq. 15 Neumann series); adaptive matrices from
``repro.core.adaptive``; the fused flat-buffer kernels from
``repro.kernels.ops`` (selected by ``FedConfig.fused``). Everything here is
one-client math: the federated structure — the leading M client axis,
rounds, cohorts, meshes — is added by ``repro.fed.runtime`` /
``repro.fed.round`` / ``repro.fed.population``, which consume these
functions through the ``Algorithm`` contract in ``repro.core.baselines``.

State:
  ClientState = {"x", "y", "v", "w"}       (per client m; leading M axis added
                                            by the federated runtime)
  ServerState = {"adaptive": {...}, "t": int32}  (replicated)

One iteration t:
  * if t % q != 0 (local step, lines 10-14 + 16-20):
      x̂ = x − γ A⁻¹ w ; x⁺ = x + η_t (x̂ − x)      (== x − γ η_t A⁻¹ w, Eq. 14)
      ŷ = y − λ B⁻¹ v ; y⁺ = y + η_t (ŷ − y)
      draw ζ, ξ̄;  STORM refresh (Eqs. 10-11) with grads at (new, old) params
  * if t % q == 0 (sync, lines 4-9): the runtime averages states across
    clients, calls ``sync_update`` (adaptive regeneration + one server update),
    and broadcasts.

The paper's schedules: η_t = k·M^{1/3}/(n+t)^{1/3}, α_{t+1} = c1 η_t²,
β_{t+1} = c2 η_t² (both clipped to (0, 1]).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core import adaptive as ada
from repro.core.bilevel import BilevelProblem
from repro.core.hypergrad import hypergrad_fn
from repro.core.tree_util import (tree_axpy, tree_barrier, tree_match_dtypes,
                                  tree_scale, tree_sub, tree_update,
                                  tree_zeros_like)


# ------------------------------------------------------------------ schedules

def eta_t(fed: FedConfig, t, m: int):
    return fed.eta_k * (m ** (1 / 3)) / (fed.eta_n + t.astype(jnp.float32)) ** (1 / 3)


def alpha_beta(fed: FedConfig, eta):
    a = jnp.clip(fed.alpha_c1 * eta ** 2, 0.0, 1.0)
    b = jnp.clip(fed.beta_c2 * eta ** 2, 0.0, 1.0)
    return a, b


# ------------------------------------------------------------------ init

def init_client_state(problem: BilevelProblem, fed: FedConfig, xp, yp,
                      batches, key) -> Dict[str, Any]:
    """Line 2: initial estimators from a (mini-batched) sample."""
    hg = hypergrad_fn(problem, fed.neumann_k, fed.theta)
    grad_g_y = problem.grad_g_y or (
        lambda xx, yy, bb: jax.grad(problem.g, argnums=1)(xx, yy, bb))
    v = grad_g_y(xp, yp, batches.get("g", batches["g0"]))
    w = hg(xp, yp, batches, key)
    return {"x": xp, "y": yp, "v": v, "w": w}


def init_server_state(x_like, fed: FedConfig) -> Dict[str, Any]:
    return {"adaptive": ada.init_adaptive_state(x_like, fed.adaptive),
            "t": jnp.int32(0)}


def warm_adaptive(server: Dict[str, Any], avg_state: Dict[str, Any],
                  fed: FedConfig) -> Dict[str, Any]:
    """Line 2 of Algorithm 1: generate A_1, B_1 from the initial averaged
    estimators (an a=0 start would make the first local phase take
    lr/ρ-scale steps)."""
    new = dict(server)
    new["adaptive"] = ada.update_adaptive(
        server["adaptive"], avg_state["w"], avg_state["v"],
        kind=fed.adaptive, varrho=0.0)
    return new


# ------------------------------------------------------------------ steps

def use_fused(fed: FedConfig) -> bool:
    """Whether the flat-buffer fused update path is active for this config."""
    mode = getattr(fed, "fused", "auto")
    if mode == "on":
        return True
    if mode == "off":
        return False
    return jax.default_backend() == "tpu"


def param_update(fed: FedConfig, adaptive_state, x, y, v, w, eta):
    """Eqs. (12)-(14): adaptive-preconditioned interpolated update."""
    if use_fused(fed) and fed.adaptive != "none":
        from repro.kernels import ops
        acc = (adaptive_state["a_max"] if fed.adaptive == "amsgrad"
               else adaptive_state["a"])
        x_new = ops.adafbio_update_tree(x, w, acc, fed.lr_x * eta, fed.rho)
    else:
        dx = ada.precondition_x(adaptive_state, w, kind=fed.adaptive,
                                rho=fed.rho)
        x_new = tree_update(x, dx, fed.lr_x * eta)
    # B_t is scalar (b·I): the y update is one cheap broadcast either way
    dy = ada.precondition_y(adaptive_state, v, kind=fed.adaptive, rho=fed.rho)
    y_new = tree_update(y, dy, fed.lr_y * eta)
    return x_new, y_new


def storm_refresh(problem: BilevelProblem, fed: FedConfig, state, x_new, y_new,
                  batches, key, alpha, beta):
    """Eqs. (10)-(11): same-sample gradients at new and old params."""
    hg = hypergrad_fn(problem, fed.neumann_k, fed.theta)
    k1, k2 = jax.random.split(key)
    bg = batches.get("g", batches["g0"])        # ζ_{t+1}: the LL minibatch
    grad_g_y = problem.grad_g_y or (
        lambda xx, yy, bb: jax.grad(problem.g, argnums=1)(xx, yy, bb))
    g_new = grad_g_y(x_new, y_new, bg)
    # sequence the (new, old) evaluations so peak memory is max(), not sum();
    # tree_barrier (not lax.optimization_barrier directly) so client-vmapped
    # steps batch on jax 0.4.x, which lacks the primitive's batching rule
    x_old, y_old = tree_barrier((state["x"], state["y"], g_new))[:2]
    g_old = grad_g_y(x_old, y_old, bg)
    fused = use_fused(fed)
    if fused:
        from repro.kernels import ops
        v_new = ops.storm_update_tree(g_new, g_old, state["v"], alpha)
    else:
        v_new = tree_axpy(1.0 - alpha, tree_sub(state["v"], g_old), g_new)
    w_hat_new = hg(x_new, y_new, batches, k1)
    x_old2, y_old2 = tree_barrier((state["x"], state["y"], w_hat_new))[:2]
    w_hat_old = hg(x_old2, y_old2, batches, k1)   # same sample & same k
    if fused:
        from repro.kernels import ops
        w_new = ops.storm_update_tree(w_hat_new, w_hat_old, state["w"], beta)
    else:
        w_new = tree_axpy(1.0 - beta, tree_sub(state["w"], w_hat_old),
                          w_hat_new)
    v_new = tree_match_dtypes(v_new, state["v"])
    w_new = tree_match_dtypes(w_new, state["w"])
    if problem.constrain_x is not None:
        w_new = problem.constrain_x(w_new)
    if problem.constrain_y is not None:
        v_new = problem.constrain_y(v_new)
    return v_new, w_new


def local_step(problem: BilevelProblem, fed: FedConfig, state: Dict[str, Any],
               adaptive_state, batches, key, t, m: int) -> Dict[str, Any]:
    """One asynchronous (no cross-client communication) iteration per client."""
    eta = eta_t(fed, t, m)
    alpha, beta = alpha_beta(fed, eta)
    x_new, y_new = param_update(fed, adaptive_state, state["x"], state["y"],
                                state["v"], state["w"], eta)
    v_new, w_new = storm_refresh(problem, fed, state, x_new, y_new, batches,
                                 key, alpha, beta)
    return {"x": x_new, "y": y_new, "v": v_new, "w": w_new}


def sync_update(fed: FedConfig, server: Dict[str, Any],
                avg_state: Dict[str, Any], m: int) -> Tuple[Dict, Dict]:
    """Server part of the sync step (lines 5-8): regenerate (A_t, B_t) from the
    averaged estimators, then one preconditioned update on the averaged params.
    Returns (new broadcastable client state, new server state).
    """
    t = server["t"]
    adaptive_state = ada.update_adaptive(
        server["adaptive"], avg_state["w"], avg_state["v"],
        kind=fed.adaptive, varrho=fed.varrho)
    eta = eta_t(fed, t, m)
    x_new, y_new = param_update(fed, adaptive_state, avg_state["x"],
                                avg_state["y"], avg_state["v"], avg_state["w"],
                                eta)
    new_client = {"x": x_new, "y": y_new, "v": avg_state["v"],
                  "w": avg_state["w"]}
    new_server = {"adaptive": adaptive_state, "t": t + 1}
    return new_client, new_server
