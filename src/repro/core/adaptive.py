"""Unified adaptive matrices (paper Alg. 1 line 6, Eqs. (8)-(9), Assumption 6).

The server generates, at every sync step, a diagonal matrix A_t for the UL
variable x and a scalar matrix B_t = b_t·I for the LL variable y, from the
*averaged* estimators (w̄, v̄). All clients then share (A_t, B_t) for the next
q local steps. Variants:

  adam      : a_t = ϱ a + (1−ϱ) w̄²,          A = diag(√a + ρ)       (line 6)
  adabelief : a_t = ϱ a + (1−ϱ)(w̄ − w̄_prev)², A = diag(√a + ρ)     (Eq. 8)
  amsgrad   : adam's a_t but A uses the running MAX (monotone precond.) —
              the paper's framework admits any A_t ⪰ ρI; this instantiates
              the local-AMSGrad-style choice referenced in Remark 3
  adagrad   : a_t = a + w̄² (no EMA),          A = diag(√a + ρ)
  none      : A = I, B = I                                      (Theorem 2)

B_t: b_t = ϱ b + (1−ϱ)‖v̄‖ (line 6) / ‖v̄ − v̄_prev‖ (Eq. 9). A_t ⪰ ρI and
ρ ≤ b_t ≤ b̂ hold by construction (Assumption 6).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.tree_util import tree_norm, tree_zeros_like


def init_adaptive_state(x_like, kind: str) -> Dict[str, Any]:
    """``a`` inherits each param's dtype (bf16 accumulators at LLM scale keep
    per-device state affordable; the paper-validation experiments use f32
    params and therefore f32 accumulators — see DESIGN.md memory plan)."""
    st = {"b": jnp.float32(0.0)}
    if kind != "none":
        st["a"] = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), x_like)
    if kind == "adabelief":
        st["w_prev"] = tree_zeros_like(st["a"])
        st["v_norm_prev"] = jnp.float32(0.0)
    if kind == "amsgrad":
        st["a_max"] = tree_zeros_like(st["a"])
    return st


def update_adaptive(state: Dict[str, Any], w_bar, v_bar, *, kind: str,
                    varrho: float, b_max: float = 1e3) -> Dict[str, Any]:
    """Server-side regeneration at a sync step."""
    new = dict(state)
    vn = tree_norm(v_bar)
    if kind == "adam":
        new["a"] = jax.tree.map(
            lambda a, w: (varrho * a.astype(jnp.float32)
                          + (1 - varrho) * w.astype(jnp.float32) ** 2
                          ).astype(a.dtype),
            state["a"], w_bar)
        new["b"] = jnp.minimum(varrho * state["b"] + (1 - varrho) * vn, b_max)
    elif kind == "adabelief":
        new["a"] = jax.tree.map(
            lambda a, w, wp: (varrho * a.astype(jnp.float32)
                              + (1 - varrho) * (w.astype(jnp.float32)
                                                - wp.astype(jnp.float32)) ** 2
                              ).astype(a.dtype),
            state["a"], w_bar, state["w_prev"])
        new["b"] = jnp.minimum(
            varrho * state["b"]
            + (1 - varrho) * jnp.abs(vn - state["v_norm_prev"]), b_max)
        new["w_prev"] = jax.tree.map(
            lambda w, wp: w.astype(wp.dtype), w_bar, state["w_prev"])
        new["v_norm_prev"] = vn
    elif kind == "amsgrad":
        new["a"] = jax.tree.map(
            lambda a, w: (varrho * a.astype(jnp.float32)
                          + (1 - varrho) * w.astype(jnp.float32) ** 2
                          ).astype(a.dtype),
            state["a"], w_bar)
        new["a_max"] = jax.tree.map(jnp.maximum, state["a_max"], new["a"])
        new["b"] = jnp.minimum(varrho * state["b"] + (1 - varrho) * vn, b_max)
    elif kind == "adagrad":
        new["a"] = jax.tree.map(
            lambda a, w: (a.astype(jnp.float32)
                          + w.astype(jnp.float32) ** 2).astype(a.dtype),
            state["a"], w_bar)
        new["b"] = jnp.minimum(state["b"] + vn, b_max)
    elif kind == "none":
        new["b"] = jnp.float32(1.0)
    else:
        raise ValueError(kind)
    return new


def precondition_x(state, w, *, kind: str, rho: float):
    """A_t^{-1} w (diagonal)."""
    if kind == "none":
        return w
    acc = state["a_max"] if kind == "amsgrad" else state["a"]
    return jax.tree.map(
        lambda wi, a: (wi.astype(jnp.float32)
                       / (jnp.sqrt(a.astype(jnp.float32)) + rho)).astype(wi.dtype),
        w, acc)


def precondition_y(state, v, *, kind: str, rho: float):
    """B_t^{-1} v = v / (b_t + ρ)."""
    if kind == "none":
        return v
    scale = 1.0 / (state["b"] + rho)
    return jax.tree.map(lambda vi: (vi * scale).astype(vi.dtype), v)
