"""Baselines from Table 1 (+ the single-level adaptive-FL comparison).

All baselines reuse the same substrate (hypergradient, client/server runtime)
with the knobs that define them, so benchmark comparisons isolate the paper's
contributions:

  fednest      — Tarzanagh et al. 2022: no variance reduction (α=β=1 i.e. plain
                 SGD estimators), no adaptivity; inner loop refreshes y several
                 times per x step. Õ(ε⁻⁴)/Õ(ε⁻⁴).
  fedbioacc    — Li et al. 2022a: STORM-VR local bilevel, no adaptive LR.
                 Õ(ε⁻³)/Õ(ε⁻²). == AdaFBiO with adaptive="none".
  localbsgvrm  — Gao 2022: momentum-VR local bilevel, no adaptive LR; same
                 complexity class. Implemented with a single momentum on the
                 hypergradient rather than full STORM.
  fedavg_sgd   — FedAvg on the bilevel estimators with no VR and no adaptivity.
  adafbio_na   — Theorem 2 ablation: AdaFBiO with A=I, B=I.

Each baseline exposes the same (local_step, sync_update) contract as
``repro.core.adafbio`` so the federated runtime is algorithm-agnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core import adafbio, adaptive as ada
from repro.core.bilevel import BilevelProblem
from repro.core.hypergrad import hypergrad_fn
from repro.core.tree_util import tree_axpy, tree_sub, tree_update


@dataclasses.dataclass(frozen=True)
class Algorithm:
    name: str
    fed: FedConfig
    local_step: Callable[..., Dict[str, Any]]
    sync_update: Callable[..., Tuple[Dict, Dict]]
    init_client_state: Callable[..., Dict[str, Any]]
    init_server_state: Callable[..., Dict[str, Any]]


def make_adafbio(fed: FedConfig, problem: BilevelProblem,
                 name: str = "adafbio") -> Algorithm:
    return Algorithm(
        name=name,
        fed=fed,
        local_step=lambda st, ad, b, k, t, m: adafbio.local_step(
            problem, fed, st, ad, b, k, t, m),
        sync_update=lambda srv, avg, m: adafbio.sync_update(fed, srv, avg, m),
        init_client_state=lambda xp, yp, b, k: adafbio.init_client_state(
            problem, fed, xp, yp, b, k),
        init_server_state=lambda x_like: adafbio.init_server_state(x_like, fed),
    )


def make_adafbio_nonadaptive(fed: FedConfig, problem: BilevelProblem) -> Algorithm:
    fed_na = dataclasses.replace(fed, adaptive="none")
    alg = make_adafbio(fed_na, problem, name="adafbio_na")
    return alg


def make_fedavg_sgd(fed: FedConfig, problem: BilevelProblem) -> Algorithm:
    """No VR: v, w are fresh stochastic (hyper)gradients each step (α=β=1)."""
    fed_sgd = dataclasses.replace(fed, adaptive="none",
                                  alpha_c1=1e9, beta_c2=1e9)  # clip -> 1
    return make_adafbio(fed_sgd, problem, name="fedavg_sgd")


def make_fednest(fed: FedConfig, problem: BilevelProblem,
                 inner_steps: int = 2) -> Algorithm:
    """FedNest-style: per local step, ``inner_steps`` plain SGD updates on y,
    then one SGD hypergradient step on x. No VR, no adaptivity."""
    fed_b = dataclasses.replace(fed, adaptive="none")
    hg = hypergrad_fn(problem, fed.neumann_k, fed.theta)

    def local_step(state, adaptive_state, batches, key, t, m):
        del adaptive_state
        eta = adafbio.eta_t(fed_b, t, m)
        x, y = state["x"], state["y"]
        for _ in range(inner_steps):
            gy = jax.grad(problem.g, argnums=1)(x, y, batches.get("g", batches["g0"]))
            y = tree_update(y, gy, fed_b.lr_y * eta)
        w = hg(x, y, batches, key)
        x = tree_update(x, w, fed_b.lr_x * eta)
        return {"x": x, "y": y, "v": state["v"], "w": w}

    def sync_update(server, avg_state, m):
        t = server["t"]
        new_client = {"x": avg_state["x"], "y": avg_state["y"],
                      "v": avg_state["v"], "w": avg_state["w"]}
        return new_client, {"adaptive": server["adaptive"], "t": t + 1}

    def init_client(xp, yp, batches, key):
        return adafbio.init_client_state(problem, fed_b, xp, yp, batches, key)

    return Algorithm("fednest", fed_b, local_step, sync_update, init_client,
                     lambda x_like: adafbio.init_server_state(x_like, fed_b))


def make_localbsgvrm(fed: FedConfig, problem: BilevelProblem,
                     momentum: float = 0.5) -> Algorithm:
    """Gao-2022-style: heavy-ball momentum-VR on the hypergradient, plain SGD
    on the LL, local steps + averaging; no adaptivity."""
    fed_b = dataclasses.replace(fed, adaptive="none")
    hg = hypergrad_fn(problem, fed.neumann_k, fed.theta)

    def local_step(state, adaptive_state, batches, key, t, m):
        del adaptive_state
        eta = adafbio.eta_t(fed_b, t, m)
        gy = jax.grad(problem.g, argnums=1)(
            state["x"], state["y"], batches.get("g", batches["g0"]))
        w_hat = hg(state["x"], state["y"], batches, key)
        w = tree_axpy(momentum, tree_sub(state["w"], w_hat), w_hat)
        w = jax.tree.map(lambda a, r: a.astype(r.dtype), w, state["w"])
        y = tree_update(state["y"], gy, fed_b.lr_y * eta)
        x = tree_update(state["x"], w, fed_b.lr_x * eta)
        return {"x": x, "y": y, "v": jax.tree.map(
            lambda a, r: a.astype(r.dtype), gy, state["v"]), "w": w}

    def sync_update(server, avg_state, m):
        return dict(avg_state), {"adaptive": server["adaptive"],
                                 "t": server["t"] + 1}

    def init_client(xp, yp, batches, key):
        return adafbio.init_client_state(problem, fed_b, xp, yp, batches, key)

    return Algorithm("localbsgvrm", fed_b, local_step, sync_update, init_client,
                     lambda x_like: adafbio.init_server_state(x_like, fed_b))


def make_algorithm(name: str, fed: FedConfig, problem: BilevelProblem) -> Algorithm:
    if name == "adafbio":
        return make_adafbio(fed, problem)
    if name in ("adafbio_na", "fedbioacc"):
        alg = make_adafbio_nonadaptive(fed, problem)
        return dataclasses.replace(alg, name=name)
    if name == "fednest":
        return make_fednest(fed, problem)
    if name == "localbsgvrm":
        return make_localbsgvrm(fed, problem)
    if name == "fedavg_sgd":
        return make_fedavg_sgd(fed, problem)
    raise KeyError(name)


ALGORITHMS = ("adafbio", "adafbio_na", "fedbioacc", "fednest", "localbsgvrm",
              "fedavg_sgd")
