"""Bilevel problem abstraction (Problem (1) of the paper).

A ``BilevelProblem`` bundles the per-client UL objective ``f^m(x, y; xi)`` and
LL objective ``g^m(x, y; zeta)``. Two calling conventions:

- generic: ``f(xp, yp, batch)`` / ``g(xp, yp, batch)`` scalars — used by the
  paper-faithful hypergradient estimator.
- factored (optional fast path): ``features(xp, batch)`` with
  ``g_from_feats(yp, feats, batch)`` / ``f_from_feats(yp, feats, batch)``.
  When the LL variable only touches the loss *through* the features (true for
  the hyper-representation split: y = head), Neumann ``∇²yy g`` products need
  only head-local autodiff against cached features — mathematically identical,
  far cheaper (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.tree_util import tree_sqnorm


@dataclasses.dataclass(frozen=True)
class BilevelProblem:
    f: Callable[..., jax.Array]                 # f(xp, yp, batch) -> scalar
    g: Callable[..., jax.Array]                 # g(xp, yp, batch) -> scalar
    features: Optional[Callable[..., Any]] = None       # features(xp, batch)
    f_from_feats: Optional[Callable[..., jax.Array]] = None
    g_from_feats: Optional[Callable[..., jax.Array]] = None
    # optional memory-bounded gradient paths (microbatched accumulation):
    grad_f_xy: Optional[Callable[..., Any]] = None  # (xp,yp,b) -> (gx, gy)
    grad_g_y: Optional[Callable[..., Any]] = None   # (xp,yp,b) -> gy
    # optional sharding re-assertion for x-/y-space gradient trees
    constrain_x: Optional[Callable[..., Any]] = None
    constrain_y: Optional[Callable[..., Any]] = None

    @property
    def factored(self) -> bool:
        return self.features is not None


def _split_chunks(batch, nc: int):
    return jax.tree.map(
        lambda a: a.reshape((nc, a.shape[0] // nc) + a.shape[1:]), batch)


def microbatched_grad(loss, argnums, nc: int, constrain=None,
                      acc_dtype=None):
    """grad of a mean-loss, accumulated over ``nc`` microbatches via lax.scan.

    Bounds backward transients/residuals to one microbatch. ``acc_dtype``
    None = accumulate in f32 (precise); "param" = accumulate in each param's
    own dtype (bf16 at LLM scale — halves accumulator + fused-dot buffers;
    the CPU-scale paper experiments use f32 params either way).
    ``constrain`` (optional) re-applies the param sharding to the accumulator
    so GSPMD doesn't replicate it.
    """
    gfn = jax.grad(loss, argnums=argnums)

    def _constrain(tree, like):
        return tree if constrain is None else constrain(tree)

    def wrapped(xp, yp, batch):
        chunks = _split_chunks(batch, nc)
        args = (xp, yp)
        like = args[argnums] if isinstance(argnums, int) else tuple(
            args[i] for i in argnums)
        dt = (lambda p: p.dtype) if acc_dtype == "param" else (
            lambda p: jnp.float32)
        acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, dt(p)), like)

        def body(acc, chunk):
            g = _constrain(gfn(xp, yp, chunk), like)
            acc = jax.tree.map(lambda a, gi: a + (gi / nc).astype(a.dtype),
                               acc, g)
            return _constrain(acc, like), None

        acc, _ = jax.lax.scan(body, acc0, chunks)
        return jax.tree.map(lambda a, p: a.astype(p.dtype), acc, like)

    return wrapped


def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token cross-entropy. logits [..., V], labels [...] int."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    # one-hot select instead of take_along_axis: partitions cleanly when the
    # vocab dim is sharded (gather would force an all-gather of the logits).
    # 1-D arange (not a broadcasted iota) so the comparison fuses instead of
    # materializing an s32 [B,S,V] tensor.
    iota = jnp.arange(lf.shape[-1], dtype=labels.dtype)
    ll = jnp.sum(jnp.where(iota == labels[..., None], lf, 0.0), axis=-1)
    loss = lse - ll
    if mask is not None:
        return (loss * mask).sum() / jnp.maximum(mask.sum(), 1)
    return loss.mean()


def lm_bilevel_problem(cfg, ctx, nu: float,
                       microbatch: Optional[int] = None) -> BilevelProblem:
    """Hyper-representation learning on the LM: x = backbone, y = head.

    ``batch`` keys: "tokens" (LL/UL chosen by caller), optional modality stubs.
    LL adds the strongly-convex regulariser (nu/2)||y||^2 (Problem (3)).
    ``microbatch``: max sequences per gradient microbatch (memory bound for the
    big-batch ∇(x,y) f and ∇y g paths).
    """
    from repro.models.model import features as model_features
    from repro.models.model import head_logits

    def feats_fn(xp, batch):
        return model_features(cfg, xp, batch, ctx)

    def _xent_head(yp, feats, batch):
        logits = head_logits(cfg, yp, feats[:, :-1])
        return softmax_xent(logits, batch["tokens"][:, 1:])

    def g_from_feats(yp, feats, batch):
        reg = 0.5 * nu * tree_sqnorm(yp)
        return _xent_head(yp, feats, batch) + reg

    def f_from_feats(yp, feats, batch):
        return _xent_head(yp, feats, batch)

    def g(xp, yp, batch):
        return g_from_feats(yp, feats_fn(xp, batch), batch)

    def f(xp, yp, batch):
        return f_from_feats(yp, feats_fn(xp, batch), batch)

    def _nc(batch):
        n = batch["tokens"].shape[0]
        if microbatch is None or n <= microbatch:
            return 1
        assert n % microbatch == 0, (n, microbatch)
        return n // microbatch

    # re-assert the param sharding on grad accumulators (GSPMD otherwise tends
    # to replicate the f32 accumulators of weight grads)
    from repro.models.model import model_specs
    from repro.models.params import axes_tree
    from repro.sharding import shard_act
    _axes = axes_tree(model_specs(cfg))

    def _is_axes(t):
        return isinstance(t, tuple) and all(u is None or isinstance(u, str)
                                            for u in t)

    def _constrain_like(axes):
        def fn(tree):
            return jax.tree.map(lambda g, a: shard_act(g, a, ctx.rules,
                                    fallback=("model",)),
                                tree, axes, is_leaf=lambda t: _is_axes(t))
        return fn

    acc_dtype = "param" if cfg.dtype == "bfloat16" else None

    def grad_f_xy(xp, yp, batch):
        c = _constrain_like((_axes["x"], _axes["y"]))
        return microbatched_grad(f, (0, 1), _nc(batch), c,
                                 acc_dtype)(xp, yp, batch)

    def grad_g_y(xp, yp, batch):
        c = _constrain_like(_axes["y"])
        return microbatched_grad(g, 1, _nc(batch), c, acc_dtype)(xp, yp, batch)

    return BilevelProblem(f=f, g=g, features=feats_fn,
                          f_from_feats=f_from_feats, g_from_feats=g_from_feats,
                          grad_f_xy=grad_f_xy, grad_g_y=grad_g_y,
                          constrain_x=_constrain_like(_axes["x"]),
                          constrain_y=_constrain_like(_axes["y"]))


def quadratic_bilevel_problem(H: jax.Array, Bm: jax.Array, c: jax.Array,
                              Q: jax.Array) -> BilevelProblem:
    """Analytic test problem with closed-form hypergradient:

      g(x, y) = 1/2 y^T H y - (B x)^T y          (H ≻ 0)
      f(x, y) = 1/2 ||y - c||^2 + 1/2 x^T Q x
      y*(x)   = H^{-1} B x
      ∇F(x)   = Q x + B^T H^{-1} (y*(x) - c)
    """
    def g(xp, yp, batch):
        del batch
        return 0.5 * yp @ H @ yp - (Bm @ xp) @ yp

    def f(xp, yp, batch):
        del batch
        return 0.5 * jnp.sum((yp - c) ** 2) + 0.5 * xp @ Q @ xp

    return BilevelProblem(f=f, g=g)


def quadratic_true_grad(H, Bm, c, Q, x):
    y_star = jnp.linalg.solve(H, Bm @ x)
    return Q @ x + Bm.T @ jnp.linalg.solve(H, y_star - c)
