"""Stochastic Neumann-series hypergradient estimator (paper Eq. (15)).

  ∇̂f(x,y; ξ̄) = ∇x f(x,y;ξ) − ∇²xy g(x,y;ζ₀) ·
                 [ K·θ · Π_{i=1..k} (I − θ ∇²yy g(x,y;ζ_i)) ] · ∇y f(x,y;ξ)

with k ~ U{0,…,K−1} drawn independently, θ ∈ (0, 1/L_g]. The bias against the
true ∇̂f decays as (1−μ/L_g)^K (Lemma 3); tests verify both the closed-form
K→∞ limit on the quadratic problem and the unbiasedness structure.

Two implementations:
  * ``hypergrad``           — paper-faithful, generic autodiff (grad-of-grad).
  * ``hypergrad_factored``  — beyond-paper fast path exploiting the factored
    LL structure (features cached; Neumann loop touches only the head). Exact
    same estimator when the problem is factored; asserted equal in tests.

``batches`` layout: {"f": ξ batch, "g0": ζ₀ batch, "gi": ζ_{1..K} batches with a
leading K axis}.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core.bilevel import BilevelProblem
from repro.core.tree_util import tree_axpy, tree_scale, tree_sub, tree_vdot


def _hvp_yy(g, xp, yp, batch, u):
    """(∇²yy g) u via jvp of grad."""
    grad_y = lambda y: jax.grad(g, argnums=1)(xp, y, batch)
    return jax.jvp(grad_y, (yp,), (u,))[1]


def _mixed_xy(g, xp, yp, batch, u):
    """(∇²xy g) u = ∇x ⟨∇y g(x,y), u⟩ (maps y-space -> x-space)."""
    def inner(x):
        gy = jax.grad(g, argnums=1)(x, yp, batch)
        return tree_vdot(gy, u)
    return jax.grad(inner)(xp)


def _neumann(hvp, gy, k, K: int, theta: float):
    """p = K·θ · Π_{i=1..k}(I − θ H_i) ∇y f, loop index selects batch ζ_i."""
    def body(i, p):
        return tree_axpy(-theta, hvp(i, p), p)          # p − θ H_i p
    p = jax.lax.fori_loop(0, k, body, gy)
    return tree_scale(p, K * theta)


def sample_k(key, K: int):
    return jax.random.randint(key, (), 0, K)


def _grad_f_xy(problem, xp, yp, batch):
    """(∇x f, ∇y f) in ONE backward (the paper computes them separately; the
    joint VJP halves that cost), optionally microbatched by the problem."""
    if problem.grad_f_xy is not None:
        return problem.grad_f_xy(xp, yp, batch)
    return jax.grad(problem.f, argnums=(0, 1))(xp, yp, batch)


def hypergrad(problem: BilevelProblem, xp, yp, batches: Dict[str, Any],
              key, K: int, theta: float):
    """Paper-faithful estimator. Returns the x-space pytree w."""
    k = sample_k(key, K)
    gx, gy = _grad_f_xy(problem, xp, yp, batches["f"])

    def hvp(i, p):
        bi = jax.tree.map(lambda a: a[i], batches["gi"])
        return _hvp_yy(problem.g, xp, yp, bi, p)

    p = _neumann(hvp, gy, k, K, theta)
    corr = _mixed_xy(problem.g, xp, yp, batches["g0"], p)
    if problem.constrain_x is not None:
        corr = problem.constrain_x(corr)
    return tree_sub(gx, corr)


def hypergrad_factored(problem: BilevelProblem, xp, yp, batches: Dict[str, Any],
                       key, K: int, theta: float):
    """Fast path: identical estimator; the Neumann ∇²yy products run against
    cached features (LL depends on x only through features)."""
    assert problem.factored
    k = sample_k(key, K)
    gx, gy = _grad_f_xy(problem, xp, yp, batches["f"])

    # cache features for the K Neumann batches once (stop-grad: the loop is
    # y-space only). Stored bf16: they are loop-invariant inputs of the
    # Neumann fori_loop, so their dtype is a live-memory term.
    feats_i = jax.vmap(lambda b: problem.features(xp, b))(batches["gi"])
    feats_i = jax.lax.stop_gradient(
        jax.tree.map(lambda a: a.astype(jnp.bfloat16) if a.dtype
                     == jnp.float32 else a, feats_i))

    def hvp(i, p):
        fi = jax.tree.map(lambda a: a[i], feats_i)
        bi = jax.tree.map(lambda a: a[i], batches["gi"])
        grad_y = lambda y: jax.grad(problem.g_from_feats)(y, fi, bi)
        return jax.jvp(grad_y, (yp,), (p,))[1]

    p = _neumann(hvp, gy, k, K, theta)
    corr = _mixed_xy(problem.g, xp, yp, batches["g0"], p)
    if problem.constrain_x is not None:
        corr = problem.constrain_x(corr)
    return tree_sub(gx, corr)


def hypergrad_fn(problem: BilevelProblem, K: int, theta: float,
                 factored: bool = True):
    impl = hypergrad_factored if (factored and problem.factored) else hypergrad
    return lambda xp, yp, batches, key: impl(problem, xp, yp, batches, key,
                                             K, theta)
