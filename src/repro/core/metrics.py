"""Analysis-quantity monitoring — the terms the paper's proof tracks.

Theorem 1's Lyapunov function and the consensus lemmas (Lemmas 20-21) bound:

  consensus error   (1/M) Σ_m ‖θ^m − θ̄‖²  for θ ∈ {x, y, v, w}
                    (resets to 0 at every sync; grows ∝ q between syncs)
  estimator drift   ‖v̄ − ∇y g(x̄,ȳ)‖, ‖w̄ − ∇̂f(x̄,ȳ)‖ (STORM tracking error)
  LL optimality gap ‖ȳ − y*(x̄)‖ (when y* is computable)

Watching these during a run is the practical counterpart of the convergence
proof: if consensus error stops contracting at syncs, q is too large for the
current learning rates (the (12kλq)³ M^{5/2} condition in Theorem 1).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.bilevel import BilevelProblem
from repro.core.tree_util import tree_mean_axis0, tree_sqnorm, tree_sub


def consensus_error(states: Dict[str, Any]) -> Dict[str, jax.Array]:
    """(1/M) Σ_m ‖θ^m − θ̄‖² per state field. States carry a leading M axis."""
    avg = tree_mean_axis0(states)
    out = {}
    for field in ("x", "y", "v", "w"):
        if field not in states:
            continue
        diffs = jax.tree.map(
            lambda a, b: jnp.sum((a.astype(jnp.float32)
                                  - b[None].astype(jnp.float32)) ** 2),
            states[field], avg[field])
        out[field] = jax.tree.reduce(jnp.add, diffs) / _m_of(states)
    return out


def _m_of(states) -> int:
    return jax.tree.leaves(states)[0].shape[0]


def estimator_drift(problem: BilevelProblem, states: Dict[str, Any],
                    batches_avg) -> Dict[str, jax.Array]:
    """‖v̄ − ∇y g(x̄,ȳ;ζ)‖ and (if cheap) the w̄ analogue on a probe batch."""
    avg = tree_mean_axis0(states)
    gy = jax.grad(problem.g, argnums=1)(avg["x"], avg["y"], batches_avg)
    dv = tree_sub(avg["v"], gy)
    return {"v_drift": jnp.sqrt(tree_sqnorm(dv)),
            "v_norm": jnp.sqrt(tree_sqnorm(avg["v"])),
            "w_norm": jnp.sqrt(tree_sqnorm(avg["w"]))}


def lyapunov_terms(problem: BilevelProblem, states: Dict[str, Any],
                   batches_avg, y_star_fn=None) -> Dict[str, jax.Array]:
    """The measurable pieces of Theorem 1's Ω_t (F(x̄) + LL gap + drift)."""
    avg = tree_mean_axis0(states)
    out = {"F": problem.f(avg["x"], avg["y"], batches_avg)}
    if y_star_fn is not None:
        ys = y_star_fn(avg["x"], avg["y"])
        gap = tree_sub(avg["y"], ys)
        out["ll_gap_sq"] = tree_sqnorm(gap)
    return out


class MetricsLog:
    """Tiny append-only metrics recorder used by the drivers."""

    def __init__(self):
        self.rows = []

    def log(self, step: int, **scalars):
        row = {"step": step}
        row.update({k: float(v) for k, v in scalars.items()})
        self.rows.append(row)

    def column(self, key):
        return [r.get(key) for r in self.rows]

    def last(self):
        return self.rows[-1] if self.rows else {}
