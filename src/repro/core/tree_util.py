"""Pytree arithmetic helpers (params/gradients live as plain dict pytrees)."""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(s, a, b):
    """s*a + b"""
    return jax.tree.map(lambda x, y: s * x + y, a, b)


def tree_update(params, direction, step):
    """params - step * direction, computed in f32, cast back to each param's
    dtype (prevents f32 step sizes from promoting bf16 params)."""
    return jax.tree.map(
        lambda p, d: (p.astype(jnp.float32)
                      - step * d.astype(jnp.float32)).astype(p.dtype),
        params, direction)


def tree_match_dtypes(a, like):
    return jax.tree.map(lambda x, r: x.astype(r.dtype), a, like)


def tree_vdot(a, b):
    leaves = jax.tree.map(
        lambda x, y: jnp.vdot(x.astype(jnp.float32), y.astype(jnp.float32)), a, b)
    return jax.tree.reduce(jnp.add, leaves, jnp.float32(0))


def tree_sqnorm(a):
    return tree_vdot(a, a)


def tree_norm(a):
    return jnp.sqrt(tree_sqnorm(a))


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), a)


def tree_mean_axis0(a):
    """Mean over a leading (client) axis on every leaf."""
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), a)


def tree_bcast_axis0(a, m: int):
    """Broadcast every leaf to a leading axis of size m."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (m,) + x.shape), a)


def tree_size(a) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(a))


def tree_stack(trees):
    """Stack a list of identically-structured pytrees along a new axis 0."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


# ------------------------------------------------------------------ barrier
#
# jax 0.4.x ships `lax.optimization_barrier` with NO batching, JVP, or
# transpose rules, so any barrier under vmap (client-batched steps) or grad
# (the model's layer scan puts one on the loss path) raises
# NotImplementedError. The barrier is the identity on values — batching keeps
# the batch dims, tangents/cotangents pass through (each behind its own
# barrier, matching the rules later jax versions added upstream). Register
# them once here; every call site then works under any transform.

def _register_barrier_rules():
    prim = getattr(jax.lax, "optimization_barrier_p", None)
    if prim is None:      # newer jax: rules ship with the primitive
        return
    from jax.interpreters import ad, batching

    if prim not in batching.primitive_batchers:
        def _batcher(batched_args, batch_dims, **params):
            return prim.bind(*batched_args, **params), batch_dims
        batching.primitive_batchers[prim] = _batcher

    if prim not in ad.primitive_jvps:
        def _jvp(primals, tangents):
            tangents = [ad.instantiate_zeros(t) for t in tangents]
            return prim.bind(*primals), prim.bind(*tangents)
        ad.primitive_jvps[prim] = _jvp

    if prim not in ad.primitive_transposes:
        def _transpose(cts, *primals):
            return cts
        ad.primitive_transposes[prim] = _transpose


_register_barrier_rules()


def tree_barrier(tree):
    """``jax.lax.optimization_barrier`` over a pytree, safe under ``vmap``,
    ``grad``/``jvp``, and ``remat`` (rules registered above).

    Use to sequence two evaluations sharing inputs so peak memory is max()
    rather than sum(): pass the values the second evaluation reads plus the
    first evaluation's outputs, and unpack the values you need.
    """
    return jax.lax.optimization_barrier(tree)


# ------------------------------------------------------------ flat buffers

@dataclasses.dataclass(frozen=True)
class TreeBufferSpec:
    """Static recipe for round-tripping a pytree through one flat buffer."""
    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    size: int                  # valid (unpadded) element count
    padded_size: int


def tree_buffer_spec(tree, *, align: int = 128) -> TreeBufferSpec:
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.asarray(l).dtype for l in leaves)
    size = sum(int(l.size) for l in leaves)
    padded = size + (-size) % align if size else align
    return TreeBufferSpec(treedef, shapes, dtypes, size, padded)


def tree_pack(tree, spec: TreeBufferSpec = None, *, dtype=jnp.float32,
              align: int = 128):
    """Flatten a pytree into ONE 1-D buffer (zero-padded to ``align``).

    Returns ``(flat, spec)``; feed ``spec`` to :func:`tree_unpack` to invert.
    All leaves are cast to ``dtype`` (f32 by default — the fused kernels do
    their math in f32 and cast back per leaf on unpack).
    """
    if spec is None:
        spec = tree_buffer_spec(tree, align=align)
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.zeros((spec.padded_size,), dtype), spec
    flat = jnp.concatenate([jnp.ravel(l).astype(dtype) for l in leaves])
    pad = spec.padded_size - spec.size
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), dtype)])
    return flat, spec


def tree_unpack(flat, spec: TreeBufferSpec):
    """Invert :func:`tree_pack`: split, reshape and cast back per leaf."""
    leaves = []
    off = 0
    for shape, dt in zip(spec.shapes, spec.dtypes):
        n = 1
        for s in shape:
            n *= s
        leaves.append(jax.lax.slice_in_dim(flat, off, off + n)
                      .reshape(shape).astype(dt))
        off += n
    return jax.tree.unflatten(spec.treedef, leaves)
