"""Pytree arithmetic helpers (params/gradients live as plain dict pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(s, a, b):
    """s*a + b"""
    return jax.tree.map(lambda x, y: s * x + y, a, b)


def tree_update(params, direction, step):
    """params - step * direction, computed in f32, cast back to each param's
    dtype (prevents f32 step sizes from promoting bf16 params)."""
    return jax.tree.map(
        lambda p, d: (p.astype(jnp.float32)
                      - step * d.astype(jnp.float32)).astype(p.dtype),
        params, direction)


def tree_match_dtypes(a, like):
    return jax.tree.map(lambda x, r: x.astype(r.dtype), a, like)


def tree_vdot(a, b):
    leaves = jax.tree.map(
        lambda x, y: jnp.vdot(x.astype(jnp.float32), y.astype(jnp.float32)), a, b)
    return jax.tree.reduce(jnp.add, leaves, jnp.float32(0))


def tree_sqnorm(a):
    return tree_vdot(a, a)


def tree_norm(a):
    return jnp.sqrt(tree_sqnorm(a))


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), a)


def tree_mean_axis0(a):
    """Mean over a leading (client) axis on every leaf."""
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), a)


def tree_bcast_axis0(a, m: int):
    """Broadcast every leaf to a leading axis of size m."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (m,) + x.shape), a)


def tree_size(a) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(a))
