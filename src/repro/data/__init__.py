from repro.data.synthetic import FederatedLMData, make_client_batch
from repro.data.hyperclean import HyperCleanData

__all__ = ["FederatedLMData", "make_client_batch", "HyperCleanData"]
