from repro.data.synthetic import (FederatedLMData, make_client_batch,
                                  make_cohort_batch)
from repro.data.hyperclean import HyperCleanData
from repro.data.partition import (dirichlet_class_priors, dirichlet_partition,
                                  label_histogram)

__all__ = ["FederatedLMData", "make_client_batch", "make_cohort_batch",
           "HyperCleanData", "dirichlet_class_priors", "dirichlet_partition",
           "label_histogram"]
