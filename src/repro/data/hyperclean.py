"""Federated data hyper-cleaning dataset (paper Problem (4)).

Per client: a training set with a fraction of labels corrupted (uniform
resample) and a clean validation set. The UL variable x^m assigns one weight
per training sample via σ(x_i); the LL variable y is a shared linear
classifier with an L2 (strongly convex) regularizer.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@functools.lru_cache(maxsize=8)
def _label_prior_table(seed: int, n_clients: int, n_classes: int,
                       alpha: float) -> jax.Array:
    """[n_clients, n_classes] Dirichlet label priors, computed once per
    (seed, N, K, alpha) rather than per client_data() call."""
    from repro.data.partition import dirichlet_class_priors
    return dirichlet_class_priors(jax.random.PRNGKey(seed), n_clients,
                                  n_classes, alpha)


@dataclasses.dataclass(frozen=True)
class HyperCleanData:
    n_clients: int
    n_train: int
    n_val: int
    feat_dim: int
    n_classes: int
    corrupt_frac: float
    seed: int = 0
    # Dirichlet label skew: client m draws labels from a client-specific
    # Dir(label_alpha·1_K) prior (data.partition) instead of uniformly —
    # small alpha concentrates each client on few classes. 0 disables.
    label_alpha: float = 0.0

    def client_data(self, m: int) -> Dict[str, jax.Array]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), m)
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        # class prototypes shared across clients; client-specific rotation for
        # heterogeneity
        proto = jax.random.normal(jax.random.PRNGKey(self.seed + 1),
                                  (self.n_classes, self.feat_dim))
        rot = jnp.eye(self.feat_dim) + 0.1 * jax.random.normal(
            k1, (self.feat_dim, self.feat_dim)) / jnp.sqrt(self.feat_dim)
        if self.label_alpha > 0:
            prior = _label_prior_table(self.seed + 2, self.n_clients,
                                       self.n_classes, self.label_alpha)[m]
            label_logits = jnp.log(prior + 1e-20)
        else:
            label_logits = None    # uniform via randint: keeps the seed's
                                   # exact draws for label_alpha == 0 runs

        def make(split_key, n):
            ka, kb = jax.random.split(split_key)
            if label_logits is None:
                labels = jax.random.randint(ka, (n,), 0, self.n_classes)
            else:
                labels = jax.random.categorical(ka, label_logits, shape=(n,))
            feats = proto[labels] @ rot + 0.5 * jax.random.normal(
                kb, (n, self.feat_dim))
            return feats.astype(jnp.float32), labels

        a_tr, b_tr = make(k2, self.n_train)
        a_val, b_val = make(k3, self.n_val)
        # corrupt a fraction of TRAIN labels
        n_bad = int(self.corrupt_frac * self.n_train)
        bad_idx = jax.random.permutation(k4, self.n_train)[:n_bad]
        bad_lab = jax.random.randint(k5, (n_bad,), 0, self.n_classes)
        b_tr = b_tr.at[bad_idx].set(bad_lab)
        corrupted = jnp.zeros(self.n_train, bool).at[bad_idx].set(True)
        return {"a_tr": a_tr, "b_tr": b_tr, "a_val": a_val, "b_val": b_val,
                "corrupted": corrupted}

    def all_clients(self) -> Dict[str, jax.Array]:
        ds = [self.client_data(m) for m in range(self.n_clients)]
        return {k: jnp.stack([d[k] for d in ds]) for k in ds[0]}
