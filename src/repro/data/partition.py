"""Dirichlet non-IID data partitioning across a client population.

The standard label-skew construction from the federated learning literature:
for each class k, split its examples among the N clients with proportions
drawn from Dir(alpha·1_N). Small alpha concentrates each class on few
clients (strong heterogeneity — Assumption 7's δ > 0 made real at population
scale); large alpha recovers a near-uniform IID split. Everything is a pure
function of the key, so a partition is exactly reproducible across runs and
hosts.

Two entry points:

  dirichlet_class_priors  — per-client class distributions [N, K]; used by
                            the synthetic generators (``data.synthetic``,
                            ``data.hyperclean``) that sample labels rather
                            than partitioning a fixed set.
  dirichlet_partition     — index partition of a fixed labeled set (ragged,
                            host-side) for map-style datasets.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def dirichlet_class_priors(key, n_clients: int, n_classes: int,
                           alpha: float) -> jax.Array:
    """[n_clients, n_classes] class priors, row i ~ Dir(alpha·1_K)."""
    if alpha <= 0:
        raise ValueError(f"alpha must be > 0, got {alpha}")
    return jax.random.dirichlet(key, jnp.full((n_classes,), alpha,
                                              jnp.float32),
                                shape=(n_clients,))


def dirichlet_partition(key, labels, n_clients: int,
                        alpha: float) -> List[np.ndarray]:
    """Partition ``labels``' indices into ``n_clients`` Dirichlet-skewed sets.

    For each class, the class's (shuffled) indices are split among clients
    with proportions ~ Dir(alpha·1_N). Returns one int64 index array per
    client; the arrays are disjoint and cover ``range(len(labels))``.
    """
    if alpha <= 0:
        raise ValueError(f"alpha must be > 0, got {alpha}")
    labels = np.asarray(labels)
    n_classes = int(labels.max()) + 1
    # [n_classes, n_clients] proportions, one Dirichlet draw per class
    props = np.asarray(jax.random.dirichlet(
        key, jnp.full((n_clients,), alpha, jnp.float32),
        shape=(n_classes,)))
    parts: List[List[np.ndarray]] = [[] for _ in range(n_clients)]
    for k in range(n_classes):
        idx_k = np.where(labels == k)[0]
        if idx_k.size == 0:
            continue
        perm = np.asarray(jax.random.permutation(
            jax.random.fold_in(key, 1 + k), idx_k.size))
        idx_k = idx_k[perm]
        cuts = np.minimum((np.cumsum(props[k]) * idx_k.size).astype(int),
                          idx_k.size)[:-1]
        for cid, chunk in enumerate(np.split(idx_k, cuts)):
            parts[cid].append(chunk)
    return [np.concatenate(p) if p else np.zeros((0,), np.int64)
            for p in parts]


def label_histogram(labels, parts: Sequence[np.ndarray],
                    n_classes: int) -> np.ndarray:
    """[n_clients, n_classes] label counts of a partition (skew diagnostics)."""
    labels = np.asarray(labels)
    return np.stack([np.bincount(labels[idx], minlength=n_classes)
                     for idx in parts])
