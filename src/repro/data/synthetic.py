"""Deterministic synthetic federated LM data (non-iid across clients).

Each client m draws tokens from a Markov-ish mixture whose unigram
distribution is a client-specific permutation of a Zipf law — clients are
*statistically heterogeneous* (Assumption 7's δ > 0 is real, not cosmetic),
while batches are reproducible pure functions of (client, step, slot), so a
restarted run or a different sharding sees identical data.

Two heterogeneity models:

  * permutation (default): client unigrams are Zipf laws under client-specific
    vocabulary permutations, mixed by ``heterogeneity`` ∈ [0, 1];
  * Dirichlet (``dirichlet_alpha``): client unigrams are rows of
    ``data.partition.dirichlet_class_priors`` over the vocabulary — the
    standard label-skew knob, small alpha = strong skew. Used by the
    population-mode runs where per-client skew must be controllable at
    N ≫ vmap scale.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@functools.lru_cache(maxsize=8)
def _dirichlet_logits_table(vocab: int, n_clients: int,
                            alpha: float) -> jax.Array:
    """[n_clients, vocab] log-priors, computed once per (vocab, N, alpha) —
    population-mode host batch building stays O(C) per round."""
    from repro.data.partition import dirichlet_class_priors
    priors = dirichlet_class_priors(jax.random.PRNGKey(7), n_clients, vocab,
                                    alpha)
    return jnp.log(priors + 1e-20)


@dataclasses.dataclass(frozen=True)
class FederatedLMData:
    vocab: int
    n_clients: int
    zipf_a: float = 1.2
    heterogeneity: float = 1.0    # 0 = iid clients, 1 = fully permuted unigrams
    # Dirichlet label-skew unigrams (overrides the permutation model):
    # client m's unigram ~ Dir(alpha·1_V); small alpha = strong non-IID skew
    dirichlet_alpha: Optional[float] = None

    def _client_logits(self, client: jax.Array) -> jax.Array:
        if self.dirichlet_alpha is not None:
            table = _dirichlet_logits_table(self.vocab, self.n_clients,
                                            self.dirichlet_alpha)
            return table[client]
        base = -self.zipf_a * jnp.log(jnp.arange(1, self.vocab + 1, dtype=jnp.float32))
        key = jax.random.fold_in(jax.random.PRNGKey(7), client)
        perm = jax.random.permutation(key, self.vocab)
        mixed = (1 - self.heterogeneity) * base + self.heterogeneity * base[perm]
        return mixed

    def sample(self, client, step, slot, shape) -> jax.Array:
        """Tokens of ``shape`` for (client, step, slot) — pure & deterministic."""
        logits = self._client_logits(jnp.asarray(client, jnp.int32))
        key = jax.random.PRNGKey(3)
        for s in (client, step, slot):
            key = jax.random.fold_in(key, jnp.asarray(s, jnp.int32))
        return jax.random.categorical(key, logits, shape=shape).astype(jnp.int32)


def _materialize(data: FederatedLMData, specs: Dict[str, Any], step: int,
                 clients: Sequence[int]) -> Dict[str, jax.Array]:
    out = {}
    for slot_id, (name, sds) in enumerate(sorted(specs.items())):
        if sds.dtype == jnp.int32:
            toks = [data.sample(int(c), step, slot_id, sds.shape[1:])
                    for c in clients]
            out[name] = jnp.stack(toks)
        else:
            # modality stubs keyed per GLOBAL client like the token slots, so
            # cohort row j ≡ full-population row ids[j] for the same step
            key = jax.random.fold_in(jax.random.PRNGKey(11), slot_id + 100 * step)
            rows = [jax.random.normal(jax.random.fold_in(key, int(c)),
                                      sds.shape[1:], jnp.float32) * 0.02
                    for c in clients]
            out[name] = jnp.stack(rows).astype(sds.dtype)
    return out


def make_client_batch(data: FederatedLMData, cfg, specs: Dict[str, Any],
                      step: int) -> Dict[str, jax.Array]:
    """Materialize one training-step batch matching ``client_batch_specs``.

    Token keys get per-client non-iid samples; modality stubs (precomputed
    frame/patch embeddings — the allowed frontend carve-out) get unit-scale
    deterministic noise.
    """
    m = next(s.shape[0] for s in specs.values())
    return _materialize(data, specs, step, range(m))


def make_cohort_batch(data: FederatedLMData, cfg, specs: Dict[str, Any],
                      step: int, ids) -> Dict[str, jax.Array]:
    """Like :func:`make_client_batch` but for a sampled cohort: ``specs``
    carries a leading [C] axis and row j holds GLOBAL client ``ids[j]``'s
    data — the O(C) host-side data path of population mode."""
    return _materialize(data, specs, step, [int(g) for g in np.asarray(ids)])
