"""Deterministic synthetic federated LM data (non-iid across clients).

Each client m draws tokens from a Markov-ish mixture whose unigram
distribution is a client-specific permutation of a Zipf law — clients are
*statistically heterogeneous* (Assumption 7's δ > 0 is real, not cosmetic),
while batches are reproducible pure functions of (client, step, slot), so a
restarted run or a different sharding sees identical data.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FederatedLMData:
    vocab: int
    n_clients: int
    zipf_a: float = 1.2
    heterogeneity: float = 1.0    # 0 = iid clients, 1 = fully permuted unigrams

    def _client_logits(self, client: jax.Array) -> jax.Array:
        base = -self.zipf_a * jnp.log(jnp.arange(1, self.vocab + 1, dtype=jnp.float32))
        key = jax.random.fold_in(jax.random.PRNGKey(7), client)
        perm = jax.random.permutation(key, self.vocab)
        mixed = (1 - self.heterogeneity) * base + self.heterogeneity * base[perm]
        return mixed

    def sample(self, client, step, slot, shape) -> jax.Array:
        """Tokens of ``shape`` for (client, step, slot) — pure & deterministic."""
        logits = self._client_logits(jnp.asarray(client, jnp.int32))
        key = jax.random.PRNGKey(3)
        for s in (client, step, slot):
            key = jax.random.fold_in(key, jnp.asarray(s, jnp.int32))
        return jax.random.categorical(key, logits, shape=shape).astype(jnp.int32)


def make_client_batch(data: FederatedLMData, cfg, specs: Dict[str, Any],
                      step: int) -> Dict[str, jax.Array]:
    """Materialize one training-step batch matching ``client_batch_specs``.

    Token keys get per-client non-iid samples; modality stubs (precomputed
    frame/patch embeddings — the allowed frontend carve-out) get unit-scale
    deterministic noise.
    """
    out = {}
    for slot_id, (name, sds) in enumerate(sorted(specs.items())):
        if sds.dtype == jnp.int32:
            m = sds.shape[0]
            toks = []
            for c in range(m):
                toks.append(data.sample(c, step, slot_id, sds.shape[1:]))
            out[name] = jnp.stack(toks)
        else:
            key = jax.random.fold_in(jax.random.PRNGKey(11), slot_id + 100 * step)
            out[name] = (jax.random.normal(key, sds.shape, jnp.float32)
                         * 0.02).astype(sds.dtype)
    return out
