from repro.fed.round import make_round_step, stack_round_batches
from repro.fed.runtime import (FederatedTrainer, build_lm_problem_ctx,
                               split_client_batch)
from repro.fed.serve import build_serve_fns

__all__ = ["FederatedTrainer", "build_lm_problem_ctx", "split_client_batch",
           "build_serve_fns", "make_round_step", "stack_round_batches"]
