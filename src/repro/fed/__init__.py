from repro.fed.population import (ClientPopulation, make_population_round,
                                  staleness_weights)
from repro.fed.round import make_round_step, stack_round_batches
from repro.fed.runtime import (FederatedTrainer, build_lm_problem_ctx,
                               split_client_batch)
from repro.fed.sampling import (AvailabilityTraceSampler, CohortSampler,
                                RoundRobinSampler, SAMPLERS, UniformSampler,
                                make_sampler)
from repro.fed.serve import build_serve_fns

__all__ = ["FederatedTrainer", "build_lm_problem_ctx", "split_client_batch",
           "build_serve_fns", "make_round_step", "stack_round_batches",
           "ClientPopulation", "make_population_round", "staleness_weights",
           "CohortSampler", "UniformSampler", "RoundRobinSampler",
           "AvailabilityTraceSampler", "SAMPLERS", "make_sampler"]
