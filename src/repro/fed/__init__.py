from repro.fed.population import (ClientPopulation, DELAY_MODELS,
                                  DelayModel, delay_model_from_config,
                                  init_async_state, make_async_round,
                                  make_delay_model, make_population_round,
                                  parse_tier_spec, staleness_weights,
                                  tier_assignment)
from repro.fed.round import make_round_step, stack_round_batches
from repro.fed.runtime import (FederatedTrainer, build_lm_problem_ctx,
                               split_client_batch)
from repro.fed.sampling import (AvailabilityTraceSampler, CohortSampler,
                                RoundRobinSampler, SAMPLERS,
                                TraceFileSampler, UniformSampler,
                                load_delay_trace, load_trace, make_sampler,
                                save_trace)
from repro.fed.serve import build_serve_fns

__all__ = ["FederatedTrainer", "build_lm_problem_ctx", "split_client_batch",
           "build_serve_fns", "make_round_step", "stack_round_batches",
           "ClientPopulation", "make_population_round", "staleness_weights",
           "make_async_round", "init_async_state",
           "DelayModel", "DELAY_MODELS", "make_delay_model",
           "delay_model_from_config", "parse_tier_spec", "tier_assignment",
           "CohortSampler", "UniformSampler", "RoundRobinSampler",
           "AvailabilityTraceSampler", "TraceFileSampler", "load_trace",
           "load_delay_trace", "save_trace", "SAMPLERS", "make_sampler"]
