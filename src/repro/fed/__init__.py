from repro.fed.population import (ClientPopulation, init_async_state,
                                  make_async_round, make_population_round,
                                  staleness_weights)
from repro.fed.round import make_round_step, stack_round_batches
from repro.fed.runtime import (FederatedTrainer, build_lm_problem_ctx,
                               split_client_batch)
from repro.fed.sampling import (AvailabilityTraceSampler, CohortSampler,
                                RoundRobinSampler, SAMPLERS,
                                TraceFileSampler, UniformSampler, load_trace,
                                make_sampler, save_trace)
from repro.fed.serve import build_serve_fns

__all__ = ["FederatedTrainer", "build_lm_problem_ctx", "split_client_batch",
           "build_serve_fns", "make_round_step", "stack_round_batches",
           "ClientPopulation", "make_population_round", "staleness_weights",
           "make_async_round", "init_async_state",
           "CohortSampler", "UniformSampler", "RoundRobinSampler",
           "AvailabilityTraceSampler", "TraceFileSampler", "load_trace",
           "save_trace", "SAMPLERS", "make_sampler"]
