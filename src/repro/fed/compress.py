"""Communication compression: pluggable client→server update codecs with
error feedback + bytes-accurate accounting.

AdaFBiO's headline communication complexity is counted in *rounds*; what a
deployment pays for is *bytes on the wire*. This module makes the
client↔server legs of every round program codec-aware so the repo can
measure the bytes-vs-convergence trade-off (communication-efficient
federated bilevel methods: Li, Huang & Huang, arXiv:2302.06701; momentum
variants: Gao, arXiv:2204.13299).

What a codec compresses: the client→server message of one sync. Client i
finished its q local steps at state ``cur_i`` starting from ``ref_i`` — the
state the server last handed it (broadcast / scatter / init), so the server
knows ``ref_i`` and the message only needs the update ``Δ_i = cur_i −
ref_i``. With error feedback (EF-SGD) the client adds its residual ``e_i``
before encoding and keeps what the codec dropped::

    sent_i  = decode(encode(Δ_i + e_i))        # what the server sees
    e_i'    = (Δ_i + e_i) − sent_i             # kept for the next sync
    recon_i = ref_i + sent_i                   # server-side reconstruction

so transmitted + residual telescopes to the true update exactly, and the
aggregation runs over the ``recon_i`` (the server's view). Three codecs:

  none   — bit-identical passthrough (``client_messages`` returns its
           inputs untouched; the round programs take their pre-codec path).
  int8   — stochastic uniform quantization to ``bits``-bit levels with one
           f32 scale per tensor (per leaf, per client), backed by the
           pad-to-block Pallas quantize/dequantize kernels
           (``repro.kernels.quantize``) on TPU and their jnp oracles
           elsewhere. Unbiased: E[decode(encode(x))] = x; worst-case
           per-entry error is one quantization step, max|x| / (2^(b-1)-1).
  topk   — per-tensor magnitude sparsification keeping ``round(topk_frac ·
           size)`` entries (at least 1); ``topk_frac = 1`` keeps everything
           and matches ``none`` up to f32 rounding. Deterministic, so EF is
           what guarantees every coordinate is eventually transmitted.

Bytes accounting (the documented per-codec formulas — ``FedDriver``, both
launchers, and ``benchmarks/sweep.py`` all report through these helpers):

  state_bytes(tree)            = Σ_leaf size · itemsize          (uplink
  none:  message_bytes(tree)   = state_bytes(tree)                 = exact)
  int8:  message_bytes(tree)   = Σ_leaf ceil(size · bits / 8) + 4  (exact;
         levels bit-packed, one f32 scale per tensor)
  topk:  message_bytes(tree)   = Σ_leaf k_leaf · (4 + 4)           (index +
         value cost: one int32 index + one f32 value per kept entry)

The server→client broadcast is NOT compressed (down-compression would
desynchronize ``ref``); one downlink costs ``state_bytes`` per receiving
client. Semantics, EF state lifecycle in the population bank, and the
accounting conventions: docs/compression.md.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CODECS, validate_codec
from repro.kernels import ops

# RNG salt for the stochastic-rounding noise — disjoint from the local-step
# fold_in(gid)/fold_in(t) stream and the async delay salts, so enabling a
# codec never perturbs the per-step sample draws
_CODEC_SALT = 0xC0DEC


def _leaf_k(size: int, frac: float) -> int:
    """Entries the topk codec keeps in a ``size``-element tensor."""
    return min(max(int(round(frac * size)), 1), size)


def state_bytes(tree) -> int:
    """Uncompressed wire size of one client-state pytree (arrays or
    ShapeDtypeStructs): Σ_leaf size · itemsize."""
    return sum(int(np.prod(l.shape, dtype=np.int64)) *
               jnp.dtype(l.dtype).itemsize for l in jax.tree.leaves(tree))


@dataclasses.dataclass(frozen=True)
class Codec:
    """One client→server update codec (see the module docstring).

    ``roundtrip`` is the lossy identity decode(encode(·)) over ONE client's
    update pytree — the simulation never materializes the encoded form, but
    ``message_bytes`` prices it exactly. Use :func:`make_codec` to build one
    with validation.
    """
    name: str = "none"
    bits: int = 8
    topk_frac: float = 0.1
    error_feedback: bool = True

    @property
    def lossy(self) -> bool:
        return self.name != "none"

    @property
    def stateful(self) -> bool:
        """True when per-client EF residuals must persist across rounds."""
        return self.lossy and self.error_feedback

    @property
    def qmax(self) -> int:
        """Largest quantization level: 2^(bits-1) - 1 (127 at 8 bits)."""
        return (1 << (self.bits - 1)) - 1

    # -------------------------------------------------- the lossy identity

    def roundtrip(self, key, tree):
        """decode(encode(tree)) for one client's update pytree (f32 leaves
        in, f32 leaves out); ``key`` seeds the stochastic rounding noise
        (unused by the deterministic codecs)."""
        if not self.lossy:
            return tree
        leaves, treedef = jax.tree.flatten(tree)
        if self.name == "int8":
            keys = jax.random.split(key, max(len(leaves), 1))
            out = [self._int8_leaf(k, l) for k, l in zip(keys, leaves)]
        else:
            out = [self._topk_leaf(l) for l in leaves]
        return jax.tree.unflatten(treedef, out)

    def _int8_leaf(self, key, x):
        xf = x.astype(jnp.float32).reshape(-1)
        # the 1e-30 floor only guards the all-zero tensor (q = 0 exactly);
        # real tensors keep scale = max|x| / qmax, so |error| <= scale
        scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-30) / self.qmax
        u = jax.random.uniform(key, xf.shape)
        q = ops.quantize_stoch(xf, u, scale, qmax=self.qmax,
                               use_pallas=ops.default_use_pallas(),
                               interpret=False)
        return ops.dequantize(q, scale,
                              use_pallas=ops.default_use_pallas(),
                              interpret=False).reshape(x.shape)

    def _topk_leaf(self, x):
        n = int(x.size)
        k = _leaf_k(n, self.topk_frac)
        flat = x.astype(jnp.float32).reshape(-1)
        if k >= n:
            return flat.reshape(x.shape)
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        return jnp.zeros_like(flat).at[idx].set(flat[idx]).reshape(x.shape)

    # -------------------------------------------------- bytes accounting

    def message_bytes(self, tree) -> int:
        """Exact uplink cost of one client→server message for a pytree of
        this shape (arrays or ShapeDtypeStructs) — the documented per-codec
        formulas (module docstring / docs/compression.md)."""
        sizes = [int(np.prod(l.shape, dtype=np.int64))
                 for l in jax.tree.leaves(tree)]
        if self.name == "int8":
            return sum(-(-s * self.bits // 8) + 4 for s in sizes)
        if self.name == "topk":
            return sum(_leaf_k(s, self.topk_frac) * (4 + 4) for s in sizes)
        return state_bytes(tree)

    def down_bytes(self, tree) -> int:
        """Downlink cost per receiving client (broadcast is uncompressed)."""
        return state_bytes(tree)


def make_codec(name: str = "none", *, bits: int = 8, topk_frac: float = 0.1,
               error_feedback: bool = True) -> Codec:
    """Build a validated :class:`Codec` (shared validation with
    ``FedConfig`` — ``repro.configs.base.validate_codec``)."""
    validate_codec(name, bits, topk_frac)
    return Codec(name=name, bits=int(bits), topk_frac=float(topk_frac),
                 error_feedback=bool(error_feedback))


def codec_from_config(fed) -> Codec:
    """The :class:`Codec` a ``FedConfig`` describes."""
    return make_codec(fed.codec, bits=fed.codec_bits,
                      topk_frac=fed.topk_frac,
                      error_feedback=fed.error_feedback)


def wire_costs(codec: "Codec", stacked_states) -> Tuple[int, int]:
    """(uplink bytes per client→server message, downlink bytes per
    receiving client) for ONE client of a stacked [C/N, ...] client-state
    pytree (arrays or ShapeDtypeStructs) — the single pricing helper the
    driver and the launchers share, so reported bytes can never drift."""
    one = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(tuple(a.shape)[1:], a.dtype),
        stacked_states)
    return codec.message_bytes(one), codec.down_bytes(one)


# ------------------------------------------------------------ EF residuals

def zeros_ef(codec: Optional[Codec], states):
    """The stacked error-feedback residual pytree matching a [C/N, ...]
    client-state pytree (f32 — residuals accumulate sub-precision error),
    or None when the codec keeps no state (lossless, or EF disabled)."""
    if codec is None or not codec.stateful:
        return None
    return jax.tree.map(lambda a: jnp.zeros(tuple(a.shape), jnp.float32),
                        states)


def mask_rows(keep, new, old):
    """Per-row select over a leading client axis: row i of ``new`` where
    ``keep[i]``, else row i of ``old`` (the masked no-op used for clients
    that did not transmit — inactive, or in flight on the async path)."""
    if new is None:
        return None

    def sel(a, b):
        m = keep.reshape((keep.shape[0],) + (1,) * (a.ndim - 1))
        return jnp.where(m, a, b)
    return jax.tree.map(sel, new, old)


# ------------------------------------------------------------ the uplink leg

def client_messages(codec: Optional[Codec], key, round_id, ids, ref, cur,
                    ef=None) -> Tuple[Any, Any]:
    """Simulate the client→server leg for a batched cohort.

    ``ref``/``cur`` are [C, ...] pytrees (the server-known dispatch states
    and the post-local-steps states), ``ids`` the [C] global client ids
    (per-client stochastic-rounding streams fold the GLOBAL id, so a cohort
    transmission reproduces the same client's full-population one), ``ef``
    the gathered [C, ...] f32 residuals (None when the codec keeps none).

    Returns ``(recon, new_ef)`` — the server-side reconstructions (leaf
    dtypes of ``cur``) and the updated residuals. Lossless codecs return
    ``(cur, ef)`` untouched: the caller's pre-codec program is unchanged
    and bit-identical.
    """
    if codec is None or not codec.lossy:
        return cur, ef
    base = jax.random.fold_in(jax.random.fold_in(key, _CODEC_SALT),
                              round_id)

    def one(gid, r, c, e):
        delta = jax.tree.map(
            lambda ci, ri: ci.astype(jnp.float32) - ri.astype(jnp.float32),
            c, r)
        if e is not None:
            delta = jax.tree.map(jnp.add, delta, e)
        sent = codec.roundtrip(jax.random.fold_in(base, gid), delta)
        e_new = (jax.tree.map(jnp.subtract, delta, sent)
                 if e is not None else None)
        recon = jax.tree.map(
            lambda ri, s: (ri.astype(jnp.float32) + s).astype(ri.dtype),
            r, sent)
        return recon, e_new

    return jax.vmap(one)(ids, ref, cur, ef)
