"""Client population bank: N persistent client states, O(C) per-round compute.

The seed runtime hard-wired "population = the vmapped leading axis": partial
participation ran ALL M clients and masked the inactive ones, so a
10%-participation round cost a full round and M was capped by what one
vmap/jit fits. This module decouples the two scales:

  * a ``ClientPopulation`` bank holds N client states (N in the
    hundreds/thousands) as ONE stacked pytree plus per-client bookkeeping
    (``last_sync``: the round at which each client last received the server
    state);
  * each round, a ``CohortSampler`` (``repro.fed.sampling``) picks C ids;
  * the round program is gather → fused-scan-round → scatter: take the C
    sampled states out of the bank, run the q local steps as one
    ``lax.scan`` (the same body the round engine uses), and write the
    results back. The program jits ONCE for cohort shape [C, ...] — compute
    scales with the cohort, not the population.

Sync modes (who receives the post-aggregation server state):

  broadcast     — every client in the bank (the classic FedAvg simulation
                  assumption, and exactly the legacy masked-participation
                  semantics: inactive clients idle at the current server
                  state). Staleness is identically zero.
  participants  — only the aggregating cohort. Clients then carry genuinely
                  stale models between participations — the asynchronous /
                  intermittent-availability regime (Jiao et al.,
                  arXiv:2212.10048) — and ``staleness_weights`` can
                  down-weight long-absent clients at aggregation time.

Asynchronous execution (``make_async_round``, PR 3) drops the synchronized-
round assumption entirely: a dispatched client takes ``delay`` rounds to
return its update (``in_flight``/``dispatch_round`` bookkeeping + a
jit-compatible pending-update buffer holding the computed update until it
"arrives"), cohorts overlap (a client sampled while still in flight simply
keeps flying — its delayed update lands when due), the server drops arrivals
older than ``max_staleness`` rounds (bounded-staleness gating) and can scale
its step by the observed cohort staleness (delay-adaptive eta_t, à la Jiao
et al. arXiv:2212.10048). The degenerate setting — every delay exactly one
round, no gating, no delay adaptation — reproduces the synchronous
``make_population_round`` trajectories, making async a strict superset of
the sync path (tests/test_async.py). See docs/async.md for the semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

SYNC_MODES = ("broadcast", "participants")

# return_round sentinel for clients with no pending update in flight
NEVER = jnp.iinfo(jnp.int32).max


# ------------------------------------------------------------ bank primitives

def gather(bank_states, ids):
    """Select cohort rows: [N, ...] pytree -> [C, ...] pytree."""
    return jax.tree.map(lambda a: jnp.take(a, ids, axis=0), bank_states)


def scatter(bank_states, ids, values):
    """Write cohort rows back: bank[ids] = values (later duplicates win)."""
    return jax.tree.map(lambda a, v: a.at[ids].set(v.astype(a.dtype)),
                        bank_states, values)


def broadcast(bank_states, value):
    """Overwrite every bank row with one (unbatched) client state."""
    return jax.tree.map(
        lambda a, v: jnp.broadcast_to(v[None].astype(a.dtype), a.shape),
        bank_states, value)


def weighted_mean(states, w):
    """Weighted client mean over the leading axis (w sums to 1)."""
    return jax.tree.map(
        lambda a: jnp.tensordot(w, a.astype(jnp.float32),
                                axes=1).astype(a.dtype), states)


def staleness_weights(last_sync, ids, round_id, decay: float):
    """Aggregation weights for a cohort, down-weighting stale members.

    Client i's staleness is ``round_id - last_sync[i]`` — the number of
    rounds since it last pulled the server state. Weights are
    ``(1 + staleness)^-decay``, normalized over the cohort; ``decay = 0``
    (or an all-fresh cohort, e.g. broadcast sync mode) recovers the plain
    uniform average.
    """
    stale = jnp.maximum(round_id - last_sync[ids], 0).astype(jnp.float32)
    w = (1.0 + stale) ** (-decay)
    return w / jnp.maximum(w.sum(), 1e-12)


# ------------------------------------------------------------ the population

@dataclasses.dataclass
class ClientPopulation:
    """N stacked client states + per-client sync/flight bookkeeping.

    ``in_flight``/``dispatch_round`` are the async-execution fields: client i
    with ``in_flight[i]`` is busy computing an update it dispatched at round
    ``dispatch_round[i]`` and cannot start new work until that update
    arrives (``make_async_round``). The synchronous path never sets them.
    """
    states: Any                  # pytree, every leaf with leading axis N
    last_sync: jax.Array         # int32 [N]: round of last server-state pull
    n: int
    in_flight: Optional[jax.Array] = None      # bool  [N]
    dispatch_round: Optional[jax.Array] = None  # int32 [N]

    def __post_init__(self):
        if self.in_flight is None:
            self.in_flight = jnp.zeros((self.n,), bool)
        if self.dispatch_round is None:
            self.dispatch_round = jnp.zeros((self.n,), jnp.int32)

    @classmethod
    def create(cls, init_one: Callable[[jax.Array, Any], Any], key,
               batches_n, n: int) -> "ClientPopulation":
        """vmap ``init_one(client_key, client_batch)`` over N clients."""
        states = jax.vmap(init_one)(jax.random.split(key, n), batches_n)
        return cls(states=states, last_sync=jnp.zeros((n,), jnp.int32), n=n)

    def gather(self, ids):
        return gather(self.states, ids)

    def scatter(self, ids, values):
        return dataclasses.replace(self, states=scatter(self.states, ids,
                                                        values))


# ------------------------------------------------------------ fused round

def make_population_round(local_step_ids: Callable, sync_update: Callable,
                          q: int, *, sync_mode: str = "broadcast",
                          staleness_decay: float = 0.0) -> Callable:
    """Build the gather → scan-round → aggregate → scatter program.

    ``local_step_ids(states_c, server, batch, key, ids)`` is the per-step
    function over the COHORT (any client-vmapping is its own; ``ids`` are the
    global client ids, so per-client RNG folds match the full-population
    path). ``sync_update(server, avg_state)`` maps the aggregated client
    state to ``(new_client_state, new_server)`` (unbatched client state).

    Returns ``round_fn(bank_states, last_sync, server, ids, batches_q, key,
    round_id) -> (bank_states, last_sync, server)`` — jit-compatible, one
    compile per cohort shape [C, ...]: q local steps on the C gathered
    states, a (staleness-weighted) cohort aggregate, the server update, and
    the write-back dictated by ``sync_mode``.
    """
    if sync_mode not in SYNC_MODES:
        raise ValueError(f"sync_mode must be one of {SYNC_MODES}, "
                         f"got {sync_mode!r}")
    if q < 1:
        raise ValueError(f"round needs q >= 1 local steps, got {q}")

    def round_fn(bank_states, last_sync, server, ids, batches_q, key,
                 round_id):
        cur = gather(bank_states, ids)

        def body(carry, batch):
            st, srv = carry
            st, srv = local_step_ids(st, srv, batch, key, ids)
            return (st, srv), None

        (cur, server), _ = jax.lax.scan(body, (cur, server), batches_q,
                                        length=q)
        w = staleness_weights(last_sync, ids, round_id, staleness_decay)
        new_client, server = sync_update(server, weighted_mean(cur, w))
        if sync_mode == "broadcast":
            bank_states = broadcast(bank_states, new_client)
            last_sync = jnp.full_like(last_sync, round_id + 1)
        else:
            c = ids.shape[0]
            bank_states = scatter(
                bank_states, ids,
                jax.tree.map(lambda v: jnp.broadcast_to(v[None],
                                                        (c,) + v.shape),
                             new_client))
            last_sync = last_sync.at[ids].set(round_id + 1)
        return bank_states, last_sync, server

    return round_fn


# ------------------------------------------------------------ async execution

def scatter_where(bank_states, ids, values, keep):
    """Masked cohort write-back: ``bank[ids[j]] = values[j]`` where
    ``keep[j]``, rows with ``~keep[j]`` are untouched (later duplicate ids
    win, as in :func:`scatter`)."""
    def upd(a, v):
        m = keep.reshape((keep.shape[0],) + (1,) * (v.ndim - 1))
        return a.at[ids].set(jnp.where(m, v.astype(a.dtype), a[ids]))
    return jax.tree.map(upd, bank_states, values)


def _rows_where(bank_states, mask, value):
    """Overwrite the bank rows selected by ``mask`` ([N] bool) with one
    unbatched client state."""
    def upd(a, v):
        m = mask.reshape((mask.shape[0],) + (1,) * (a.ndim - 1))
        return jnp.where(m, v[None].astype(a.dtype), a)
    return jax.tree.map(upd, bank_states, value)


def _tree_where(pred, a, b):
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def delay_schedule(key, round_id, n: int, max_delay: int) -> jax.Array:
    """Per-(client, round) return delays, uniform over [1, max_delay] rounds.

    Deterministic in (key, round_id, client id) and drawn on a salt stream
    disjoint from the local-step RNG folds, so enabling async never perturbs
    the per-step sample draws."""
    if max_delay == 1:
        return jnp.ones((n,), jnp.int32)
    k = jax.random.fold_in(jax.random.fold_in(key, 0x0DE1A7), round_id)
    return jax.random.randint(k, (n,), 1, max_delay + 1).astype(jnp.int32)


def init_async_state(bank_states, server, n: int) -> dict:
    """Initial async-execution state around a freshly initialized bank.

    Keys:
      bank            [N, ...] pytree — each client's latest local state
      pending         [N, ...] pytree — the in-flight update awaiting arrival
      last_sync       int32 [N] — round of last server-state pull
      in_flight       bool  [N] — client is computing / update not yet landed
      dispatch_round  int32 [N] — round the current flight started
      return_round    int32 [N] — round the pending update arrives (NEVER
                      when idle)
      anchor          unbatched client state — the server's current global
                      model (last broadcast value; delay-adaptive scaling
                      interpolates toward it)
      server          the algorithm's server state
    """
    uniform = jnp.full((n,), 1.0 / n, jnp.float32)
    return {
        "bank": bank_states,
        # a real copy: pending must not alias the bank's buffers, the round
        # program donates both
        "pending": jax.tree.map(jnp.copy, bank_states),
        "last_sync": jnp.zeros((n,), jnp.int32),
        "in_flight": jnp.zeros((n,), bool),
        "dispatch_round": jnp.zeros((n,), jnp.int32),
        "return_round": jnp.full((n,), NEVER, jnp.int32),
        "anchor": weighted_mean(bank_states, uniform),
        "server": server,
    }


def make_async_round(local_step_ids: Callable, sync_update: Callable,
                     q: int, *, sync_mode: str = "broadcast",
                     staleness_decay: float = 0.0,
                     max_staleness: float = float("inf"),
                     max_delay: int = 1,
                     delay_eta: float = 0.0) -> Callable:
    """Build the asynchronous round program: arrivals → gate → server step →
    dispatch.

    One call advances the simulation by one server round ``round_id``:

      1. **Arrivals** — every in-flight update whose ``return_round`` is due
         lands. Its observed staleness is ``tau = round_id -
         dispatch_round`` (the rounds elapsed since the client pulled the
         server state).
      2. **Bounded-staleness gate** — arrivals with ``tau > max_staleness``
         are dropped (their compute is discarded; the client still re-syncs
         so it cannot stay stale forever). Accepted arrivals aggregate with
         the ``(1 + tau)^-staleness_decay`` weights of
         :func:`staleness_weights`.
      3. **Server step** — ``sync_update`` maps the aggregate to the new
         global model; with ``delay_eta > 0`` the movement away from the
         previous global model (``anchor``) is scaled by the delay-adaptive
         factor ``1 / (1 + delay_eta * max(mean_tau - 1, 0))`` — staler
         cohorts take smaller server steps (Jiao et al., arXiv:2212.10048).
         ``broadcast`` pushes the result to every idle client,
         ``participants`` only to the clients that just arrived. A round
         with no arrivals leaves the server untouched.
      4. **Dispatch** — the sampled cohort ``ids`` starts the q local steps.
         Clients still in flight are ineligible (their row of the cohort
         compute is masked out — overlapping cohorts); eligible clients
         store the computed update in the pending buffer with a return round
         ``round_id + delay``, ``delay`` ~ U[1, max_delay]
         (:func:`delay_schedule`).

    With ``max_delay=1``, ``max_staleness=inf``, ``delay_eta=0`` every
    update returns next round with staleness 1 and the program reproduces
    the synchronous path exactly (tests/test_async.py).

    Returns ``round_fn(state, ids, batches_q, key, round_id) -> (state,
    stats)`` over the :func:`init_async_state` dict; ``stats`` carries
    ``arrived/accepted/dropped`` counts, ``mean_staleness``, ``eta_scale``,
    ``dispatched``, and the per-client ``staleness`` vector (int32 [N], the
    accepted arrival's tau, -1 elsewhere) for histogramming.
    """
    if sync_mode not in SYNC_MODES:
        raise ValueError(f"sync_mode must be one of {SYNC_MODES}, "
                         f"got {sync_mode!r}")
    if q < 1:
        raise ValueError(f"round needs q >= 1 local steps, got {q}")
    if max_delay < 1:
        raise ValueError(f"max_delay must be >= 1 round, got {max_delay}")
    if max_staleness <= 0:
        raise ValueError("async rounds need max_staleness > 0 (use the "
                         "synchronous make_population_round for the "
                         "max_staleness=0 setting)")

    def round_fn(state, ids, batches_q, key, round_id):
        bank, pending = state["bank"], state["pending"]
        last_sync, in_flight = state["last_sync"], state["in_flight"]
        disp, ret = state["dispatch_round"], state["return_round"]
        anchor, server = state["anchor"], state["server"]
        n = last_sync.shape[0]

        # 1. arrivals + 2. bounded-staleness gate
        arrived = in_flight & (ret <= round_id)
        tau = jnp.maximum(round_id - disp, 0).astype(jnp.float32)
        accept = arrived & (tau <= max_staleness)
        n_acc = accept.sum()
        has = n_acc > 0
        w = accept.astype(jnp.float32) * (1.0 + tau) ** (-staleness_decay)
        w = w / jnp.maximum(w.sum(), 1e-12)
        # no-arrival rounds aggregate the anchor (result discarded below)
        avg = _tree_where(has, weighted_mean(pending, w), anchor)

        # 3. server step (+ delay-adaptive scaling of the model movement)
        new_client, new_server = sync_update(server, avg)
        mean_tau = jnp.where(has, (accept * tau).sum()
                             / jnp.maximum(n_acc, 1), 0.0)
        scale = 1.0 / (1.0 + delay_eta * jnp.maximum(mean_tau - 1.0, 0.0))
        if delay_eta > 0.0:
            new_client = jax.tree.map(
                lambda a, c: (a.astype(jnp.float32) + scale
                              * (c.astype(jnp.float32)
                                 - a.astype(jnp.float32))).astype(c.dtype),
                anchor, new_client)
        server = _tree_where(has, new_server, server)
        anchor = _tree_where(has, new_client, anchor)
        if sync_mode == "broadcast":
            sync_rows = ~(in_flight & ~arrived)   # everyone not mid-flight
        else:
            # returners only — dropped arrivals re-sync too, so a client
            # can never be wedged permanently past the staleness bound
            sync_rows = arrived
        sync_rows = sync_rows & has               # no arrivals → no write
        bank = _rows_where(bank, sync_rows, anchor)
        last_sync = jnp.where(sync_rows, round_id, last_sync)
        in_flight = in_flight & ~arrived
        ret = jnp.where(arrived, NEVER, ret)

        # 4. dispatch the cohort (in-flight members are ineligible)
        eligible = ~in_flight[ids]
        cur = gather(bank, ids)

        def body(carry, batch):
            st, srv = carry
            st, srv = local_step_ids(st, srv, batch, key, ids)
            return (st, srv), None

        (cur, server), _ = jax.lax.scan(body, (cur, server), batches_q)
        delay = delay_schedule(key, round_id, n, max_delay)[ids]
        pending = scatter_where(pending, ids, cur, eligible)
        # the bank row mirrors the client's own latest local state (same
        # meaning as the sync path's post-round scatter); the server never
        # reads it before the arrival lands from `pending`
        bank = scatter_where(bank, ids, cur, eligible)
        in_flight = in_flight.at[ids].set(True)   # eligible start, rest stay
        disp = disp.at[ids].set(jnp.where(eligible, round_id, disp[ids]))
        ret = ret.at[ids].set(jnp.where(eligible, round_id + delay,
                                        ret[ids]))

        state = {"bank": bank, "pending": pending, "last_sync": last_sync,
                 "in_flight": in_flight, "dispatch_round": disp,
                 "return_round": ret, "anchor": anchor, "server": server}
        stats = {"arrived": arrived.sum().astype(jnp.int32),
                 "accepted": n_acc.astype(jnp.int32),
                 "dropped": (arrived.sum() - n_acc).astype(jnp.int32),
                 "mean_staleness": mean_tau,
                 "eta_scale": scale.astype(jnp.float32),
                 "dispatched": eligible.sum().astype(jnp.int32),
                 "staleness": jnp.where(accept, tau.astype(jnp.int32), -1)}
        return state, stats

    return round_fn
