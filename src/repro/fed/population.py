"""Client population bank: N persistent client states, O(C) per-round compute.

The seed runtime hard-wired "population = the vmapped leading axis": partial
participation ran ALL M clients and masked the inactive ones, so a
10%-participation round cost a full round and M was capped by what one
vmap/jit fits. This module decouples the two scales:

  * a ``ClientPopulation`` bank holds N client states (N in the
    hundreds/thousands) as ONE stacked pytree plus per-client bookkeeping
    (``last_sync``: the round at which each client last received the server
    state);
  * each round, a ``CohortSampler`` (``repro.fed.sampling``) picks C ids;
  * the round program is gather → fused-scan-round → scatter: take the C
    sampled states out of the bank, run the q local steps as one
    ``lax.scan`` (the same body the round engine uses), and write the
    results back. The program jits ONCE for cohort shape [C, ...] — compute
    scales with the cohort, not the population.

Sync modes (who receives the post-aggregation server state):

  broadcast     — every client in the bank (the classic FedAvg simulation
                  assumption, and exactly the legacy masked-participation
                  semantics: inactive clients idle at the current server
                  state). Staleness is identically zero.
  participants  — only the aggregating cohort. Clients then carry genuinely
                  stale models between participations — the asynchronous /
                  intermittent-availability regime (Jiao et al.,
                  arXiv:2212.10048) — and ``staleness_weights`` can
                  down-weight long-absent clients at aggregation time.

Asynchronous execution (``make_async_round``, PR 3) drops the synchronized-
round assumption entirely: a dispatched client takes ``delay`` rounds to
return its update (``in_flight``/``dispatch_round`` bookkeeping + a
jit-compatible pending-update buffer holding the computed update until it
"arrives"), cohorts overlap (a client sampled while still in flight simply
keeps flying — its delayed update lands when due), the server drops arrivals
older than ``max_staleness`` rounds (bounded-staleness gating) and can scale
its step by the observed cohort staleness (delay-adaptive eta_t, à la Jiao
et al. arXiv:2212.10048). The degenerate setting — every delay exactly one
round, no gating, no delay adaptation — reproduces the synchronous
``make_population_round`` trajectories, making async a strict superset of
the sync path (tests/test_async.py). See docs/async.md for the semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DELAY_MODELS, validate_delay_model
from repro.fed.round import make_multi_round
# weighted_mean moved to the aggregation layer (its canonical home) in the
# topology refactor; re-exported here because it predates the move as this
# module's public API
from repro.fed.topology import as_aggregator, weighted_mean  # noqa: F401

SYNC_MODES = ("broadcast", "participants")

# return_round sentinel for clients with no pending update in flight
NEVER = jnp.iinfo(jnp.int32).max


# ------------------------------------------------------------ bank primitives

def gather(bank_states, ids):
    """Select cohort rows: [N, ...] pytree -> [C, ...] pytree."""
    return jax.tree.map(lambda a: jnp.take(a, ids, axis=0), bank_states)


def resolve_last_wins(ids, values, keep=None):
    """Rewrite duplicate-id cohort slots so every writer of a row carries
    the LAST (kept) slot's value.

    ``.at[ids].set`` with duplicate indices has no ordering guarantee under
    XLA — which slot lands is backend/compiler-dependent. After this
    resolution every slot j writing row ``ids[j]`` holds the value of the
    last slot j' with ``ids[j'] == ids[j]`` (and ``keep[j']``, when a keep
    mask is given), so the scatter result is order-independent. Returns
    ``(values, wins)`` where ``wins[j]`` is False only when no kept slot
    writes row ``ids[j]`` (the row must stay untouched). O(C^2) in the
    cohort size — negligible next to the round compute."""
    c = ids.shape[0]
    pos = jnp.arange(c)
    same = ids[:, None] == ids[None, :]
    if keep is not None:
        same = same & keep[None, :]
    winner = jnp.max(jnp.where(same, pos[None, :], -1), axis=1)
    wins = winner >= 0
    src = jnp.maximum(winner, 0)
    return jax.tree.map(lambda v: jnp.take(v, src, axis=0), values), wins


def scatter(bank_states, ids, values):
    """Write cohort rows back: bank[ids] = values; later duplicates win,
    deterministically (:func:`resolve_last_wins` — a raw duplicate-index
    ``.at[].set`` could land either slot depending on the backend)."""
    values, _ = resolve_last_wins(ids, values)
    return jax.tree.map(lambda a, v: a.at[ids].set(v.astype(a.dtype)),
                        bank_states, values)


def broadcast(bank_states, value):
    """Overwrite every bank row with one (unbatched) client state."""
    return jax.tree.map(
        lambda a, v: jnp.broadcast_to(v[None].astype(a.dtype), a.shape),
        bank_states, value)


def cohort_staleness_weights(last_sync_c, round_id, decay: float):
    """:func:`staleness_weights` from the ALREADY-GATHERED cohort slice
    ``last_sync_c`` (int32 [C]) — the form the host-spill tier uses, where
    the [N] vector lives in host memory and only the cohort rows travel."""
    stale = jnp.maximum(round_id - last_sync_c, 0).astype(jnp.float32)
    w = (1.0 + stale) ** (-decay)
    return w / jnp.maximum(w.sum(), 1e-12)


def staleness_weights(last_sync, ids, round_id, decay: float):
    """Aggregation weights for a cohort, down-weighting stale members.

    Client i's staleness is ``round_id - last_sync[i]`` — the number of
    rounds since it last pulled the server state. Weights are
    ``(1 + staleness)^-decay``, normalized over the cohort; ``decay = 0``
    (or an all-fresh cohort, e.g. broadcast sync mode) recovers the plain
    uniform average.
    """
    return cohort_staleness_weights(last_sync[ids], round_id, decay)


# ------------------------------------------------------------ the population

@dataclasses.dataclass
class ClientPopulation:
    """N stacked client states + per-client sync/flight bookkeeping.

    ``in_flight``/``dispatch_round`` are the async-execution fields: client i
    with ``in_flight[i]`` is busy computing an update it dispatched at round
    ``dispatch_round[i]`` and cannot start new work until that update
    arrives (``make_async_round``). The synchronous path never sets them.
    """
    states: Any                  # pytree, every leaf with leading axis N
    last_sync: jax.Array         # int32 [N]: round of last server-state pull
    n: int
    in_flight: Optional[jax.Array] = None      # bool  [N]
    dispatch_round: Optional[jax.Array] = None  # int32 [N]

    def __post_init__(self):
        if self.in_flight is None:
            self.in_flight = jnp.zeros((self.n,), bool)
        if self.dispatch_round is None:
            self.dispatch_round = jnp.zeros((self.n,), jnp.int32)

    @classmethod
    def create(cls, init_one: Callable[[jax.Array, Any], Any], key,
               batches_n, n: int) -> "ClientPopulation":
        """vmap ``init_one(client_key, client_batch)`` over N clients."""
        states = jax.vmap(init_one)(jax.random.split(key, n), batches_n)
        return cls(states=states, last_sync=jnp.zeros((n,), jnp.int32), n=n)

    def gather(self, ids):
        return gather(self.states, ids)

    def scatter(self, ids, values):
        return dataclasses.replace(self, states=scatter(self.states, ids,
                                                        values))


# ------------------------------------------------------------ fused round

def make_population_round(local_step_ids: Callable, sync_update: Callable,
                          q: int, *, sync_mode: str = "broadcast",
                          staleness_decay: float = 0.0,
                          codec=None) -> Callable:
    """Build the gather → scan-round → aggregate → scatter program.

    ``local_step_ids(states_c, server, batch, key, ids)`` is the per-step
    function over the COHORT (any client-vmapping is its own; ``ids`` are the
    global client ids, so per-client RNG folds match the full-population
    path). ``sync_update(server, avg_state)`` maps the aggregated client
    state to ``(new_client_state, new_server)`` (unbatched client state) —
    or pass a ``repro.fed.topology.Aggregator`` directly; a bare callable
    wraps into the star default (:func:`as_aggregator`), whose ops are the
    pre-refactor ones bit-for-bit.

    Returns ``round_fn(bank_states, last_sync, server, ids, batches_q, key,
    round_id) -> (bank_states, last_sync, server)`` — jit-compatible, one
    compile per cohort shape [C, ...]: q local steps on the C gathered
    states, a (staleness-weighted) cohort aggregate, the server update, and
    the write-back dictated by ``sync_mode``.

    With a lossy ``codec`` (``repro.fed.compress.Codec``) the cohort's
    client→server messages pass through the codec before aggregation (the
    gathered pre-step state is the server-known reference) and the signature
    grows the stacked error-feedback residual bank: ``round_fn(bank_states,
    last_sync, ef_bank, server, ids, batches_q, key, round_id) ->
    (bank_states, last_sync, ef_bank, server)`` (``ef_bank`` is None when
    ``codec.error_feedback`` is off). A lossless codec (or None) keeps the
    original signature and program, bit-identically.
    """
    if sync_mode not in SYNC_MODES:
        raise ValueError(f"sync_mode must be one of {SYNC_MODES}, "
                         f"got {sync_mode!r}")
    if q < 1:
        raise ValueError(f"round needs q >= 1 local steps, got {q}")
    agg = as_aggregator(sync_update, codec=codec)
    codec = agg.codec
    lossy = codec is not None and codec.lossy

    def run_steps(cur, server, ids, batches_q, key):
        def body(carry, batch):
            st, srv = carry
            st, srv = local_step_ids(st, srv, batch, key, ids)
            return (st, srv), None

        # named_scope = pure XLA op metadata: the regions show up in a
        # jax.profiler trace (docs/observability.md), numerics untouched
        with jax.named_scope("round/local_scan"):
            (cur, server), _ = jax.lax.scan(body, (cur, server), batches_q,
                                            length=q)
        return cur, server

    def write_back(bank_states, last_sync, new_client, ids, round_id):
        with jax.named_scope("round/scatter"):
            if sync_mode == "broadcast":
                return (broadcast(bank_states, new_client),
                        jnp.full_like(last_sync, round_id + 1))
            c = ids.shape[0]
            return (scatter(bank_states, ids,
                            jax.tree.map(lambda v: jnp.broadcast_to(
                                v[None], (c,) + v.shape), new_client)),
                    last_sync.at[ids].set(round_id + 1))

    def round_fn(bank_states, last_sync, server, ids, batches_q, key,
                 round_id):
        with jax.named_scope("round/gather"):
            cur = gather(bank_states, ids)
        cur, server = run_steps(cur, server, ids, batches_q, key)
        with jax.named_scope("round/aggregate"):
            w = staleness_weights(last_sync, ids, round_id, staleness_decay)
            new_client, server = agg.reduce(server, cur, weights=w)
        bank_states, last_sync = write_back(bank_states, last_sync,
                                            new_client, ids, round_id)
        return bank_states, last_sync, server

    if not lossy:
        return round_fn

    def round_fn_codec(bank_states, last_sync, ef_bank, server, ids,
                       batches_q, key, round_id):
        with jax.named_scope("round/gather"):
            ref = gather(bank_states, ids)   # server-known dispatch states
        cur, server = run_steps(ref, server, ids, batches_q, key)
        with jax.named_scope("round/codec"):
            ef_c = gather(ef_bank, ids) if ef_bank is not None else None
            recon, ef_c = agg.messages(key, round_id, ids, ref, cur, ef_c)
            if ef_bank is not None:
                ef_bank = scatter(ef_bank, ids, ef_c)
        with jax.named_scope("round/aggregate"):
            w = staleness_weights(last_sync, ids, round_id, staleness_decay)
            new_client, server = agg.reduce(server, recon, weights=w)
        bank_states, last_sync = write_back(bank_states, last_sync,
                                            new_client, ids, round_id)
        return bank_states, last_sync, ef_bank, server

    return round_fn_codec


def make_cohort_round(local_step_ids: Callable, sync_update: Callable,
                      q: int, *, staleness_decay: float = 0.0,
                      codec=None) -> Callable:
    """The cohort-only core of :func:`make_population_round`, for banks the
    device cannot materialize: gather and write-back are the CALLER's
    (``repro.fed.spill.HostSpillBank`` keeps the [N, ...] rows in host
    memory), this program sees only the [C, ...] cohort.

    ``round_fn(cur, last_sync_c, server, ids, batches_q, key, round_id) ->
    (new_client, server)`` where ``cur`` is the gathered cohort states and
    ``last_sync_c`` the gathered int32 [C] slice of the sync bookkeeping.
    The q scanned local steps, the staleness-weighted aggregate and the
    server update are the exact ops of :func:`make_population_round`, so a
    spilled run replays the dense broadcast-mode trajectory (the caller's
    write-back: broadcast ``new_client`` to every row, stamp ``last_sync =
    round_id + 1``). With a lossy ``codec`` the signature grows the
    gathered EF residual slice: ``round_fn(cur, last_sync_c, ef_c, server,
    ids, batches_q, key, round_id) -> (new_client, ef_c, server)``; the
    caller scatters ``ef_c`` back into its EF bank."""
    if q < 1:
        raise ValueError(f"round needs q >= 1 local steps, got {q}")
    agg = as_aggregator(sync_update, codec=codec)
    codec = agg.codec
    lossy = codec is not None and codec.lossy

    def run_steps(cur, server, ids, batches_q, key):
        def body(carry, batch):
            st, srv = carry
            st, srv = local_step_ids(st, srv, batch, key, ids)
            return (st, srv), None

        with jax.named_scope("round/local_scan"):
            (cur, server), _ = jax.lax.scan(body, (cur, server), batches_q,
                                            length=q)
        return cur, server

    def round_fn(cur, last_sync_c, server, ids, batches_q, key, round_id):
        cur, server = run_steps(cur, server, ids, batches_q, key)
        with jax.named_scope("round/aggregate"):
            w = cohort_staleness_weights(last_sync_c, round_id,
                                         staleness_decay)
            new_client, server = agg.reduce(server, cur, weights=w)
        return new_client, server

    if not lossy:
        return round_fn

    def round_fn_codec(cur, last_sync_c, ef_c, server, ids, batches_q, key,
                       round_id):
        ref = cur                     # server-known dispatch states
        cur, server = run_steps(ref, server, ids, batches_q, key)
        with jax.named_scope("round/codec"):
            recon, ef_c = agg.messages(key, round_id, ids, ref, cur, ef_c)
        with jax.named_scope("round/aggregate"):
            w = cohort_staleness_weights(last_sync_c, round_id,
                                         staleness_decay)
            new_client, server = agg.reduce(server, recon, weights=w)
        return new_client, ef_c, server

    return round_fn_codec


# ------------------------------------------------------------ async execution

def scatter_where(bank_states, ids, values, keep):
    """Masked cohort write-back: ``bank[ids[j]] = values[j]`` where
    ``keep[j]``; a row none of whose slots are kept stays untouched. The
    last KEPT duplicate wins, deterministically (:func:`resolve_last_wins`
    — every slot writing a row carries the same value, so the raw
    duplicate-index scatter's ordering ambiguity cannot surface)."""
    values, wins = resolve_last_wins(ids, values, keep)
    def upd(a, v):
        m = wins.reshape((wins.shape[0],) + (1,) * (v.ndim - 1))
        return a.at[ids].set(jnp.where(m, v.astype(a.dtype), a[ids]))
    return jax.tree.map(upd, bank_states, values)


def _rows_where(bank_states, mask, value):
    """Overwrite the bank rows selected by ``mask`` ([N] bool) with one
    unbatched client state."""
    def upd(a, v):
        m = mask.reshape((mask.shape[0],) + (1,) * (a.ndim - 1))
        return jnp.where(m, v[None].astype(a.dtype), a)
    return jax.tree.map(upd, bank_states, value)


def _tree_where(pred, a, b):
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def delay_schedule(key, round_id, n: int, max_delay: int) -> jax.Array:
    """Per-(client, round) return delays, uniform over [1, max_delay] rounds.

    Deterministic in (key, round_id, client id) and drawn on a salt stream
    disjoint from the local-step RNG folds, so enabling async never perturbs
    the per-step sample draws."""
    if max_delay == 1:
        return jnp.ones((n,), jnp.int32)
    k = jax.random.fold_in(jax.random.fold_in(key, 0x0DE1A7), round_id)
    return jax.random.randint(k, (n,), 1, max_delay + 1).astype(jnp.int32)


# ------------------------------------------------------------ delay models

# salt streams for the heterogeneous delay draws — disjoint from the
# local-step RNG folds and from the uniform delay_schedule salt (0x0DE1A7)
_TIER_ASSIGN_SALT = 0x71E5A
_TIER_DRAW_SALT = 0x71D0D
_LOGNORMAL_SALT = 0x10C4A


def _tier_sizes(n: int, fracs: Tuple[float, ...]) -> Tuple[int, ...]:
    """Largest-remainder rounding of ``fracs * n`` (sums to exactly n)."""
    raw = [f * n for f in fracs]
    sizes = [int(x) for x in raw]
    order = sorted(range(len(fracs)), key=lambda i: raw[i] - sizes[i],
                   reverse=True)
    for j in range(n - sum(sizes)):
        sizes[order[j % len(sizes)]] += 1
    return tuple(sizes)


def tier_assignment(key, n: int, fracs: Tuple[float, ...]) -> jax.Array:
    """Permanent speed tier of each client: int32 [n] of tier indices.

    Tier SIZES are the largest-remainder rounding of ``fracs * n`` (exact,
    so a 20/60/20 split of 10 clients is 2/6/2); WHICH clients land in
    which tier is a key-seeded permutation — deterministic in (key, n,
    fracs), drawn on its own salt stream so it never perturbs the cohort or
    per-step sample draws."""
    bounds = jnp.cumsum(jnp.asarray(_tier_sizes(n, fracs), jnp.int32))
    slot_tier = jnp.searchsorted(bounds, jnp.arange(n),
                                 side="right").astype(jnp.int32)
    perm = jax.random.permutation(
        jax.random.fold_in(key, _TIER_ASSIGN_SALT), n)
    return jnp.zeros((n,), jnp.int32).at[perm].set(slot_tier)


@dataclasses.dataclass(frozen=True)
class DelayModel:
    """Pluggable per-client dispatch-return delay model (device speeds).

    ``schedule(key, round_id, n)`` yields the int32 [n] vector of return
    delays (in rounds) a dispatch at ``round_id`` would observe; every model
    is deterministic in (key, round_id, client id) and draws on salt
    streams disjoint from the local-step RNG folds, so switching models
    never perturbs the per-step sample draws. Models:

      uniform    — delay ~ U[1, max_delay] per (client, round). The PR 3
                   behaviour, bit-identical (same :func:`delay_schedule`
                   draw), and the default.
      tiers      — each client is PERMANENTLY assigned to a speed tier
                   (:func:`tier_assignment` over ``tier_fracs``, e.g.
                   20/60/20 fast/medium/straggler) and draws its delay
                   uniformly from its tier's ``(lo, hi)`` range in
                   ``tier_delays`` each round.
      lognormal  — a continuous permanent per-client compute+comm latency
                   ``exp(mu + sigma * z_i)``, ``z_i ~ N(0, 1)``, quantized
                   to rounds (ceil) and clipped to [1, max_delay].
      trace      — per-dispatch delays replayed from a recorded table
                   (``table[round % horizon, client]``; parsed from the
                   JSONL trace's optional per-client ``"delay"`` field by
                   ``repro.fed.sampling.load_delay_trace``).

    Use :func:`make_delay_model` to build one with validation.
    """
    name: str = "uniform"
    max_delay: int = 1
    tier_fracs: Tuple[float, ...] = (0.2, 0.6, 0.2)
    tier_delays: Tuple[Tuple[int, int], ...] = ((1, 1), (2, 4), (4, 8))
    mu: float = 0.0
    sigma: float = 0.5
    table: Optional[Any] = None     # np [horizon, n] int32 (trace model)
    # resolve() caches of the permanent per-client quantities:
    client_lo: Optional[Any] = None      # tiers: per-client delay lo bound
    client_hi: Optional[Any] = None      # tiers: per-client delay hi bound
    client_delay: Optional[Any] = None   # lognormal: whole delay vector

    @property
    def bound(self) -> int:
        """The largest delay this model can emit (histogram sizing)."""
        if self.name == "tiers":
            return max(hi for _, hi in self.tier_delays)
        if self.name == "trace":
            return int(self.table.max())
        return self.max_delay

    def tiers(self, key, n: int) -> jax.Array:
        """The permanent tier of each client (tiers model)."""
        return tier_assignment(key, n, self.tier_fracs)

    def resolve(self, key, n: int) -> "DelayModel":
        """Precompute the PERMANENT per-client quantities for a known run
        key — the tiers model's per-client [lo, hi] range, the lognormal
        model's whole delay vector — so the jitted round program closes
        over them as constants instead of rederiving them every round.
        Draws are unchanged: ``resolve(key, n).schedule(key, r, n)`` ==
        ``schedule(key, r, n)`` bitwise; only pass the same key the round
        program will receive."""
        if self.name == "tiers":
            lo, hi = self._tier_ranges(key, n)
            return dataclasses.replace(self, client_lo=lo, client_hi=hi)
        if self.name == "lognormal":
            return dataclasses.replace(
                self, client_delay=self._lognormal(key, n))
        return self

    def _tier_ranges(self, key, n: int):
        """Per-client permanent [lo, hi] delay range (tiers model)."""
        tier = tier_assignment(key, n, self.tier_fracs)
        lo = jnp.asarray([d[0] for d in self.tier_delays], jnp.int32)[tier]
        hi = jnp.asarray([d[1] for d in self.tier_delays], jnp.int32)[tier]
        return lo, hi

    def _lognormal(self, key, n: int) -> jax.Array:
        z = jax.random.normal(
            jax.random.fold_in(key, _LOGNORMAL_SALT), (n,))
        lat = jnp.exp(self.mu + self.sigma * z)
        return jnp.clip(jnp.ceil(lat), 1, self.max_delay).astype(jnp.int32)

    def schedule(self, key, round_id, n: int) -> jax.Array:
        """int32 [n] return delays for a dispatch at ``round_id``."""
        if self.name == "uniform":
            return delay_schedule(key, round_id, n, self.max_delay)
        if self.name == "tiers":
            if self.client_lo is not None:
                lo, hi = self.client_lo, self.client_hi
            else:
                lo, hi = self._tier_ranges(key, n)
            k = jax.random.fold_in(
                jax.random.fold_in(key, _TIER_DRAW_SALT), round_id)
            u = jax.random.uniform(k, (n,))
            return lo + (u * (hi - lo + 1).astype(jnp.float32)).astype(
                jnp.int32)
        if self.name == "lognormal":
            if self.client_delay is not None:
                return self.client_delay
            return self._lognormal(key, n)
        if self.name == "trace":
            if self.table.shape[1] != n:
                raise ValueError(
                    f"trace delay table covers {self.table.shape[1]} "
                    f"clients but the population has {n} (jax gather "
                    f"would silently clip the out-of-range ids)")
            tab = jnp.asarray(self.table, jnp.int32)
            return tab[round_id % tab.shape[0]]
        raise ValueError(f"unknown delay model {self.name!r}; "
                         f"known: {DELAY_MODELS}")


def accum_staleness_hist(hist, taus) -> "np.ndarray":
    """Accumulate accepted-staleness values into a growing int64 histogram
    (index = staleness in rounds). Host-side numpy — the one accumulation
    shared by ``FedDriver`` and the launchers, so overall and per-tier
    histograms can never drift in semantics. Returns the (possibly
    reallocated) histogram; start from ``np.zeros(0, np.int64)``."""
    h = np.bincount(np.asarray(taus)).astype(np.int64)
    if h.size > hist.size:
        h[:hist.size] += hist
        return h
    hist = hist.copy()
    hist[:h.size] += h
    return hist


def accum_tier_hists(hist_by_tier: dict, stale, tier_of,
                     n_tiers: int) -> dict:
    """Split one round's staleness vector (int32 [N], accepted tau or -1)
    by permanent speed tier and accumulate each slice into
    ``hist_by_tier[tier]`` via :func:`accum_staleness_hist`. The one
    tier-bucketing implementation shared by ``FedDriver`` and the
    launchers. Returns the updated dict."""
    for ti in range(n_tiers):
        acc = stale[(stale >= 0) & (tier_of == ti)]
        if acc.size:
            hist_by_tier[ti] = accum_staleness_hist(
                hist_by_tier.get(ti, np.zeros(0, np.int64)), acc)
    return hist_by_tier


def parse_tier_spec(spec: str):
    """Parse a ``frac:lo:hi[,frac:lo:hi...]`` CLI tier spec, e.g.
    ``0.2:1:1,0.6:2:4,0.2:4:8`` → ``((0.2, 0.6, 0.2),
    ((1, 1), (2, 4), (4, 8)))``."""
    fracs, delays = [], []
    for part in spec.split(","):
        fields = part.split(":")
        if len(fields) != 3:
            raise ValueError(f"bad tier spec segment {part!r} (want "
                             f"frac:lo:hi, e.g. 0.2:1:1,0.6:2:4,0.2:4:8)")
        f, lo, hi = fields
        fracs.append(float(f))
        delays.append((int(lo), int(hi)))
    return tuple(fracs), tuple(delays)


def make_delay_model(name: str = "uniform", max_delay: int = 1, *,
                     tier_fracs=None, tier_delays=None, mu: float = 0.0,
                     sigma: float = 0.5, table=None) -> DelayModel:
    """Build a validated :class:`DelayModel` (see its docstring for the
    model semantics); ``tier_fracs``/``tier_delays`` default to the 20/60/20
    fast/medium/straggler split with ranges (1,1)/(2,4)/(4,8)."""
    fr = tuple(tier_fracs) if tier_fracs is not None else (0.2, 0.6, 0.2)
    td = (tuple((int(lo), int(hi)) for lo, hi in tier_delays)
          if tier_delays is not None else ((1, 1), (2, 4), (4, 8)))
    validate_delay_model(name, max_delay, fr, td, sigma)
    kw = {}
    if name == "tiers":
        kw = {"tier_fracs": fr, "tier_delays": td}
    elif name == "lognormal":
        kw = {"mu": float(mu), "sigma": float(sigma)}
    elif name == "trace":
        if table is None:
            raise ValueError("delay model 'trace' needs a [horizon, n] "
                             "delay table (repro.fed.sampling."
                             "load_delay_trace over the JSONL trace's "
                             "per-client 'delay' field, docs/async.md)")
        if getattr(table, "ndim", 0) != 2 or table.size == 0:
            raise ValueError(f"delay table must be a non-empty "
                             f"[horizon, n] array, got shape "
                             f"{getattr(table, 'shape', None)}")
        if int(table.min()) < 1:
            raise ValueError(f"trace delays must be >= 1 round, "
                             f"min is {int(table.min())}")
        kw = {"table": table}
    return DelayModel(name=name, max_delay=max_delay, **kw)


def delay_model_from_config(pcfg) -> DelayModel:
    """The :class:`DelayModel` a ``PopulationConfig`` describes (loads the
    per-client delay table from ``pcfg.trace_file`` for the trace model)."""
    table = None
    if pcfg.delay_model == "trace":
        from repro.fed.sampling import load_delay_trace
        table = load_delay_trace(pcfg.trace_file, pcfg.n)
    return make_delay_model(
        pcfg.delay_model, pcfg.max_delay, tier_fracs=pcfg.tier_fracs,
        tier_delays=pcfg.tier_delays, mu=pcfg.delay_mu,
        sigma=pcfg.delay_sigma, table=table)


def init_async_state(bank_states, server, n: int, codec=None) -> dict:
    """Initial async-execution state around a freshly initialized bank.

    Keys:
      bank            [N, ...] pytree — each client's latest local state
      pending         [N, ...] pytree — the in-flight update awaiting arrival
      last_sync       int32 [N] — round of last server-state pull
      in_flight       bool  [N] — client is computing / update not yet landed
      dispatch_round  int32 [N] — round the current flight started
      return_round    int32 [N] — round the pending update arrives (NEVER
                      when idle)
      anchor          unbatched client state — the server's current global
                      model (last broadcast value; delay-adaptive scaling
                      interpolates toward it)
      server          the algorithm's server state
      ef              [N, ...] f32 pytree — per-client error-feedback
                      residuals; present only when ``codec`` is a stateful
                      ``repro.fed.compress.Codec`` (lossy + error feedback)
    """
    uniform = jnp.full((n,), 1.0 / n, jnp.float32)
    state = {
        "bank": bank_states,
        # a real copy: pending must not alias the bank's buffers, the round
        # program donates both
        "pending": jax.tree.map(jnp.copy, bank_states),
        "last_sync": jnp.zeros((n,), jnp.int32),
        "in_flight": jnp.zeros((n,), bool),
        "dispatch_round": jnp.zeros((n,), jnp.int32),
        "return_round": jnp.full((n,), NEVER, jnp.int32),
        "anchor": weighted_mean(bank_states, uniform),
        "server": server,
    }
    if codec is not None and codec.stateful:
        from repro.fed.compress import zeros_ef
        state["ef"] = zeros_ef(codec, bank_states)
    return state


def make_async_round(local_step_ids: Callable, sync_update: Callable,
                     q: int, *, sync_mode: str = "broadcast",
                     staleness_decay: float = 0.0,
                     max_staleness: float = float("inf"),
                     max_delay: int = 1,
                     delay_eta: float = 0.0,
                     delay: Optional[DelayModel] = None,
                     codec=None) -> Callable:
    """Build the asynchronous round program: arrivals → gate → server step →
    dispatch.

    One call advances the simulation by one server round ``round_id``:

      1. **Arrivals** — every in-flight update whose ``return_round`` is due
         lands. Its observed staleness is ``tau = round_id -
         dispatch_round`` (the rounds elapsed since the client pulled the
         server state).
      2. **Bounded-staleness gate** — arrivals with ``tau > max_staleness``
         are dropped (their compute is discarded; the client still re-syncs
         so it cannot stay stale forever). Accepted arrivals aggregate with
         the ``(1 + tau)^-staleness_decay`` weights of
         :func:`staleness_weights`.
      3. **Server step** — ``sync_update`` maps the aggregate to the new
         global model; with ``delay_eta > 0`` the movement away from the
         previous global model (``anchor``) is scaled by the delay-adaptive
         factor ``1 / (1 + delay_eta * max(mean_tau - 1, 0))`` — staler
         cohorts take smaller server steps (Jiao et al., arXiv:2212.10048).
         ``broadcast`` pushes the result to every idle client,
         ``participants`` only to the clients that just arrived. A round
         with no arrivals leaves the server untouched.
      4. **Dispatch** — the sampled cohort ``ids`` starts the q local steps.
         Clients still in flight are ineligible (their row of the cohort
         compute is masked out — overlapping cohorts); eligible clients
         store the computed update in the pending buffer with a return round
         ``round_id + delay``, where ``delay`` comes from the pluggable
         :class:`DelayModel` (default: the uniform U[1, max_delay]
         :func:`delay_schedule` — heterogeneous per-client models via the
         ``delay`` argument).

    With ``max_delay=1``, ``max_staleness=inf``, ``delay_eta=0`` every
    update returns next round with staleness 1 and the program reproduces
    the synchronous path exactly (tests/test_async.py).

    Returns ``round_fn(state, ids, batches_q, key, round_id) -> (state,
    stats)`` over the :func:`init_async_state` dict; ``stats`` carries
    ``arrived/accepted/dropped`` counts, ``mean_staleness``, ``eta_scale``,
    ``dispatched`` (the number of UNIQUE clients that started work this
    round — a duplicate cohort id occupies two slots but dispatches one
    client), ``synced`` (clients that received the new global model this
    round — the downlink count for bytes accounting), and the per-client
    ``staleness`` vector (int32 [N], the accepted arrival's tau, -1
    elsewhere) for histogramming.

    With a lossy ``codec`` (``repro.fed.compress.Codec``) the message a
    dispatch parks in ``pending`` is the codec's reconstruction of the
    client's update against its server-known dispatch state — what later
    arrives and aggregates IS the compressed message — and the per-client
    EF residuals ride in ``state["ef"]`` (:func:`init_async_state` with the
    codec), updated only for the clients that actually dispatched: a cohort
    slot masked out because its client is still in flight is a no-op on the
    residual too.
    """
    if sync_mode not in SYNC_MODES:
        raise ValueError(f"sync_mode must be one of {SYNC_MODES}, "
                         f"got {sync_mode!r}")
    if q < 1:
        raise ValueError(f"round needs q >= 1 local steps, got {q}")
    if max_delay < 1:
        raise ValueError(f"max_delay must be >= 1 round, got {max_delay}")
    if max_staleness <= 0:
        raise ValueError("async rounds need max_staleness > 0 (use the "
                         "synchronous make_population_round for the "
                         "max_staleness=0 setting)")
    dm = delay if delay is not None else make_delay_model("uniform",
                                                          max_delay)
    agg = as_aggregator(sync_update, codec=codec)
    codec = agg.codec
    lossy = codec is not None and codec.lossy

    def round_fn(state, ids, batches_q, key, round_id):
        bank, pending = state["bank"], state["pending"]
        last_sync, in_flight = state["last_sync"], state["in_flight"]
        disp, ret = state["dispatch_round"], state["return_round"]
        anchor, server = state["anchor"], state["server"]
        ef = state.get("ef")
        n = last_sync.shape[0]

        # 1. arrivals + 2. bounded-staleness gate
        arrived = in_flight & (ret <= round_id)
        tau = jnp.maximum(round_id - disp, 0).astype(jnp.float32)
        accept = arrived & (tau <= max_staleness)
        n_acc = accept.sum()
        has = n_acc > 0
        w = accept.astype(jnp.float32) * (1.0 + tau) ** (-staleness_decay)
        w = w / jnp.maximum(w.sum(), 1e-12)
        # no-arrival rounds aggregate the anchor (result discarded below)
        with jax.named_scope("round/aggregate"):
            avg = _tree_where(has, agg.combine(pending, weights=w), anchor)

        # 3. server step (+ delay-adaptive scaling of the model movement)
        new_client, new_server = agg.server_step(server, avg)
        mean_tau = jnp.where(has, (accept * tau).sum()
                             / jnp.maximum(n_acc, 1), 0.0)
        scale = 1.0 / (1.0 + delay_eta * jnp.maximum(mean_tau - 1.0, 0.0))
        if delay_eta > 0.0:
            new_client = jax.tree.map(
                lambda a, c: (a.astype(jnp.float32) + scale
                              * (c.astype(jnp.float32)
                                 - a.astype(jnp.float32))).astype(c.dtype),
                anchor, new_client)
        server = _tree_where(has, new_server, server)
        anchor = _tree_where(has, new_client, anchor)
        if sync_mode == "broadcast":
            sync_rows = ~(in_flight & ~arrived)   # everyone not mid-flight
        else:
            # returners only — dropped arrivals re-sync too, so a client
            # can never be wedged permanently past the staleness bound
            sync_rows = arrived
        sync_rows = sync_rows & has               # no arrivals → no write
        bank = _rows_where(bank, sync_rows, anchor)
        last_sync = jnp.where(sync_rows, round_id, last_sync)
        in_flight = in_flight & ~arrived
        ret = jnp.where(arrived, NEVER, ret)

        # 4. dispatch the cohort (in-flight members are ineligible)
        eligible = ~in_flight[ids]
        with jax.named_scope("round/gather"):
            cur = gather(bank, ids)
        ref = cur                     # server-known dispatch states

        def body(carry, batch):
            st, srv = carry
            st, srv = local_step_ids(st, srv, batch, key, ids)
            return (st, srv), None

        with jax.named_scope("round/local_scan"):
            (cur, server), _ = jax.lax.scan(body, (cur, server), batches_q)
        if lossy:
            # the message fixed at send time: what arrives (and aggregates)
            # from `pending` is the codec's reconstruction; residuals update
            # only where the dispatch actually happened
            ef_c = gather(ef, ids) if ef is not None else None
            recon, ef_c_new = agg.messages(key, round_id, ids, ref, cur,
                                           ef_c)
            cur = recon
            if ef is not None:
                ef = scatter_where(ef, ids, ef_c_new, eligible)
        delays = dm.schedule(key, round_id, n)[ids]
        with jax.named_scope("round/scatter"):
            pending = scatter_where(pending, ids, cur, eligible)
            # the bank row mirrors the client's own latest local state (same
            # meaning as the sync path's post-round scatter); the server
            # never reads it before the arrival lands from `pending`
            bank = scatter_where(bank, ids, cur, eligible)
        new_flight = in_flight.at[ids].set(True)  # eligible start, rest stay
        # the UNIQUE clients that started work: duplicate cohort ids (trace
        # shortfall cycling) occupy two slots but dispatch one client
        started = new_flight & ~in_flight
        in_flight = new_flight
        disp = disp.at[ids].set(jnp.where(eligible, round_id, disp[ids]))
        ret = ret.at[ids].set(jnp.where(eligible, round_id + delays,
                                        ret[ids]))

        state = {"bank": bank, "pending": pending, "last_sync": last_sync,
                 "in_flight": in_flight, "dispatch_round": disp,
                 "return_round": ret, "anchor": anchor, "server": server}
        if ef is not None:
            state["ef"] = ef
        stats = {"arrived": arrived.sum().astype(jnp.int32),
                 "accepted": n_acc.astype(jnp.int32),
                 "dropped": (arrived.sum() - n_acc).astype(jnp.int32),
                 "mean_staleness": mean_tau,
                 "eta_scale": scale.astype(jnp.float32),
                 "dispatched": started.sum().astype(jnp.int32),
                 "synced": sync_rows.sum().astype(jnp.int32),
                 "staleness": jnp.where(accept, tau.astype(jnp.int32), -1)}
        return state, stats

    return round_fn


# ------------------------------------------------------------- mega-scan tier
#
# R full rounds compiled into ONE donated-carry program (docs/megascan.md).
# The per-round programs above already derive everything round-dependent
# (staleness weights, last_sync stamps, codec RNG folds, delay schedules)
# from the round_id argument, so fusing is pure carry-threading: wrap a
# round program into the (carry, ids, batches_q, key, round_id) shape
# make_multi_round scans.

def make_multi_population_round(round_fn: Callable, *, lossy: bool,
                                cohort_fn: Callable | None = None
                                ) -> Callable:
    """Fuse R synchronous population rounds into one scanned program.

    ``round_fn`` is exactly what :func:`make_population_round` returned
    (``lossy`` says whether it threads the EF bank). Returns
    ``multi(bank_states, last_sync[, ef_bank], server, ids_R, batches_R,
    key, round0)`` -> the same state tuple after rounds ``round0 ..
    round0 + R - 1``, where ``ids_R`` is [R, C] int32 (or None with a
    ``cohort_fn`` drawing in-scan) and ``batches_R`` stacks each round's
    ``batches_q`` on a new leading R axis. Bit-identical to R sequential
    ``round_fn`` calls (tests/test_megascan.py).
    """
    if lossy:
        def one(carry, ids, batches_q, key, round_id):
            return round_fn(*carry, ids, batches_q, key, round_id), None

        multi = make_multi_round(one, cohort_fn=cohort_fn)

        def mega(bank_states, last_sync, ef_bank, server, ids_R, batches_R,
                 key, round0):
            carry, _ = multi((bank_states, last_sync, ef_bank, server),
                             ids_R, batches_R, key, round0)
            return carry

        return mega

    def one(carry, ids, batches_q, key, round_id):
        return round_fn(*carry, ids, batches_q, key, round_id), None

    multi = make_multi_round(one, cohort_fn=cohort_fn)

    def mega(bank_states, last_sync, server, ids_R, batches_R, key, round0):
        carry, _ = multi((bank_states, last_sync, server), ids_R, batches_R,
                         key, round0)
        return carry

    return mega


def make_multi_async_round(round_fn: Callable, *,
                           cohort_fn: Callable | None = None) -> Callable:
    """Fuse R asynchronous rounds (:func:`make_async_round` programs) into
    one scanned program: ``multi(state, ids_R, batches_R, key, round0) ->
    (state, stats_R)`` with every per-round stats field stacked on a new
    leading R axis. The async round is already uniform in ``round_id``
    (round 0 is not special), so the driver chunks from round 0."""
    return make_multi_round(round_fn, cohort_fn=cohort_fn)
