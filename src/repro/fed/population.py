"""Client population bank: N persistent client states, O(C) per-round compute.

The seed runtime hard-wired "population = the vmapped leading axis": partial
participation ran ALL M clients and masked the inactive ones, so a
10%-participation round cost a full round and M was capped by what one
vmap/jit fits. This module decouples the two scales:

  * a ``ClientPopulation`` bank holds N client states (N in the
    hundreds/thousands) as ONE stacked pytree plus per-client bookkeeping
    (``last_sync``: the round at which each client last received the server
    state);
  * each round, a ``CohortSampler`` (``repro.fed.sampling``) picks C ids;
  * the round program is gather → fused-scan-round → scatter: take the C
    sampled states out of the bank, run the q local steps as one
    ``lax.scan`` (the same body the round engine uses), and write the
    results back. The program jits ONCE for cohort shape [C, ...] — compute
    scales with the cohort, not the population.

Sync modes (who receives the post-aggregation server state):

  broadcast     — every client in the bank (the classic FedAvg simulation
                  assumption, and exactly the legacy masked-participation
                  semantics: inactive clients idle at the current server
                  state). Staleness is identically zero.
  participants  — only the aggregating cohort. Clients then carry genuinely
                  stale models between participations — the asynchronous /
                  intermittent-availability regime (Jiao et al.,
                  arXiv:2212.10048) — and ``staleness_weights`` can
                  down-weight long-absent clients at aggregation time.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

SYNC_MODES = ("broadcast", "participants")


# ------------------------------------------------------------ bank primitives

def gather(bank_states, ids):
    """Select cohort rows: [N, ...] pytree -> [C, ...] pytree."""
    return jax.tree.map(lambda a: jnp.take(a, ids, axis=0), bank_states)


def scatter(bank_states, ids, values):
    """Write cohort rows back: bank[ids] = values (later duplicates win)."""
    return jax.tree.map(lambda a, v: a.at[ids].set(v.astype(a.dtype)),
                        bank_states, values)


def broadcast(bank_states, value):
    """Overwrite every bank row with one (unbatched) client state."""
    return jax.tree.map(
        lambda a, v: jnp.broadcast_to(v[None].astype(a.dtype), a.shape),
        bank_states, value)


def weighted_mean(states, w):
    """Weighted client mean over the leading axis (w sums to 1)."""
    return jax.tree.map(
        lambda a: jnp.tensordot(w, a.astype(jnp.float32),
                                axes=1).astype(a.dtype), states)


def staleness_weights(last_sync, ids, round_id, decay: float):
    """Aggregation weights for a cohort, down-weighting stale members.

    Client i's staleness is ``round_id - last_sync[i]`` — the number of
    rounds since it last pulled the server state. Weights are
    ``(1 + staleness)^-decay``, normalized over the cohort; ``decay = 0``
    (or an all-fresh cohort, e.g. broadcast sync mode) recovers the plain
    uniform average.
    """
    stale = jnp.maximum(round_id - last_sync[ids], 0).astype(jnp.float32)
    w = (1.0 + stale) ** (-decay)
    return w / jnp.maximum(w.sum(), 1e-12)


# ------------------------------------------------------------ the population

@dataclasses.dataclass
class ClientPopulation:
    """N stacked client states + per-client sync bookkeeping."""
    states: Any                  # pytree, every leaf with leading axis N
    last_sync: jax.Array         # int32 [N]: round of last server-state pull
    n: int

    @classmethod
    def create(cls, init_one: Callable[[jax.Array, Any], Any], key,
               batches_n, n: int) -> "ClientPopulation":
        """vmap ``init_one(client_key, client_batch)`` over N clients."""
        states = jax.vmap(init_one)(jax.random.split(key, n), batches_n)
        return cls(states=states, last_sync=jnp.zeros((n,), jnp.int32), n=n)

    def gather(self, ids):
        return gather(self.states, ids)

    def scatter(self, ids, values):
        return dataclasses.replace(self, states=scatter(self.states, ids,
                                                        values))


# ------------------------------------------------------------ fused round

def make_population_round(local_step_ids: Callable, sync_update: Callable,
                          q: int, *, sync_mode: str = "broadcast",
                          staleness_decay: float = 0.0) -> Callable:
    """Build the gather → scan-round → aggregate → scatter program.

    ``local_step_ids(states_c, server, batch, key, ids)`` is the per-step
    function over the COHORT (any client-vmapping is its own; ``ids`` are the
    global client ids, so per-client RNG folds match the full-population
    path). ``sync_update(server, avg_state)`` maps the aggregated client
    state to ``(new_client_state, new_server)`` (unbatched client state).

    Returns ``round_fn(bank_states, last_sync, server, ids, batches_q, key,
    round_id) -> (bank_states, last_sync, server)`` — jit-compatible, one
    compile per cohort shape [C, ...]: q local steps on the C gathered
    states, a (staleness-weighted) cohort aggregate, the server update, and
    the write-back dictated by ``sync_mode``.
    """
    if sync_mode not in SYNC_MODES:
        raise ValueError(f"sync_mode must be one of {SYNC_MODES}, "
                         f"got {sync_mode!r}")
    if q < 1:
        raise ValueError(f"round needs q >= 1 local steps, got {q}")

    def round_fn(bank_states, last_sync, server, ids, batches_q, key,
                 round_id):
        cur = gather(bank_states, ids)

        def body(carry, batch):
            st, srv = carry
            st, srv = local_step_ids(st, srv, batch, key, ids)
            return (st, srv), None

        (cur, server), _ = jax.lax.scan(body, (cur, server), batches_q,
                                        length=q)
        w = staleness_weights(last_sync, ids, round_id, staleness_decay)
        new_client, server = sync_update(server, weighted_mean(cur, w))
        if sync_mode == "broadcast":
            bank_states = broadcast(bank_states, new_client)
            last_sync = jnp.full_like(last_sync, round_id + 1)
        else:
            c = ids.shape[0]
            bank_states = scatter(
                bank_states, ids,
                jax.tree.map(lambda v: jnp.broadcast_to(v[None],
                                                        (c,) + v.shape),
                             new_client))
            last_sync = last_sync.at[ids].set(round_id + 1)
        return bank_states, last_sync, server

    return round_fn
