"""Fused scan-based round engine.

AdaFBiO's communication saving is structural: q local steps per sync round
(paper §4, Remark 2). Dispatching each local step as its own jitted Python
call re-pays host dispatch + donation plumbing q times per round and hides
the structure from XLA. The round engine rolls the whole round — q local
steps then one sync — into a single jitted program:

  * the q per-step batches (keys / token streams) are stacked on a leading
    axis and carried as the scanned inputs of one ``jax.lax.scan``;
  * the iteration counter ``t`` rides in the server state through the loop
    carry (per-step RNG keys are derived from it via ``fold_in``, exactly as
    the eager path does), so scan and eager steps see identical keys;
  * the sync step (client mean + adaptive regeneration + server update)
    closes the round inside the same program.

Parity guarantee: ``make_round_step(local, sync, q)(states, server,
batches_q, key)`` computes exactly ``sync(*local(...q times...))`` — the scan
body IS the per-step function, so the engine is numerics-identical to q eager
``local_step`` calls followed by one ``sync_step`` (verified to 1e-5 in
tests/test_round_engine.py; any drift is XLA re-association inside scan).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.tree_util import tree_stack

# "gossip" is the decentralized fifth engine (repro.fed.topology): same
# fused round program, but the sync is a mixing-matrix step instead of the
# star server's mean+sync_update+broadcast
ENGINES = ("eager", "scan", "gossip")


def make_round_step(local_step: Callable, sync_step: Callable,
                    q: int) -> Callable:
    """Build ``round(states, server, batches_q, key) -> (states, server)``.

    ``local_step(states, server, batch, key)`` and ``sync_step(states,
    server)`` are the per-step functions (any client-vmapping / sharding is
    theirs); ``batches_q`` is the per-step batch pytree stacked on a leading
    axis of size ``q``. The returned function is jit-compatible and contains
    the whole round as one ``lax.scan`` + sync.
    """
    if q < 1:
        raise ValueError(f"round needs q >= 1 local steps, got {q}")

    def round_step(states, server, batches_q, key):
        def body(carry, batch):
            st, srv = carry
            st, srv = local_step(st, srv, batch, key)
            return (st, srv), None

        # named_scope: profiler-visible region names (docs/observability.md)
        with jax.named_scope("round/local_scan"):
            (states, server), _ = jax.lax.scan(body, (states, server),
                                               batches_q, length=q)
        with jax.named_scope("round/sync"):
            return sync_step(states, server)

    return round_step


def stack_round_batches(batch_fn: Callable[[int], Any], t0: int, q: int):
    """Stack ``batch_fn(t0) .. batch_fn(t0+q-1)`` on a new leading axis —
    the scanned-input layout ``make_round_step`` expects."""
    return tree_stack([batch_fn(t0 + j) for j in range(q)])


def make_multi_round(round_fn: Callable, *,
                     cohort_fn: Callable | None = None) -> Callable:
    """Fuse R full rounds into ONE scanned program (the mega-scan tier).

    ``round_fn(carry, ids, batches_q, key, round_id) -> (carry, out)`` is a
    complete communication round over an opaque ``carry`` pytree. ``ids`` is
    an arbitrary per-round input pytree (cohort ids, participation masks, an
    empty tree, ...) and ``out`` is the per-round output pytree (stats rows;
    ``None`` is fine). ``round_id`` arrives as a traced int32 scalar, so the
    round body must derive everything round-dependent (staleness weights,
    codec RNG folds, delay schedules, ``last_sync`` stamps) from it — the
    existing round programs already do.

    Returns ``multi(carry, ids_R, batches_R, key, round0) -> (carry, outs)``
    which scans rounds ``round0 .. round0 + R - 1`` where R is the leading
    axis of ``batches_R``; ``ids_R`` stacks the per-round ``ids`` on the same
    leading axis and ``outs`` stacks the per-round ``out``. When
    ``cohort_fn`` is given (a jit-traceable ``round_id -> ids`` draw, see
    :func:`repro.fed.sampling.in_scan_cohort_fn`) the cohort is drawn INSIDE
    the scan and ``ids_R`` may be ``None``.

    R = 1 is exactly one ``round_fn`` call inside a length-1 scan: same op
    graph, same numerics. tests/test_megascan.py pins mega(R) bit-identical
    to R sequential single-round calls for every engine/codec combination.
    """

    def multi(carry, ids_R, batches_R, key, round0):
        r = jax.tree_util.tree_leaves(batches_R)[0].shape[0]

        def body(c, x):
            i, ids, batches_q = x
            rid = round0 + i
            if cohort_fn is not None:
                ids = cohort_fn(rid)
            return round_fn(c, ids, batches_q, key, rid)

        xs = (jnp.arange(r, dtype=jnp.int32), ids_R, batches_R)
        with jax.named_scope("megascan"):
            return jax.lax.scan(body, carry, xs, length=r)

    return multi
