"""Federated LM runtime: the mesh-sharded trainer that turns the paper's
algorithm into jitted step/round programs over real architectures.

What this module owns: ``FederatedTrainer`` — state structure (client x/y/v/w
pytrees with a leading M client axis, server adaptive state), logical-axis
shardings, and the jitted step functions (``local``/``sync``/``round``/
``population_round``/``async_population_round``) for one (arch, mesh) pair.
How it composes with its neighbours: per-step math comes from ``repro.core``
(``alg.local_step`` = Algorithm 1 lines 10-20 / Eq. 14, ``alg.sync_update``
= lines 4-9); fused round programs from ``repro.fed.round`` (scan engine)
and ``repro.fed.population`` (bank rounds, async rounds); model forward/
backward from ``repro.models`` via the bilevel problem split
(``repro.core.bilevel``). The host-side loop that drives these programs is
``repro.launch.train`` (or ``repro.tasks.driver`` for the small-scale paper
experiments).

Placement: replica mode — M = pods x data rows; each client's tensors shard
over `model` only. Zero mode — M = pods; client tensors additionally
FSDP-shard over `data`. Local steps are vmapped per client with
``spmd_axis_name`` = the client mesh axes, so the compiled local step
contains NO collectives over client axes (the paper's communication saving
is structural, not scheduled). The sync step's client-mean lowers to
all-reduces over the client axes — once per q steps (paper §4, Remark 2).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, FedConfig, ShapeConfig
from repro.core.baselines import Algorithm, make_algorithm
from repro.core.bilevel import BilevelProblem, lm_bilevel_problem
from repro.core.tree_util import tree_bcast_axis0, tree_mean_axis0
from repro.models.model import ModelCtx, model_specs
from repro.models.params import abstract_params, axes_tree, init_params
from repro import sharding as shlib


# ------------------------------------------------------------------ batches

def split_client_batch(cfg: ArchConfig, b: Dict[str, jax.Array]) -> Dict[str, Any]:
    """Per-client runtime inputs -> {'f','g0','gi'} batch dicts for the
    hypergradient/STORM estimators."""
    def pack(tokens, stub_key_prefix):
        d = {"tokens": tokens}
        if cfg.n_prefix_embeds and stub_key_prefix + "prefix_embeds" in b:
            d["prefix_embeds"] = b[stub_key_prefix + "prefix_embeds"]
        if cfg.family == "encdec":
            d["enc_embeds"] = b[stub_key_prefix + "enc_embeds"]
        return d

    return {
        "g": pack(b["tokens"], ""),                 # ζ: LL STORM sample (big)
        "g0": pack(b["hyper0_tokens"], "hyper0_"),  # ζ₀: mixed ∇²xy term (small)
        "f": pack(b["val_tokens"], "val_"),         # ξ: UL sample
        "gi": pack(b["neumann_tokens"], "neumann_"),  # ζ₁..K: Neumann samples
    }


def client_batch_specs(cfg: ArchConfig, shape: ShapeConfig, m: int,
                       fed: FedConfig) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """ShapeDtypeStructs + logical axes for one training step's inputs
    (leading M axis included)."""
    s = shape.seq_len
    # Neumann / ζ₀ samples are independent draws; shorter sequences keep the
    # K cached feature buffers and the second-order term cheap (DESIGN.md §3).
    sn = max(s // 4, 64)
    bg = max(shape.global_batch // m, 1)
    bf = max(int(bg * fed.ul_batch_frac), 1)
    bn = fed.neumann_batch
    K = fed.neumann_k
    d = cfg.d_model
    tok = jnp.int32
    emb = jnp.bfloat16
    specs: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((m, bg, s), tok),
        "val_tokens": jax.ShapeDtypeStruct((m, bf, s), tok),
        "hyper0_tokens": jax.ShapeDtypeStruct((m, bn, sn), tok),
        "neumann_tokens": jax.ShapeDtypeStruct((m, K, bn, sn), tok),
    }
    axes: Dict[str, Any] = {
        "tokens": ("clients", "batch", None),
        "val_tokens": ("clients", "batch", None),
        "hyper0_tokens": ("clients", "batch", None),
        "neumann_tokens": ("clients", None, "batch", None),
    }
    if cfg.n_prefix_embeds:
        pfe = min(cfg.n_prefix_embeds, sn // 2)
        specs.update({
            "prefix_embeds": jax.ShapeDtypeStruct((m, bg, cfg.n_prefix_embeds, d), emb),
            "val_prefix_embeds": jax.ShapeDtypeStruct((m, bf, cfg.n_prefix_embeds, d), emb),
            "hyper0_prefix_embeds": jax.ShapeDtypeStruct((m, bn, pfe, d), emb),
            "neumann_prefix_embeds": jax.ShapeDtypeStruct((m, K, bn, pfe, d), emb),
        })
        axes.update({
            "prefix_embeds": ("clients", "batch", None, "act_embed"),
            "val_prefix_embeds": ("clients", "batch", None, "act_embed"),
            "hyper0_prefix_embeds": ("clients", "batch", None, "act_embed"),
            "neumann_prefix_embeds": ("clients", None, "batch", None, "act_embed"),
        })
    if cfg.family == "encdec":
        senc = s
        senc_n = sn
        for k, sd in (("tokens", s // 4), ("val_tokens", s // 4),
                      ("hyper0_tokens", sn // 4), ("neumann_tokens", sn // 4)):
            sh = specs[k].shape
            specs[k] = jax.ShapeDtypeStruct(sh[:-1] + (max(sd, 8),), tok)
        specs.update({
            "enc_embeds": jax.ShapeDtypeStruct((m, bg, senc, d), emb),
            "val_enc_embeds": jax.ShapeDtypeStruct((m, bf, senc, d), emb),
            "hyper0_enc_embeds": jax.ShapeDtypeStruct((m, bn, senc_n, d), emb),
            "neumann_enc_embeds": jax.ShapeDtypeStruct((m, K, bn, senc_n, d), emb),
        })
        axes.update({
            "enc_embeds": ("clients", "batch", "seq", "act_embed"),
            "val_enc_embeds": ("clients", "batch", "seq", "act_embed"),
            "hyper0_enc_embeds": ("clients", "batch", "seq", "act_embed"),
            "neumann_enc_embeds": ("clients", None, "batch", "seq", "act_embed"),
        })
    return specs, axes


def build_lm_problem_ctx(cfg: ArchConfig, fed: FedConfig, rules,
                         data_shards: int = 1) -> Tuple[BilevelProblem, ModelCtx]:
    ctx = ModelCtx(rules=rules, kind="train")
    mb = max(fed.microbatch_per_shard * data_shards, 1)
    return lm_bilevel_problem(cfg, ctx, fed.nu, microbatch=mb), ctx


# ------------------------------------------------------------------ trainer

@dataclasses.dataclass
class FederatedTrainer:
    """Builds jitted local/sync/eval step functions for one (arch, mesh)."""
    cfg: ArchConfig
    fed: FedConfig
    shape: ShapeConfig
    mesh: Optional[Mesh] = None
    algorithm: str = "adafbio"
    problem: Optional[BilevelProblem] = None      # default: LM hyper-rep split

    def __post_init__(self):
        mesh = self.mesh
        self.rules = shlib.train_rules(self.cfg, mesh) if mesh is not None else None
        self.m = shlib.n_clients(mesh, self.cfg.fed_mode) if mesh is not None else 1
        if self.problem is None:
            # in zero mode the per-client batch is data-sharded: one microbatch
            # spans data_shards sequences so each device sees microbatch_per_shard
            data_shards = 1
            if mesh is not None and self.cfg.fed_mode == "zero":
                data_shards = dict(zip(mesh.axis_names,
                                       mesh.devices.shape)).get("data", 1)
            self.problem, self.ctx = build_lm_problem_ctx(
                self.cfg, self.fed, self.rules, data_shards)
        else:
            self.ctx = ModelCtx(rules=self.rules, kind="train")
        self.alg: Algorithm = make_algorithm(self.algorithm, self.fed,
                                             self.problem)
        from repro.fed.compress import codec_from_config
        self.codec = codec_from_config(self.fed)
        self.specs = model_specs(self.cfg)
        self._axes = axes_tree(self.specs)
        self.client_axes_names = (shlib.client_axes(mesh, self.cfg.fed_mode)
                                  if mesh is not None else ())

    # -------------------------------------------------- state structure

    def abstract_client_states(self):
        p = abstract_params(self.specs, self.cfg.dtype)
        one = {"x": p["x"], "y": p["y"], "v": p["y"], "w": p["x"]}
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((self.m,) + s.shape, s.dtype), one)

    def one_state_axes(self):
        """Logical axes of ONE client's state (no leading clients axis)."""
        ax = self._axes
        return {"x": ax["x"], "y": ax["y"], "v": ax["y"], "w": ax["x"]}

    def client_state_axes(self):
        one = self.one_state_axes()
        return jax.tree.map(lambda a: ("clients",) + a, one,
                            is_leaf=lambda t: isinstance(t, tuple)
                            and all(u is None or isinstance(u, str) for u in t))

    def abstract_server_state(self):
        xp = abstract_params(self.specs, self.cfg.dtype)["x"]
        st = {"adaptive": {"b": jax.ShapeDtypeStruct((), jnp.float32)},
              "t": jax.ShapeDtypeStruct((), jnp.int32)}
        if self.fed.adaptive != "none":
            st["adaptive"]["a"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), xp)
        if self.fed.adaptive == "adabelief":
            st["adaptive"]["w_prev"] = st["adaptive"]["a"]
            st["adaptive"]["v_norm_prev"] = jax.ShapeDtypeStruct((), jnp.float32)
        return st

    def server_state_axes(self):
        ax = self._axes["x"]
        st = {"adaptive": {"b": ()}, "t": ()}
        if self.fed.adaptive != "none":
            st["adaptive"]["a"] = ax
        if self.fed.adaptive == "adabelief":
            st["adaptive"]["w_prev"] = ax
            st["adaptive"]["v_norm_prev"] = ()
        return st

    # -------------------------------------------------- shardings

    def _shardings(self, axes_pytree, shapes_pytree=None, fallback=()):
        if self.mesh is None:
            return None
        return shlib.tree_shardings(axes_pytree, self.rules, self.mesh,
                                    shapes_pytree, fallback)

    def state_shardings(self):
        return self._shardings(self.client_state_axes(),
                               self.abstract_client_states(),
                               fallback=("model",))

    def server_shardings(self):
        return self._shardings(self.server_state_axes(),
                               self.abstract_server_state(),
                               fallback=("model",))

    def batch_shardings(self, batch_specs, batch_axes):
        return self._shardings(batch_axes, batch_specs)

    # -------------------------------------------------- step functions

    def _vmap_clients(self, fn):
        if self.client_axes_names:
            name = (self.client_axes_names if len(self.client_axes_names) > 1
                    else self.client_axes_names[0])
            return jax.vmap(fn, spmd_axis_name=name)
        return jax.vmap(fn)

    def init_states(self, key, batch):
        """Materialized init (CPU-scale usage)."""
        states, _, server = self.init_population_states(key, batch, self.m)
        return states, server

    def local_step_fn(self) -> Callable:
        """All-clients step: the cohort step over the full population
        (ids = 0..m-1), so the two paths share one implementation."""
        step = self.cohort_local_step_fn()
        ids = jnp.arange(self.m)
        return lambda states, server, batch, key: step(states, server, batch,
                                                       key, ids)

    # -------------------------------------------------- aggregators

    def star_aggregator(self, n: Optional[int] = None):
        """The star sync as an ``Aggregator`` (``repro.fed.topology``):
        ``sync_update`` with the population size ``n`` (default: the
        trainer's client count) closed over, plus the trainer's codec. All
        the star round builders below sync through it."""
        from repro.fed.topology import StarAggregator
        m = n if n is not None else self.m
        return StarAggregator(
            sync_update=lambda srv, avg: self.alg.sync_update(srv, avg, m),
            codec=self.codec)

    def gossip_aggregator(self, n: int, *, topology: str = "ring",
                          er_p: float = 0.4, seed: int = 0,
                          time_varying: bool = False):
        """The decentralized sync: a ``GossipAggregator`` mixing an
        n-node bank over ``topology`` (docs/topology.md)."""
        from repro.fed.topology import GossipAggregator
        return GossipAggregator(
            sync_update=lambda srv, avg: self.alg.sync_update(srv, avg, n),
            n=n, topology=topology, er_p=er_p, seed=seed,
            time_varying=time_varying, codec=self.codec)

    def sync_step_fn(self) -> Callable:
        agg = self.star_aggregator()

        def step(states, server):
            new_client, new_server = agg.reduce(server, states)
            return tree_bcast_axis0(new_client, self.m), new_server
        return step

    def round_step_fn(self, q: Optional[int] = None) -> Callable:
        """One fused communication round: q local steps rolled into a single
        ``lax.scan`` + the sync step, as one jit-able program.

        Signature: ``round(states, server, batches_q, key)`` where
        ``batches_q`` is the per-step batch pytree stacked on a leading axis
        of size q (see ``repro.fed.round.stack_round_batches``). Numerics
        match q eager ``local_step_fn()`` calls + one ``sync_step_fn()``.
        """
        from repro.fed.round import make_round_step
        return make_round_step(self.local_step_fn(), self.sync_step_fn(),
                               q if q is not None else self.fed.q)

    def round_step_codec_fn(self, q: Optional[int] = None) -> Callable:
        """Codec-aware fused round for the plain all-clients path: like
        :meth:`round_step_fn` but the sync leg ships each client's round
        delta through ``FedConfig.codec`` against ``ref`` (the server's last
        broadcast — what every client started the round from) before the
        mean, carrying the per-client EF residual across rounds.

        ``round(states, server, ref, ef, batches_q, key, round_id) ->
        (states, server, ref, ef)``; the new ``ref`` is the fresh broadcast.
        With ``codec='none'`` the codec leg is the identity and the program
        is bit-identical to :meth:`round_step_fn` (pinned in
        tests/test_round_engine.py). Build ``ef`` with
        ``repro.fed.compress.zeros_ef`` over :meth:`abstract_client_states`;
        it is ``None`` for stateless codecs."""
        agg = self.star_aggregator()
        local = self.local_step_fn()
        nq = q if q is not None else self.fed.q
        ids = jnp.arange(self.m)

        def round_step(states, server, ref, ef, batches_q, key, round_id):
            def body(carry, batch):
                st, srv = carry
                st, srv = local(st, srv, batch, key)
                return (st, srv), None

            with jax.named_scope("round/local_scan"):
                (states, server), _ = jax.lax.scan(body, (states, server),
                                                   batches_q, length=nq)
            with jax.named_scope("round/codec"):
                recon, ef = agg.messages(key, round_id, ids, ref, states, ef)
            with jax.named_scope("round/sync"):
                new_client, server = agg.reduce(server, recon)
            states = tree_bcast_axis0(new_client, self.m)
            return states, server, states, ef

        return round_step

    # -------------------------------------------------- population mode

    def cohort_local_step_fn(self, n: Optional[int] = None) -> Callable:
        """``local_step_fn`` over a sampled cohort: identical math, but the
        per-client RNG folds the GLOBAL client id carried in ``ids`` (not the
        vmap position), and the eta_t schedule sees the POPULATION size ``n``
        (the paper's M — not the cohort/vmap width), so a cohort step
        reproduces the same client's step as a full-population step."""
        m_sched = n if n is not None else self.m
        def step(states, server, batch, key, ids):
            t = server["t"]
            def one(state, b, gid):
                batches = split_client_batch(self.cfg, b)
                k = jax.random.fold_in(jax.random.fold_in(key, gid), t)
                return self.alg.local_step(state, server["adaptive"], batches,
                                           k, t, m_sched)
            new_states = self._vmap_clients(one)(states, batch, ids)
            new_server = dict(server)
            new_server["t"] = t + 1
            return new_states, new_server
        return step

    def init_population_states(self, key, batch, n: int):
        """Bank init: like ``init_states`` but over a population of ``n``
        clients (``batch`` carries a leading n axis). The shared (x0, y0)
        derive from ``key`` (runs with different keys start from different
        parameters — the seed behaviour hard-coded PRNGKey(0) and made
        every run's init identical); the per-client estimator keys are the
        n-way split of the same key. Returns ``(bank_states, last_sync,
        server)``."""
        keys = jax.random.split(key, n)
        # one shared init, hoisted out of the client vmap; the salt keeps
        # the parameter draw off the per-client estimator-key stream
        params = init_params(self.specs, jax.random.fold_in(key, 0x9142A),
                             self.cfg.dtype)
        def one(k, b):
            batches = split_client_batch(self.cfg, b)
            return self.alg.init_client_state(params["x"], params["y"], batches, k)
        bank = self._vmap_clients(one)(keys, batch)
        xp_like = jax.tree.map(lambda a: a[0], bank["x"])
        server = self.alg.init_server_state(xp_like)
        if self.fed.adaptive != "none":
            from repro.core.adafbio import warm_adaptive
            server = warm_adaptive(server, tree_mean_axis0(bank), self.fed)
        return bank, jnp.zeros((n,), jnp.int32), server

    def population_round_fn(self, n: int, q: Optional[int] = None, *,
                            sync_mode: str = "broadcast",
                            staleness_decay: float = 0.0) -> Callable:
        """Gather → fused scan round → aggregate → scatter over an n-client
        bank: ``round(bank, last_sync, server, ids, batches_q, key,
        round_id)``. Jits once per cohort shape [C, ...]; compute is O(C),
        the bank writes O(n) memory bandwidth only.

        With a lossy ``FedConfig.codec`` the signature grows the stacked
        error-feedback residual bank (``repro.fed.population.
        make_population_round``): ``round(bank, last_sync, ef_bank, server,
        ids, batches_q, key, round_id)`` — build ``ef_bank`` with
        :meth:`init_ef_bank`."""
        from repro.fed.population import make_population_round
        return make_population_round(
            self.cohort_local_step_fn(n), self.star_aggregator(n),
            q if q is not None else self.fed.q,
            sync_mode=sync_mode, staleness_decay=staleness_decay,
            codec=self.codec)

    def multi_population_round_fn(self, n: int, q: Optional[int] = None, *,
                                  sync_mode: str = "broadcast",
                                  staleness_decay: float = 0.0,
                                  cohort_fn=None) -> Callable:
        """The mega-scan tier over :meth:`population_round_fn`: R full
        rounds fused into one scanned program (docs/megascan.md).
        ``multi(bank, last_sync[, ef_bank], server, ids_R, batches_R, key,
        round0)`` where ``ids_R`` is [R, C] (or None with a ``cohort_fn``
        drawing cohorts in-scan, see ``repro.fed.sampling.
        in_scan_cohort_fn``) and ``batches_R`` stacks each round's
        ``batches_q`` on a new leading R axis."""
        from repro.fed.population import make_multi_population_round
        return make_multi_population_round(
            self.population_round_fn(n, q, sync_mode=sync_mode,
                                     staleness_decay=staleness_decay),
            lossy=self.codec.lossy, cohort_fn=cohort_fn)

    def multi_async_population_round_fn(self, n: int,
                                        q: Optional[int] = None, *,
                                        cohort_fn=None,
                                        **async_opts) -> Callable:
        """The mega-scan tier over :meth:`async_population_round_fn`:
        ``multi(state, ids_R, batches_R, key, round0) -> (state, stats_R)``
        with the per-round stats stacked on a new leading R axis
        (docs/megascan.md). ``async_opts`` forwards the async knobs."""
        from repro.fed.population import make_multi_async_round
        return make_multi_async_round(
            self.async_population_round_fn(n, q, **async_opts),
            cohort_fn=cohort_fn)

    def init_ef_bank(self, n: int):
        """The stacked [n, ...] error-feedback residual bank the lossy
        population/async round programs carry (zeros; None when
        ``FedConfig.codec`` keeps no per-client state)."""
        from repro.fed.compress import zeros_ef
        return zeros_ef(self.codec, self.abstract_population_states(n))

    def abstract_population_states(self, n: int):
        p = abstract_params(self.specs, self.cfg.dtype)
        one = {"x": p["x"], "y": p["y"], "v": p["y"], "w": p["x"]}
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), one)

    def init_async_population_states(self, key, batch, n: int):
        """Bank init + async bookkeeping: the ``init_async_state`` dict
        (bank, pending buffer, flight/staleness vectors, anchor, server)
        that ``async_population_round_fn`` advances."""
        from repro.fed.population import init_async_state
        bank, _, server = self.init_population_states(key, batch, n)
        return init_async_state(bank, server, n, codec=self.codec)

    def async_population_round_fn(self, n: int, q: Optional[int] = None, *,
                                  sync_mode: str = "broadcast",
                                  staleness_decay: float = 0.0,
                                  max_staleness: float = float("inf"),
                                  max_delay: int = 1,
                                  delay_eta: float = 0.0,
                                  delay_model=None) -> Callable:
        """Asynchronous round over an n-client bank: arrivals →
        bounded-staleness gate → delay-adaptive server step → overlapping-
        cohort dispatch, one jitted program per round
        (``repro.fed.population.make_async_round``; semantics in
        docs/async.md). ``delay_model`` is an optional
        ``repro.fed.population.DelayModel`` (heterogeneous per-client
        delays; None = uniform U[1, max_delay]). ``round(state, ids,
        batches_q, key, round_id) -> (state, stats)``."""
        from repro.fed.population import make_async_round
        return make_async_round(
            self.cohort_local_step_fn(n), self.star_aggregator(n),
            q if q is not None else self.fed.q,
            sync_mode=sync_mode, staleness_decay=staleness_decay,
            max_staleness=max_staleness, max_delay=max_delay,
            delay_eta=delay_eta, delay=delay_model, codec=self.codec)

    def population_state_shardings(self, n: int):
        """Bank shardings: the leading population axis PARTITIONS over the
        client mesh axes (``pod``/``data`` per ``shlib.client_axes``) — each
        device holds N/devices rows, so per-device bank bytes shrink with
        the mesh and the cohort gather is the round's only cross-shard op
        (docs/sharding.md). Trailing model axes keep their rule-based
        layout. When N does not divide the client-axes product the leading
        assignment drops and the bank replicates client-wise (the
        pre-sharded layout)."""
        return self._shardings(self.client_state_axes(),
                               self.abstract_population_states(n),
                               fallback=("model",))

    def bank_vector_sharding(self, n: int):
        """Sharding of the int32/bool [N] per-client bookkeeping vectors
        (``last_sync`` / ``in_flight`` / ``dispatch_round`` /
        ``return_round``): partitioned like the bank rows, so the async
        round's arrival/gate masks are computed shard-locally."""
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, shlib.bank_spec(
            self.mesh, self.cfg.fed_mode, (n,)))

    def async_state_shardings(self, n: int):
        """Shardings of the :func:`repro.fed.population.init_async_state`
        dict: bank / pending buffer / EF residuals and the [N] bookkeeping
        vectors partition over the client mesh axes; the anchor and server
        state replicate client-wise. None without a mesh."""
        if self.mesh is None:
            return None
        pss = self.population_state_shardings(n)
        vec = self.bank_vector_sharding(n)
        one_abs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype),
            self.abstract_population_states(n))
        one_sh = self._shardings(self.one_state_axes(), one_abs,
                                 fallback=("model",))
        st_sh = {"bank": pss, "pending": pss, "last_sync": vec,
                 "in_flight": vec, "dispatch_round": vec,
                 "return_round": vec, "anchor": one_sh,
                 "server": self.server_shardings()}
        if self.codec.stateful:
            st_sh["ef"] = self.population_state_shardings(n)
        return st_sh

    def cohort_round_fn(self, n: int, q: Optional[int] = None, *,
                        staleness_decay: float = 0.0) -> Callable:
        """The cohort-only round program of the host-spill tier
        (``repro.fed.spill``, docs/sharding.md): same math as
        :meth:`population_round_fn` but the [N, ...] bank never enters the
        program — the caller gathers/writes back the C rows. ``round(cur,
        last_sync_c, server, ids, batches_q, key, round_id) -> (new_client,
        server)`` (a lossy codec adds the gathered EF slice, see
        ``repro.fed.population.make_cohort_round``)."""
        from repro.fed.population import make_cohort_round
        return make_cohort_round(
            self.cohort_local_step_fn(n), self.star_aggregator(n),
            q if q is not None else self.fed.q,
            staleness_decay=staleness_decay, codec=self.codec)

    # -------------------------------------------------- gossip mode

    def gossip_local_step_fn(self, n: int) -> Callable:
        """Per-node local step for the decentralized engine: like
        :meth:`cohort_local_step_fn` but the server state is a stacked [n]
        bank — every node advances against its OWN adaptive matrices and
        step counter. In lockstep the counters stay equal, so the per-node
        RNG fold (``fold_in(fold_in(key, gid), t)``) matches the star
        engines' draw for the same (gid, t)."""
        def step(states, srv_bank, batch, key, ids):
            def one(state, srv, b, gid):
                batches = split_client_batch(self.cfg, b)
                t = srv["t"]
                k = jax.random.fold_in(jax.random.fold_in(key, gid), t)
                new_state = self.alg.local_step(state, srv["adaptive"],
                                                batches, k, t, n)
                new_srv = dict(srv)
                new_srv["t"] = t + 1
                return new_state, new_srv
            return self._vmap_clients(one)(states, srv_bank, batch, ids)
        return step

    def init_gossip_states(self, key, batch, n: int):
        """Gossip bank init: the population bank plus the per-node server
        bank — the star server state (same shared init + ``warm_adaptive``
        pass, one documented initial consensus) broadcast to a leading [n]
        axis. Returns ``(bank, srv_bank)``."""
        bank, _, server = self.init_population_states(key, batch, n)
        return bank, tree_bcast_axis0(server, n)

    def gossip_round_fn(self, n: int, q: Optional[int] = None, *,
                        topology: str = "ring", er_p: float = 0.4,
                        seed: int = 0, time_varying: bool = False):
        """The fifth engine's fused round (``repro.fed.topology.
        make_gossip_round``): the mixing step that closes the previous
        round, then q local steps as one scan. ``round(bank, srv_bank, ef,
        batches_q, key, round_id, *, n_steps, sync_first) -> (bank,
        srv_bank, ef)``; ``ef`` is ``None`` unless the codec keeps
        per-node residuals (:meth:`init_ef_bank`)."""
        from repro.fed.topology import make_gossip_round
        agg = self.gossip_aggregator(n, topology=topology, er_p=er_p,
                                     seed=seed, time_varying=time_varying)
        return make_gossip_round(
            self.gossip_local_step_fn(n), agg,
            q if q is not None else self.fed.q)

    def multi_gossip_round_fn(self, n: int, q: Optional[int] = None,
                              **topo_opts) -> Callable:
        """Mega-scan tier over :meth:`gossip_round_fn`: ``multi(bank,
        srv_bank, ef, batches_R, key, round0) -> (bank, srv_bank, ef)``
        fusing R full rounds (each with its opening mix) into one scanned
        program. Round 0 (no mix to run) is peeled off by the caller with
        ``sync_first=False`` on the single-round program, exactly like the
        population mega-scan's opening round."""
        from repro.fed.round import make_multi_round
        round_fn = self.gossip_round_fn(n, q, **topo_opts)

        def chunk(carry, ids, batches_q, key, rid):
            del ids
            bank, srv_bank, ef = carry
            return round_fn(bank, srv_bank, ef, batches_q, key, rid), None

        mega = make_multi_round(chunk)

        def multi(bank, srv_bank, ef, batches_R, key, round0):
            carry, _ = mega((bank, srv_bank, ef), None, batches_R, key,
                            round0)
            return carry
        return multi

    def gossip_server_shardings(self, n: int):
        """Shardings of the stacked [n] per-node server bank: the leading
        node axis partitions like the state bank's rows, trailing model
        axes keep the rule-based layout."""
        if self.mesh is None:
            return None
        is_axes = lambda t: (isinstance(t, tuple) and
                             all(u is None or isinstance(u, str) for u in t))
        axes = jax.tree.map(lambda a: ("clients",) + a,
                            self.server_state_axes(), is_leaf=is_axes)
        shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype),
            self.abstract_server_state())
        return self._shardings(axes, shapes, fallback=("model",))

    def eval_fn(self) -> Callable:
        """Mean UL loss f(x̄, ȳ) over the clients' val batches."""
        def ev(states, batch):
            avg = tree_mean_axis0(states)
            def one(b):
                batches = split_client_batch(self.cfg, b)
                return self.problem.f(avg["x"], avg["y"], batches["f"])
            return jnp.mean(jax.vmap(one)(batch))
        return ev

    # -------------------------------------------------- jit plumbing

    def jitted(self, which: str, batch_specs=None, batch_axes=None,
               donate: bool = True, population_n: Optional[int] = None,
               async_opts: Optional[Dict[str, Any]] = None,
               rounds_per_scan: int = 1, cohort_fn=None):
        """jit with shardings; returns the (lowerable) compiled callable.

        ``async_opts`` (async_population_round only) forwards the async
        knobs — sync_mode / staleness_decay / max_staleness / max_delay /
        delay_eta — to :meth:`async_population_round_fn`. For the
        ``"gossip_round"``/``"multi_gossip_round"`` entries the same dict
        instead forwards the topology knobs (topology / er_p / seed /
        time_varying) to :meth:`gossip_round_fn`.

        ``which`` in {"multi_population_round", "multi_async_population_
        round"} selects the mega-scan tier (docs/megascan.md):
        ``rounds_per_scan`` sizes the leading R axis of the batch specs the
        shardings are built from (the compiled program itself re-traces per
        actual chunk length, so a shorter trailing chunk just compiles a
        second program), and ``cohort_fn`` optionally moves the cohort draw
        in-scan (``ids_R`` then passed as None)."""
        ss = self.state_shardings()
        sv = self.server_shardings()
        rep = NamedSharding(self.mesh, P()) if self.mesh else None
        if which in ("gossip_round", "multi_gossip_round"):
            if population_n is None:
                raise ValueError(f"{which} needs population_n")
            is_axes = lambda t: (isinstance(t, tuple) and
                                 all(u is None or isinstance(u, str)
                                     for u in t))
            lead = ((rounds_per_scan, self.fed.q)
                    if which == "multi_gossip_round" else (self.fed.q,))
            round_axes = (jax.tree.map(lambda a: (None,) * len(lead) + a,
                                       batch_axes, is_leaf=is_axes)
                          if batch_axes is not None else None)
            round_specs = (jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(lead + s.shape, s.dtype),
                batch_specs) if batch_specs is not None else None)
            bsh = self.batch_shardings(round_specs, round_axes)
            pss = self.population_state_shardings(population_n)
            svb = self.gossip_server_shardings(population_n)
            efsh = (self.population_state_shardings(population_n)
                    if self.codec.stateful else None)
            topo = dict(async_opts or {})
            in_sh = (pss, svb, efsh, bsh, rep, rep)
            out_sh = (pss, svb, efsh)
            dn = ((0, 1, 2) if self.codec.stateful else (0, 1)) \
                if donate else ()

            def _jit(fn):
                if self.mesh is None:
                    return jax.jit(fn, donate_argnums=dn)
                return jax.jit(fn, in_shardings=in_sh,
                               out_shardings=out_sh, donate_argnums=dn)

            if which == "multi_gossip_round":
                return _jit(self.multi_gossip_round_fn(population_n, **topo))
            # per-round programs vary in (n_steps, sync_first) — round 0
            # skips the opening mix — so cache one compiled variant per
            # static combination instead of threading static kwargs
            # through the sharded jit
            base = self.gossip_round_fn(population_n, **topo)
            cache: Dict[Tuple[int, bool], Callable] = {}

            def dispatch(*a, n_steps=None, sync_first=True):
                ns = self.fed.q if n_steps is None else n_steps
                k = (ns, bool(sync_first))
                if k not in cache:
                    cache[k] = _jit(functools.partial(
                        base, n_steps=ns, sync_first=sync_first))
                return cache[k](*a)

            return dispatch
        if which in ("multi_population_round",
                     "multi_async_population_round"):
            if population_n is None:
                raise ValueError(f"{which} needs population_n")
            is_axes = lambda t: (isinstance(t, tuple) and
                                 all(u is None or isinstance(u, str)
                                     for u in t))
            # scanned batches carry leading (R, q) axes, both unsharded
            round_axes = (jax.tree.map(lambda a: (None, None) + a,
                                       batch_axes, is_leaf=is_axes)
                          if batch_axes is not None else None)
            round_specs = (jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    (rounds_per_scan, self.fed.q) + s.shape, s.dtype),
                batch_specs) if batch_specs is not None else None)
            bsh = self.batch_shardings(round_specs, round_axes)
            ids_sh = None if cohort_fn is not None else rep
            if which == "multi_population_round":
                fn = self.multi_population_round_fn(population_n,
                                                    cohort_fn=cohort_fn)
                pss = self.population_state_shardings(population_n)
                vec = self.bank_vector_sharding(population_n)
                if self.codec.lossy:
                    efsh = (self.population_state_shardings(population_n)
                            if self.codec.stateful else None)
                    in_sh = (pss, vec, efsh, sv, ids_sh, bsh, rep, rep)
                    out_sh = (pss, vec, efsh, sv)
                    dn = (0, 2) if donate and self.codec.stateful else (
                        (0,) if donate else ())
                else:
                    in_sh = (pss, vec, sv, ids_sh, bsh, rep, rep)
                    out_sh = (pss, vec, sv)
                    dn = (0,) if donate else ()
            else:
                fn = self.multi_async_population_round_fn(
                    population_n, cohort_fn=cohort_fn,
                    **(async_opts or {}))
                st_sh = self.async_state_shardings(population_n)
                stats_sh = None if self.mesh is None else {
                    k: rep for k in ("arrived", "accepted", "dropped",
                                     "mean_staleness", "eta_scale",
                                     "dispatched", "synced", "staleness")}
                in_sh = (st_sh, ids_sh, bsh, rep, rep)
                out_sh = (st_sh, stats_sh)
                dn = (0,) if donate else ()
            if self.mesh is None:
                return jax.jit(fn, donate_argnums=dn)
            return jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                           donate_argnums=dn)
        if which == "local":
            fn = self.local_step_fn()
            in_sh = (ss, sv, self.batch_shardings(batch_specs, batch_axes),
                     NamedSharding(self.mesh, P()) if self.mesh else None)
            out_sh = (ss, sv)
            dn = (0,) if donate else ()
        elif which == "sync":
            fn = self.sync_step_fn()
            in_sh = (ss, sv)
            out_sh = (ss, sv)
            dn = (0,) if donate else ()
        elif which in ("round", "population_round",
                       "async_population_round"):
            # scanned batches carry a leading (unsharded) q axis
            is_axes = lambda t: (isinstance(t, tuple) and
                                 all(u is None or isinstance(u, str)
                                     for u in t))
            round_axes = (jax.tree.map(lambda a: (None,) + a, batch_axes,
                                       is_leaf=is_axes)
                          if batch_axes is not None else None)
            round_specs = (jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((self.fed.q,) + s.shape,
                                               s.dtype), batch_specs)
                if batch_specs is not None else None)
            bsh = self.batch_shardings(round_specs, round_axes)
            # lossy codecs carry the EF residual bank alongside the states;
            # it shares the bank's layout (same structure/shapes, f32)
            efsh = None
            if self.codec.stateful and population_n is not None:
                efsh = self.population_state_shardings(population_n)
            if which == "round":
                fn = self.round_step_fn()
                in_sh = (ss, sv, bsh, rep)
                out_sh = (ss, sv)
            elif which == "population_round":
                if population_n is None:
                    raise ValueError("population_round needs population_n")
                fn = self.population_round_fn(population_n)
                pss = self.population_state_shardings(population_n)
                vec = self.bank_vector_sharding(population_n)
                if self.codec.lossy:
                    in_sh = (pss, vec, efsh, sv, rep, bsh, rep, rep)
                    out_sh = (pss, vec, efsh, sv)
                else:
                    in_sh = (pss, vec, sv, rep, bsh, rep, rep)
                    out_sh = (pss, vec, sv)
            else:
                if population_n is None:
                    raise ValueError("async_population_round needs "
                                     "population_n")
                fn = self.async_population_round_fn(population_n,
                                                    **(async_opts or {}))
                st_sh = self.async_state_shardings(population_n)
                stats_sh = None if self.mesh is None else {
                    k: rep for k in ("arrived", "accepted", "dropped",
                                     "mean_staleness", "eta_scale",
                                     "dispatched", "synced", "staleness")}
                in_sh = (st_sh, rep, bsh, rep, rep)
                out_sh = (st_sh, stats_sh)
            dn = (0,) if donate else ()
            if (donate and which == "population_round"
                    and self.codec.stateful):
                # the EF residual bank is input 2 and output 2 of the lossy
                # round — as bank-sized as the state bank; without donation
                # every round would allocate a second [N, ...] f32 copy
                dn = (0, 2)
        else:
            raise ValueError(which)
        if self.mesh is None:
            return jax.jit(fn, donate_argnums=dn)
        return jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                       donate_argnums=dn)
