"""Per-round cohort samplers: which C of the N population clients train.

The population subsystem (``repro.fed.population``) decouples the client
*population* (N persistent states) from the per-round compute *cohort*
(C sampled clients). Samplers are the pluggable policy in between: a
deterministic function ``round_id -> C client ids``, seeded from the run key
so different runs draw different cohorts while any single run is exactly
reproducible (and replayable against the legacy masked-participation path —
`FedDriver._active_mask` consumes the same draw, which is what the
cohort ≡ masked parity tests rely on).

Three policies, mirroring the client-sampling settings of the related
federated-bilevel work (uniform sampling à la Gao arXiv:2204.13299;
availability traces à la the asynchronous setting of Jiao et al.
arXiv:2212.10048):

  uniform     — C clients uniformly without replacement each round.
  roundrobin  — deterministic cyclic sweep; every client participates exactly
                once per ⌈N/C⌉ rounds (useful for coverage tests & debugging).
  trace       — each client has a periodic up/down availability schedule
                (random phase); the cohort is drawn uniformly from the
                currently-available clients. If fewer than C are up, the
                available set is cycled to fill the fixed-shape cohort
                (duplicates are an availability artifact, and are weighted
                like any repeated participant by the aggregation).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

SAMPLERS = ("uniform", "roundrobin", "trace")


class CohortSampler:
    """Protocol: deterministic ``round_id -> [c] int32 global client ids``."""

    n: int
    c: int

    def cohort(self, round_id: int) -> jax.Array:
        raise NotImplementedError

    def mask(self, round_id: int) -> jax.Array:
        """Boolean participation mask over the full population — the legacy
        masked-participation view of the same draw."""
        return jnp.zeros((self.n,), bool).at[self.cohort(round_id)].set(True)


@dataclasses.dataclass(frozen=True)
class UniformSampler(CohortSampler):
    """C of N uniformly at random, without replacement, per round."""
    n: int
    c: int
    key: jax.Array

    def cohort(self, round_id: int) -> jax.Array:
        k = jax.random.fold_in(self.key, round_id)
        return jax.random.permutation(k, self.n)[: self.c].astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class RoundRobinSampler(CohortSampler):
    """Cyclic sweep: round r takes clients [r*c, r*c + c) mod n."""
    n: int
    c: int
    offset: int = 0

    def cohort(self, round_id: int) -> jax.Array:
        start = self.offset + round_id * self.c
        return ((start + jnp.arange(self.c)) % self.n).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class AvailabilityTraceSampler(CohortSampler):
    """Clients follow periodic up/down schedules; sample among the available.

    Client i is up at round r iff ``(r + phase_i) % period < duty * period``,
    with a random per-client phase derived from ``key``. The cohort is a
    uniform draw (without replacement) from the up set; a shortfall cycles
    the up set so the cohort keeps its static shape [c].
    """
    n: int
    c: int
    key: jax.Array
    period: int = 8
    duty: float = 0.5

    def _phases(self) -> jax.Array:
        # schedule salt kept off the per-round fold_in(round_id) stream
        return jax.random.randint(jax.random.fold_in(self.key, 0x7FFFFFFF),
                                  (self.n,), 0, self.period)

    def up_mask(self, round_id: int) -> jax.Array:
        up_len = max(int(round(self.duty * self.period)), 1)
        return (round_id + self._phases()) % self.period < up_len

    def cohort(self, round_id: int) -> jax.Array:
        up = self.up_mask(round_id)
        k = jax.random.fold_in(self.key, round_id)
        # available clients get scores in [-1, 0), unavailable in [0, 1):
        # argsort ranks every up client ahead of every down client, with a
        # uniform shuffle within each group.
        score = jax.random.uniform(k, (self.n,)) - up.astype(jnp.float32)
        order = jnp.argsort(score)
        n_up = jnp.maximum(up.sum(), 1)
        slot = jnp.arange(self.c)
        # slots beyond the up count wrap around the available prefix rather
        # than dipping into down clients
        return order[jnp.where(slot < n_up, slot, slot % n_up)].astype(jnp.int32)


def make_sampler(name: str, n: int, c: int, key: jax.Array, *,
                 period: int = 8, duty: float = 0.5,
                 offset: int = 0) -> CohortSampler:
    if not 1 <= c <= n:
        raise ValueError(f"cohort size must satisfy 1 <= c <= n, "
                         f"got c={c}, n={n}")
    if name == "uniform":
        return UniformSampler(n, c, key)
    if name == "roundrobin":
        return RoundRobinSampler(n, c, offset)
    if name == "trace":
        return AvailabilityTraceSampler(n, c, key, period, duty)
    raise KeyError(f"unknown sampler {name!r}; known: {SAMPLERS}")
