"""Per-round cohort samplers: which C of the N population clients train.

The population subsystem (``repro.fed.population``) decouples the client
*population* (N persistent states) from the per-round compute *cohort*
(C sampled clients). Samplers are the pluggable policy in between: a
deterministic function ``round_id -> C client ids``, seeded from the run key
so different runs draw different cohorts while any single run is exactly
reproducible (and replayable against the legacy masked-participation path —
`FedDriver._active_mask` consumes the same draw, which is what the
cohort ≡ masked parity tests rely on).

Four policies, mirroring the client-sampling settings of the related
federated-bilevel work (uniform sampling à la Gao arXiv:2204.13299;
availability traces à la the asynchronous setting of Jiao et al.
arXiv:2212.10048):

  uniform     — C clients uniformly without replacement each round.
  roundrobin  — deterministic cyclic sweep; every client participates exactly
                once per ⌈N/C⌉ rounds (useful for coverage tests & debugging).
  trace       — each client has a periodic up/down availability schedule
                (random phase); the cohort is drawn uniformly from the
                currently-available clients. If fewer than C are up, the
                available set is cycled to fill the fixed-shape cohort
                (duplicates are an availability artifact, and are weighted
                like any repeated participant by the aggregation). If NO
                client is up, the draw falls back to uniform without
                replacement over all N (docs/async.md documents why).
  trace-file  — same cohort draw, but availability replays a recorded
                device trace (JSONL of per-client up intervals,
                :func:`load_trace`) instead of a synthetic periodic
                schedule; the trace cycles past its horizon.
"""
from __future__ import annotations

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

SAMPLERS = ("uniform", "roundrobin", "trace", "trace-file")


def draw_from_available(up: jax.Array, key: jax.Array, round_id: int,
                        c: int) -> jax.Array:
    """Uniform cohort draw (without replacement) from the up set.

    Available clients get scores in [-1, 0), unavailable in [0, 1): argsort
    ranks every up client ahead of every down client, with a uniform shuffle
    within each group. A shortfall (0 < #up < C) cycles the up set so the
    cohort keeps its static shape [c]; an EMPTY up set falls back to a
    uniform draw without replacement over all N clients — the defined
    all-clients-down behaviour (every score then sits in [0, 1), so the
    argsort is already a uniform permutation of the full population).
    """
    n = up.shape[0]
    k = jax.random.fold_in(key, round_id)
    score = jax.random.uniform(k, (n,)) - up.astype(jnp.float32)
    order = jnp.argsort(score)
    pool = jnp.where(up.sum() > 0, up.sum(), n)
    slot = jnp.arange(c)
    # slots beyond the pool wrap around the available prefix rather than
    # dipping into down clients
    return order[jnp.where(slot < pool, slot, slot % pool)].astype(jnp.int32)


class CohortSampler:
    """Protocol: deterministic ``round_id -> [c] int32 global client ids``."""

    n: int
    c: int

    def cohort(self, round_id: int) -> jax.Array:
        raise NotImplementedError

    def mask(self, round_id: int) -> jax.Array:
        """Boolean participation mask over the full population — the legacy
        masked-participation view of the same draw."""
        return jnp.zeros((self.n,), bool).at[self.cohort(round_id)].set(True)


@dataclasses.dataclass(frozen=True)
class UniformSampler(CohortSampler):
    """C of N uniformly at random, without replacement, per round."""
    n: int
    c: int
    key: jax.Array

    def cohort(self, round_id: int) -> jax.Array:
        k = jax.random.fold_in(self.key, round_id)
        return jax.random.permutation(k, self.n)[: self.c].astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class RoundRobinSampler(CohortSampler):
    """Cyclic sweep: round r takes clients [r*c, r*c + c) mod n."""
    n: int
    c: int
    offset: int = 0

    def cohort(self, round_id: int) -> jax.Array:
        start = self.offset + round_id * self.c
        return ((start + jnp.arange(self.c)) % self.n).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class AvailabilityTraceSampler(CohortSampler):
    """Clients follow periodic up/down schedules; sample among the available.

    Client i is up at round r iff ``(r + phase_i) % period < duty * period``,
    with a random per-client phase derived from ``key``. The cohort is a
    uniform draw (without replacement) from the up set; a shortfall cycles
    the up set so the cohort keeps its static shape [c].
    """
    n: int
    c: int
    key: jax.Array
    period: int = 8
    duty: float = 0.5

    def _phases(self) -> jax.Array:
        # schedule salt kept off the per-round fold_in(round_id) stream
        return jax.random.randint(jax.random.fold_in(self.key, 0x7FFFFFFF),
                                  (self.n,), 0, self.period)

    def up_mask(self, round_id: int) -> jax.Array:
        up_len = max(int(round(self.duty * self.period)), 1)
        return (round_id + self._phases()) % self.period < up_len

    def cohort(self, round_id: int) -> jax.Array:
        return draw_from_available(self.up_mask(round_id), self.key,
                                   round_id, self.c)


# ------------------------------------------------------------ trace replay

def load_trace(path: str, n: int) -> np.ndarray:
    """Load a JSONL availability trace into a dense [horizon, n] bool table.

    One line per client: ``{"client": i, "up": [[start, end], ...]}`` —
    client ``i`` is available during the half-open round intervals
    ``[start, end)``. An optional ``{"horizon": T}`` line fixes the table
    length; otherwise the horizon is the max interval end, stretched to
    the longest per-client ``"delay"`` list so availability and the
    :func:`load_delay_trace` delay table always cycle with the SAME
    period. Clients absent from the file — or listed with a ``"delay"``
    but no ``"up"`` key — are always available (an un-instrumented device
    is assumed up). Format spec + worked example: docs/async.md.
    """
    explicit = None
    derived = 0
    intervals = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            if "horizon" in rec:
                explicit = int(rec["horizon"])
                if explicit < 1:
                    raise ValueError(f"horizon must be >= 1 round, "
                                     f"got {explicit}")
                continue
            i = int(rec["client"])
            if not 0 <= i < n:
                raise ValueError(f"trace client id {i} outside population "
                                 f"[0, {n})")
            if "up" in rec:
                ivs = [(int(a), int(b)) for a, b in rec["up"]]
                for a, b in ivs:
                    if a < 0 or b < a:
                        raise ValueError(f"bad up interval [{a}, {b}) for "
                                         f"client {i}")
                    derived = max(derived, b)
                intervals[i] = intervals.get(i, []) + ivs
            if "delay" in rec:
                d = rec["delay"]
                derived = max(derived,
                              len(d) if isinstance(d, list) else 1)
    # an explicit horizon line FIXES the trace length (docs/async.md);
    # intervals past it are clipped. Without one, the max interval end wins.
    horizon = explicit if explicit is not None else derived
    if horizon == 0:
        raise ValueError(f"trace {path!r} has no up intervals, no delay "
                         f"lists, and no horizon line")
    table = np.zeros((horizon, n), bool)
    table[:, [i for i in range(n) if i not in intervals]] = True
    for i, ivs in intervals.items():
        for a, b in ivs:
            table[a:min(b, horizon), i] = True
    return table


def save_trace(path: str, table: np.ndarray, delays=None) -> None:
    """Write a dense [horizon, n] availability table as the JSONL trace
    format :func:`load_trace` reads (maximal up intervals per client).

    ``delays``, if given, adds the optional per-client ``"delay"`` field
    :func:`load_delay_trace` reads: an [n] vector writes one constant delay
    per client, a [horizon, n] table writes the per-round delay list
    (constant columns collapse to the scalar form)."""
    table = np.asarray(table, bool)
    horizon, n = table.shape
    if delays is not None:
        delays = np.asarray(delays, np.int64)
        if delays.shape not in ((n,), (horizon, n)):
            raise ValueError(f"delays must be [n] or [horizon, n] for a "
                             f"[{horizon}, {n}] table, got "
                             f"{delays.shape}")
    with open(path, "w") as f:
        f.write(json.dumps({"horizon": int(horizon)}) + "\n")
        for i in range(n):
            col = table[:, i]
            edges = np.flatnonzero(np.diff(np.concatenate(
                ([False], col, [False]))))
            ivs = [[int(a), int(b)] for a, b in
                   zip(edges[::2], edges[1::2])]
            rec = {"client": i, "up": ivs}
            if delays is not None:
                d = delays[i] if delays.ndim == 1 else delays[:, i]
                if np.ndim(d) == 0 or (np.asarray(d) == np.asarray(d).flat[0]).all():
                    rec["delay"] = int(np.asarray(d).flat[0])
                else:
                    rec["delay"] = [int(v) for v in d]
            f.write(json.dumps(rec) + "\n")


def load_delay_trace(path: str, n: int) -> np.ndarray:
    """Parse the JSONL trace's optional per-client ``"delay"`` field into a
    dense [horizon, n] int32 per-round delay table (the ``trace`` delay
    model of ``repro.fed.population.DelayModel``).

    A client line may carry ``"delay": d`` (every dispatch of client ``i``
    returns after ``d`` rounds) or ``"delay": [d0, d1, ...]`` (the list is
    tiled across the trace horizon — a dispatch at round ``r < horizon``
    takes ``d[r % len(d)]`` rounds; past the horizon the WHOLE trace
    cycles, row ``r % horizon``, exactly like the availability table).
    Clients without the field — or absent from the file — default to
    delay 1: an un-instrumented device is assumed fast, mirroring
    :func:`load_trace`'s always-available default. Delays must be >= 1
    round. The horizon follows :func:`load_trace`'s rules (explicit
    ``horizon`` line, else the max up-interval end), additionally
    stretched to the longest delay list; a delay list LONGER than an
    explicit horizon is an error (silently truncating recorded delays
    would drop e.g. a straggler's slow rounds). A trace with neither
    intervals nor a horizon line gets horizon 1. Format spec + worked
    example: docs/async.md.
    """
    explicit = None
    derived = 0
    delays = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            if "horizon" in rec:
                explicit = int(rec["horizon"])
                if explicit < 1:
                    raise ValueError(f"horizon must be >= 1 round, "
                                     f"got {explicit}")
                continue
            i = int(rec["client"])
            if not 0 <= i < n:
                raise ValueError(f"trace client id {i} outside population "
                                 f"[0, {n})")
            for a, b in rec.get("up", []):
                derived = max(derived, int(b))
            if "delay" in rec:
                d = rec["delay"]
                seq = [int(d)] if np.ndim(d) == 0 else [int(v) for v in d]
                if any(v < 1 for v in seq):
                    raise ValueError(f"client {i} delays must be >= 1 "
                                     f"round, got {seq}")
                if seq:
                    delays[i] = seq
                    derived = max(derived, len(seq))
    horizon = explicit if explicit is not None else max(derived, 1)
    table = np.ones((horizon, n), np.int32)
    for i, seq in delays.items():
        if len(seq) > horizon:
            raise ValueError(
                f"client {i} has {len(seq)} recorded delays but the trace "
                f"horizon is {horizon}: raise the horizon line (truncating"
                f" would silently drop recorded delays)")
        table[:, i] = np.resize(np.asarray(seq, np.int32), horizon)
    return table


@dataclasses.dataclass(frozen=True)
class TraceFileSampler(CohortSampler):
    """Replay a recorded availability trace ([horizon, n] bool table).

    ``up_mask(r)`` is row ``r % horizon`` (the trace cycles past its
    horizon); the cohort draw — including the shortfall cycling and the
    all-down uniform fallback — is :func:`draw_from_available`, shared with
    the synthetic ``trace`` sampler, so replaying a trace generated from a
    periodic schedule reproduces that schedule's cohorts exactly
    (tests/test_property.py).
    """
    n: int
    c: int
    key: jax.Array
    table: np.ndarray            # [horizon, n] bool (host-side, static)

    @classmethod
    def from_file(cls, path: str, n: int, c: int,
                  key: jax.Array) -> "TraceFileSampler":
        return cls(n, c, key, load_trace(path, n))

    def up_mask(self, round_id: int) -> jax.Array:
        return jnp.asarray(self.table[int(round_id) % self.table.shape[0]])

    def cohort(self, round_id: int) -> jax.Array:
        return draw_from_available(self.up_mask(round_id), self.key,
                                   round_id, self.c)


def in_scan_cohort_fn(sampler: CohortSampler):
    """A jit-traceable ``round_id -> [c] int32 ids`` draw for the mega-scan
    tier, or ``None`` when the sampler's draw needs host state.

    Uniform and roundrobin cohorts are pure functions of (key, round_id):
    ``fold_in`` + ``permutation`` and modular arithmetic both trace fine
    with a round_id that is a scanned loop variable, and produce draws
    bit-identical to the host-side ``cohort()`` calls — the equality the
    hypothesis property in tests/test_property.py pins, and the reason the
    driver can keep drawing ids on the host (for batch gather and unique-
    transmitter byte accounting) while the mega program re-draws them
    in-scan. Trace-backed samplers index a host numpy table per round, so
    they return ``None`` here and the driver prefetches their cohorts per
    chunk instead (docs/megascan.md).
    """
    if isinstance(sampler, (UniformSampler, RoundRobinSampler)):
        return sampler.cohort
    return None


def make_sampler(name: str, n: int, c: int, key: jax.Array, *,
                 period: int = 8, duty: float = 0.5,
                 offset: int = 0, trace_file: str = None) -> CohortSampler:
    if not 1 <= c <= n:
        raise ValueError(f"cohort size must satisfy 1 <= c <= n, "
                         f"got c={c}, n={n}")
    if name == "uniform":
        return UniformSampler(n, c, key)
    if name == "roundrobin":
        return RoundRobinSampler(n, c, offset)
    if name == "trace":
        return AvailabilityTraceSampler(n, c, key, period, duty)
    if name == "trace-file":
        if not trace_file:
            raise ValueError("sampler 'trace-file' needs trace_file=<path> "
                             "(JSONL availability trace, see docs/async.md)")
        return TraceFileSampler.from_file(trace_file, n, c, key)
    raise KeyError(f"unknown sampler {name!r}; known: {SAMPLERS}")
