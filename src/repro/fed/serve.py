"""Serving path: prefill + single-token decode for the (post-training) global
model x̄, ȳ — no client axis. Used by the decode/prefill dry-run shapes and the
serving example."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.decode import cache_spec, decode_step, prefill
from repro.models.model import ModelCtx, model_specs
from repro.models.params import abstract_params, axes_tree
from repro import sharding as shlib


def serve_window(cfg: ArchConfig, shape: ShapeConfig) -> Optional[int]:
    """long_500k: attention archs fall back to their sliding-window variant
    (SSM/hybrid state is already O(1); hybrid's shared attention also windows)."""
    if shape.seq_len > 65536 and cfg.family != "ssm":
        return cfg.long_context_window
    return None


def serve_batch_specs(cfg: ArchConfig, shape: ShapeConfig,
                      kind: str) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    b = shape.global_batch
    s = shape.seq_len
    d = cfg.d_model
    specs: Dict[str, Any] = {}
    axes: Dict[str, Any] = {}
    # embeddings enter the residual stream directly, so they must carry the
    # model's activation dtype (a hardcoded bf16 spec breaks f32 models:
    # the encoder scan carry would change dtype mid-loop)
    emb = jnp.dtype(cfg.dtype)
    if kind == "prefill":
        sdec = max(s // 4, 8) if cfg.family == "encdec" else s
        specs["tokens"] = jax.ShapeDtypeStruct((b, sdec), jnp.int32)
        axes["tokens"] = ("batch", None)
        if cfg.n_prefix_embeds:
            specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_prefix_embeds, d), emb)
            axes["prefix_embeds"] = ("batch", None, "act_embed")
        if cfg.family == "encdec":
            specs["enc_embeds"] = jax.ShapeDtypeStruct((b, s, d), emb)
            axes["enc_embeds"] = ("batch", "seq", "act_embed")
    else:
        specs["token"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        axes["token"] = ("batch", None)
    return specs, axes


def serve_cache(cfg: ArchConfig, shape: ShapeConfig, kv_quant: bool = False):
    window = serve_window(cfg, shape)
    enc_len = shape.seq_len if cfg.family == "encdec" else 0
    spec, axes = cache_spec(cfg, shape.global_batch, shape.seq_len,
                            window=window, enc_len=enc_len, quant=kv_quant)
    return spec, axes, window


def build_serve_fns(cfg: ArchConfig, shape: ShapeConfig, mesh: Optional[Mesh],
                    kv_quant: bool = False, kv_kernel: str = "xla"):
    """Returns dict with jitted prefill_fn/decode_fn + abstract inputs for
    lowering. Params are a single (client-free) model pytree.

    ``kv_kernel`` selects the int8-KV decode attention path (see
    ``ModelCtx.kv_kernel``): "xla" reference dequant, "pallas" fused kernel,
    "interpret" the same kernel in Pallas interpret mode (CPU-safe)."""
    specs = model_specs(cfg)
    p_axes = axes_tree(specs)
    p_abs = abstract_params(specs, cfg.dtype)
    cache_abs, cache_axes, window = serve_cache(cfg, shape, kv_quant)

    kind = "prefill" if shape.kind == "prefill" else "decode"
    rules = shlib.rules_for(cfg, mesh, kind) if mesh is not None else None
    if rules is not None and shape.global_batch == 1:
        rules = dict(rules)
        rules["batch"] = None            # long_500k: nothing to shard on batch
    ctx = ModelCtx(rules=rules, kind=kind, window=window, kv_kernel=kv_kernel)

    def prefill_fn(params, batch, cache):
        return prefill(cfg, params, batch, cache, ctx)

    def decode_fn(params, cache, token, pos):
        return decode_step(cfg, params, cache, token, pos, ctx)

    out: Dict[str, Any] = {
        "params_abs": p_abs,
        "cache_abs": cache_abs,
        "window": window,
        "ctx": ctx,
    }
    batch_specs, batch_axes = serve_batch_specs(cfg, shape, kind)
    out["batch_specs"] = batch_specs
    if mesh is None:
        out["prefill"] = jax.jit(prefill_fn)
        out["decode"] = jax.jit(decode_fn)
        return out

    p_sh = shlib.tree_shardings(p_axes, rules, mesh, p_abs,
                            fallback=("model",))
    c_sh = shlib.tree_shardings(cache_axes, rules, mesh, cache_abs)
    b_sh = shlib.tree_shardings(batch_axes, rules, mesh, batch_specs)
    rep = NamedSharding(mesh, P())
    if kind == "prefill":
        out["prefill"] = jax.jit(
            prefill_fn, in_shardings=(p_sh, b_sh, c_sh),
            out_shardings=(NamedSharding(mesh, P()), c_sh))
        out["in_abs"] = (p_abs, batch_specs, cache_abs)
    else:
        out["decode"] = jax.jit(
            decode_fn, in_shardings=(p_sh, c_sh, b_sh["token"], rep),
            out_shardings=(NamedSharding(mesh, P()), c_sh),
            donate_argnums=(1,))
        out["in_abs"] = (p_abs, cache_abs, batch_specs["token"],
                         jax.ShapeDtypeStruct((), jnp.int32))
    out["params_shardings"] = p_sh
    out["cache_shardings"] = c_sh
    return out
