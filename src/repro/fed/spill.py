"""Host-spill tier for dormant client bank rows.

The sharded device bank (docs/sharding.md) divides the [N, ...] population
state over the mesh's client axes, but N is still capped by AGGREGATE device
memory. For populations beyond that, only the per-round cohort ever needs to
be resident on device: the other N - C rows are dormant until sampled. This
module keeps the full bank in HOST memory and moves exactly the cohort
across the host<->device boundary each round:

  * :meth:`HostSpillBank.gather` device_puts the C sampled rows (the round
    program is ``repro.fed.population.make_cohort_round`` — the same q-step
    scan / staleness-weighted aggregate / server update as the dense
    ``make_population_round``, minus the bank-sized operands);
  * the write-back is a host-side numpy update. ``broadcast`` (the sync
    population mode's write-back: every row := the new global state) is
    LAZY — the bank stores one ``base`` state plus a per-row ``fresh`` mask
    instead of memcpy-ing N rows, so a broadcast costs O(1) + the O(N) mask
    clear, and per-round host work stays O(C);
  * :meth:`HostSpillBank.prefetch` starts the NEXT round's cohort transfer
    early (``jax.device_put`` dispatches asynchronously), overlapping the
    host->device copy with the current round's host-side batch building.

Duplicate cohort ids resolve last-wins on write-back, matching the device
bank's deterministic ``repro.fed.population.scatter`` semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np


def _last_wins_mask(ids: np.ndarray) -> np.ndarray:
    """bool [C]: True at the LAST slot of each distinct id — the slots whose
    values a deterministic duplicate-resolving scatter writes."""
    # np.unique returns the FIRST occurrence; reverse to get the last
    c = ids.shape[0]
    _, first_of_reversed = np.unique(ids[::-1], return_index=True)
    keep = np.zeros(c, bool)
    keep[c - 1 - first_of_reversed] = True
    return keep


@dataclasses.dataclass
class HostSpillBank:
    """[N, ...] bank rows resident in host memory; cohorts travel on demand.

    ``rows`` holds every leaf as a numpy array with leading axis N.
    ``base``/``fresh`` implement the lazy broadcast: row i's authoritative
    value is ``rows[i]`` when ``fresh[i]`` else ``base`` (the last broadcast
    global state). ``base is None`` only before the first broadcast, when
    every row is fresh by construction.
    """
    rows: Any                       # pytree of np [N, ...]
    n: int
    base: Optional[Any] = None      # pytree of np [...] (one client state)
    fresh: Optional[np.ndarray] = None   # bool [N]

    def __post_init__(self):
        if self.fresh is None:
            self.fresh = np.ones(self.n, bool)
        self._prefetched: Optional[tuple] = None

    @classmethod
    def from_device(cls, bank) -> "HostSpillBank":
        """Move a device bank pytree to host storage (the one O(N) transfer
        of a spilled run — init still materializes the bank once).
        np.array (not asarray): device arrays view as read-only numpy, and
        ``scatter`` writes rows in place."""
        rows = jax.tree.map(lambda a: np.array(a), bank)
        n = jax.tree.leaves(rows)[0].shape[0]
        return cls(rows=rows, n=n)

    @property
    def nbytes(self) -> int:
        return sum(leaf.nbytes for leaf in jax.tree.leaves(self.rows))

    def _host_gather(self, ids: np.ndarray):
        def one(rows_leaf, base_leaf):
            out = rows_leaf[ids]
            if base_leaf is not None:
                stale = ~self.fresh[ids]
                if stale.any():
                    out[stale] = base_leaf
            return out
        if self.base is None:
            return jax.tree.map(lambda r: r[ids], self.rows)
        return jax.tree.map(one, self.rows, self.base)

    def gather(self, ids, device=None):
        """The cohort rows as device arrays ([C, ...] pytree). Consumes the
        matching :meth:`prefetch` result when one is pending."""
        # TraceAnnotation: the host-side spill phases show up as named
        # regions in a jax.profiler trace (docs/observability.md); no-op
        # (one cheap object) when no trace is active
        with jax.profiler.TraceAnnotation("spill_gather"):
            ids = np.asarray(ids)
            if self._prefetched is not None:
                key, tree = self._prefetched
                self._prefetched = None
                if np.array_equal(key, ids):
                    return tree
            out = self._host_gather(ids)
            return jax.device_put(out, device)

    def prefetch(self, ids, device=None) -> None:
        """Start the host->device transfer of a FUTURE cohort.
        ``jax.device_put`` dispatches asynchronously, so the copy overlaps
        whatever host work follows; the next :meth:`gather` with the same
        ids consumes it. Any bank write drops the prefetch (the rows may
        have changed)."""
        with jax.profiler.TraceAnnotation("spill_prefetch"):
            ids = np.asarray(ids)
            self._prefetched = (ids, jax.device_put(self._host_gather(ids),
                                                    device))

    def scatter(self, ids, values) -> None:
        """Write cohort rows back (host-side). Duplicate ids resolve
        last-wins, matching ``repro.fed.population.scatter``."""
        with jax.profiler.TraceAnnotation("spill_scatter"):
            ids = np.asarray(ids)
            self._prefetched = None
            keep = _last_wins_mask(ids)
            win_ids = ids[keep]

            def one(rows_leaf, vals):
                v = np.asarray(vals)[keep]
                rows_leaf[win_ids] = v.astype(rows_leaf.dtype)
            jax.tree.map(one, self.rows, values)
            self.fresh[win_ids] = True

    def broadcast(self, value) -> None:
        """Every row := one client state — lazily: store it as ``base`` and
        clear the ``fresh`` mask instead of writing N rows."""
        self._prefetched = None
        self.base = jax.tree.map(np.asarray, value)
        self.fresh[:] = False

    def lazy_leaves(self):
        """The bank as a pytree of :class:`repro.checkpoint.ckpt.LazyRows`
        leaves — ``save_checkpoint(..., shards=K)`` then pulls one shard's
        row range at a time, so a spilled checkpoint never materializes
        the dense [N, ...] bank (peak extra host memory is one shard)."""
        from repro.checkpoint.ckpt import LazyRows

        def one(rows_leaf, base_leaf):
            def fetch(lo, hi):
                out = rows_leaf[lo:hi].copy()
                if base_leaf is not None:
                    stale = ~self.fresh[lo:hi]
                    if stale.any():
                        out[stale] = base_leaf.astype(rows_leaf.dtype)
                return out
            return LazyRows(fetch, rows_leaf.shape, rows_leaf.dtype)
        if self.base is None:
            return jax.tree.map(lambda r: one(r, None), self.rows)
        return jax.tree.map(one, self.rows, self.base)

    def materialize(self):
        """The full dense [N, ...] bank (checkpointing / parity checks) —
        the only O(N*state) host operation besides construction."""
        if self.base is None:
            return jax.tree.map(np.copy, self.rows)

        def one(rows_leaf, base_leaf):
            out = rows_leaf.copy()
            out[~self.fresh] = base_leaf.astype(rows_leaf.dtype)
            return out
        return jax.tree.map(one, self.rows, self.base)
