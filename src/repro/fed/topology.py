"""Pluggable sync/aggregation topologies — the layer every engine syncs
through.

AdaFBiO's round structure (q local steps, one sync — paper §4, Remark 2) is
engine-independent, but what the *sync* does is a topology choice. This
module owns that choice behind one ``Aggregator`` contract:

  * :class:`StarAggregator` — the paper's star server: combine the client
    states into ONE average, run ``sync_update`` (Algorithm 1 lines 4-9)
    once, broadcast/scatter the result. All four pre-existing engines
    (eager / scan / population / async) sync through it; with it installed
    they are bit-identical to the pre-refactor implementations
    (tests/test_topology.py pins full trajectories).
  * :class:`GossipAggregator` — the decentralized setting of Gao–Gu–Thai
    (arXiv 2206.15025, PAPERS.md): no server. Each node keeps its OWN
    server state (adaptive matrices + step counter) and one sync is one
    doubly-stochastic mixing-matrix step ``x_i ← Σ_j W_ij x_j`` over a
    pluggable graph, followed by every node running ``sync_update`` on its
    own mixed average. On the complete graph W is uniform (every row
    ``1/n``), so gossip degenerates to the star population engine — the
    identity the parity tests ride on.

The aggregator contract (duck-typed; :class:`Aggregator` documents it):

  ``combine(states, weights=None)``
      [C, ...] client states → one average. ``weights=None`` is the plain
      mean (``tree_mean_axis0`` — what the trainer's all-clients sync
      computes); a [C] weight vector is the convex combination
      :func:`weighted_mean` (what the population/driver sites compute).
  ``server_step(server, avg)``
      the server update on the combined average → ``(new_client,
      new_server)``.
  ``reduce(server, states, weights=None)``
      convenience: ``server_step(server, combine(states, weights))``.
  ``messages(key, round_id, ids, ref, cur, ef)``
      the codec-priced uplink leg (``repro.fed.compress.client_messages``
      with the aggregator's codec) → ``(recon, new_ef)``.
  ``wire_round(msg_b, down_b, *, ...)``
      HOST-side per-sync wire pricing → ``(bytes_up, bytes_down)``. Star
      bills ``tx`` codec-priced uplinks + ``rx`` full-precision downlinks;
      gossip bills per DIRECTED EDGE — each node ships one codec-priced
      message along every out-edge and receives one along every in-edge
      (peer exchanges are compressed in both directions; self-loops are
      free). Moving the pricing behind the aggregator is what makes
      per-edge accounting possible at all.

Write-back (broadcast / scatter / pending-row sync) stays in the engines —
it is an *engine* policy (who receives the result), not a topology one.

Mixing matrices are Metropolis–Hastings over a symmetric adjacency::

    W_ij = A_ij / (1 + max(deg_i, deg_j)),   W_ii = 1 - Σ_{j≠i} W_ij

— symmetric and doubly stochastic by construction, so the mix preserves
the network average exactly and convergence is governed by the spectral
gap ``1 − |λ₂(W)|`` (:func:`spectral_gap`; the ``--bench topology`` sweep
grids it against convergence). Topology zoo: ring, 2D torus, complete,
Erdős–Rényi (static seeded, or time-varying — resampled every round from
``fold_in(fold_in(PRNGKey(seed), 0x70B0), round_id)``, a salt disjoint
from the local-step / codec / delay streams). Semantics and the wire
convention: docs/topology.md.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TOPOLOGIES, validate_topology
from repro.core.tree_util import tree_mean_axis0
from repro.fed.compress import Codec, client_messages

# RNG salt for time-varying graph draws — disjoint from the local-step
# fold_in(gid)/fold_in(t) stream, the codec salt (0xC0DEC) and the async
# delay salts, so changing topology never perturbs the sample draws
_TOPOLOGY_SALT = 0x70B0


def weighted_mean(states, w):
    """Convex combination over the leading client axis: ``Σ_i w_i ·
    state_i`` per leaf, computed in f32 and cast back to the leaf dtype
    (``w`` is a [C] weight vector). The canonical definition — the
    population, async and driver sync sites all aggregate through it."""
    return jax.tree.map(
        lambda a: jnp.tensordot(w, a.astype(jnp.float32),
                                axes=1).astype(a.dtype), states)


# ------------------------------------------------------------ topology zoo

def ring_adjacency(n: int) -> np.ndarray:
    """Cycle graph: node i ↔ i±1 (mod n). [n, n] bool, zero diagonal."""
    A = np.zeros((n, n), bool)
    for i in range(n):
        A[i, (i - 1) % n] = True
        A[i, (i + 1) % n] = True
    np.fill_diagonal(A, False)
    return A


def torus2d_dims(n: int) -> Tuple[int, int]:
    """The a × b grid of the 2D torus: a = largest divisor of n with
    a <= sqrt(n). Raises for prime n (a 1 × n "torus" is just the ring —
    ask for the ring instead)."""
    a = int(math.isqrt(n))
    while n % a:
        a -= 1
    if a == 1 and n > 2:
        raise ValueError(f"torus2d needs a composite population size to "
                         f"form an a x b grid, got prime n={n} "
                         f"(use topology='ring')")
    return a, n // a


def torus2d_adjacency(n: int) -> np.ndarray:
    """2D torus: nodes on an a × b wrap-around grid, each joined to its 4
    grid neighbours (fewer when a dimension has length <= 2)."""
    a, b = torus2d_dims(n)
    A = np.zeros((n, n), bool)
    for i in range(a):
        for j in range(b):
            u = i * b + j
            for v in (((i - 1) % a) * b + j, ((i + 1) % a) * b + j,
                      i * b + (j - 1) % b, i * b + (j + 1) % b):
                if v != u:
                    A[u, v] = True
                    A[v, u] = True
    return A


def complete_adjacency(n: int) -> np.ndarray:
    """Complete graph — Metropolis weights come out uniform (every entry
    ``1/n``), which is exactly the star engines' unweighted mean."""
    return ~np.eye(n, dtype=bool)


def erdos_adjacency(n: int, p: float, seed: int) -> np.ndarray:
    """Static seeded Erdős–Rényi graph G(n, p), unioned with the ring as a
    connectivity backbone (a disconnected component would never reach
    consensus: spectral gap 0). ``p`` therefore interpolates ring → complete."""
    rng = np.random.default_rng(seed)
    u = rng.random((n, n))
    A = np.triu(u < p, 1)
    A = A | A.T
    A |= ring_adjacency(n)
    np.fill_diagonal(A, False)
    return A


def metropolis_weights(adj):
    """Doubly-stochastic Metropolis–Hastings mixing matrix of a symmetric
    adjacency: ``W_ij = A_ij / (1 + max(deg_i, deg_j))``, diagonal takes
    the slack. Works on a host numpy adjacency (static topologies) or a
    traced jnp one (time-varying draws inside jit); returns f32 [n, n]."""
    A = jnp.asarray(adj)
    n = A.shape[0]
    A = jnp.logical_and(A, ~jnp.eye(n, dtype=bool))
    deg = jnp.sum(A, axis=1)
    pair = 1.0 + jnp.maximum(deg[:, None], deg[None, :]).astype(jnp.float32)
    W = jnp.where(A, 1.0 / pair, 0.0)
    return W + jnp.diag(1.0 - W.sum(axis=1))


def mixing_matrix(topology: str, n: int, *, er_p: float = 0.4,
                  seed: int = 0) -> np.ndarray:
    """The static [n, n] f32 Metropolis mixing matrix of a named topology."""
    if topology not in TOPOLOGIES:
        raise ValueError(f"topology must be one of {TOPOLOGIES}, "
                         f"got {topology!r}")
    if topology == "ring":
        A = ring_adjacency(n)
    elif topology == "torus2d":
        A = torus2d_adjacency(n)
    elif topology == "complete":
        A = complete_adjacency(n)
    else:
        A = erdos_adjacency(n, er_p, seed)
    return np.asarray(metropolis_weights(A), np.float32)


def sample_er_matrix(key, n: int, p: float):
    """One time-varying Erdős–Rényi draw INSIDE the round program: a
    symmetric Bernoulli(p) adjacency → Metropolis weights. No backbone —
    a transiently disconnected round just mixes less (B-connectivity in
    expectation is the time-varying analysis' assumption)."""
    u = jax.random.uniform(key, (n, n))
    up = jnp.triu(u < p, k=1)
    return metropolis_weights(jnp.logical_or(up, up.T))


def spectral_gap(W) -> float:
    """``1 − |λ₂(W)|`` of a symmetric doubly-stochastic mixing matrix —
    the per-mix consensus contraction rate (0 = disconnected, 1 = one mix
    reaches exact consensus, i.e. the complete graph / star)."""
    lam = np.sort(np.abs(np.linalg.eigvalsh(np.asarray(W, np.float64))))
    return float(1.0 - (lam[-2] if lam.size > 1 else 0.0))


def directed_edges(W) -> int:
    """Directed (ordered-pair) edge count of a mixing matrix, self-loops
    excluded — the number of peer messages one gossip sync puts on the
    wire."""
    W = np.asarray(W)
    n = W.shape[0]
    return int(((W > 0) & ~np.eye(n, dtype=bool)).sum())


# ------------------------------------------------------------ the contract

class Aggregator:
    """The duck-typed sync contract (module docstring). Engines accept any
    object with these methods; :func:`as_aggregator` wraps a bare
    ``sync_update`` callable into the star default."""

    codec: Optional[Codec] = None

    def combine(self, states, weights=None):
        raise NotImplementedError

    def server_step(self, server, avg):
        raise NotImplementedError

    def reduce(self, server, states, weights=None):
        return self.server_step(server, self.combine(states, weights))

    def messages(self, key, round_id, ids, ref, cur, ef=None):
        """The codec-priced uplink leg; lossless codecs return ``(cur,
        ef)`` untouched so the pre-codec program is unchanged."""
        return client_messages(self.codec, key, round_id, ids, ref, cur, ef)

    def wire_round(self, msg_b: int, down_b: int, **counts) -> Tuple[int, int]:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class StarAggregator(Aggregator):
    """The paper's star server: one average, one ``sync_update``, one
    broadcast. ``sync_update(server, avg) -> (new_client, new_server)`` is
    the algorithm's server step with the population size already closed
    over. Installed as the default everywhere, it reproduces the
    pre-refactor engines bit-for-bit: ``combine`` with ``weights=None`` is
    exactly the trainer's ``tree_mean_axis0`` mean, with a weight vector
    exactly the population/driver ``weighted_mean`` tensordot."""
    sync_update: Callable[[Any, Any], Tuple[Any, Any]]
    codec: Optional[Codec] = None

    def combine(self, states, weights=None):
        if weights is None:
            return tree_mean_axis0(states)
        return weighted_mean(states, weights)

    def server_step(self, server, avg):
        return self.sync_update(server, avg)

    def wire_round(self, msg_b: int, down_b: int, *, tx: int,
                   rx: int) -> Tuple[int, int]:
        """``tx`` unique transmitters ship one codec-priced message each;
        ``rx`` receivers each take one full-precision downlink push."""
        return tx * msg_b, rx * down_b


def as_aggregator(sync_or_agg, codec: Optional[Codec] = None) -> Aggregator:
    """Normalize an engine's sync argument: an :class:`Aggregator` passes
    through (its own codec wins), a bare ``sync_update`` callable wraps
    into the star default with ``codec``."""
    if hasattr(sync_or_agg, "combine"):
        return sync_or_agg
    return StarAggregator(sync_update=sync_or_agg, codec=codec)


@dataclasses.dataclass(frozen=True)
class GossipAggregator(Aggregator):
    """Decentralized gossip: one doubly-stochastic Metropolis mixing step
    over a pluggable graph, then every node runs ``sync_update`` on its own
    mixed average against its OWN server state (the per-node server bank
    stacks the ``{"adaptive", "t"}`` tree on a leading [n] axis — every
    algorithm shares that structure, so ``vmap(sync_update)`` is generic).

    Static topologies build their mixing matrix once at construction;
    ``time_varying`` (erdos only) resamples it inside the round program
    from ``fold_in(fold_in(PRNGKey(seed), 0x70B0), round_id)`` — the host
    can replay the same draw eagerly (:meth:`host_matrix`) for per-round
    edge billing, so accounting stays exact even when the graph changes
    every round."""
    sync_update: Callable[[Any, Any], Tuple[Any, Any]]
    n: int
    topology: str = "ring"
    er_p: float = 0.4
    seed: int = 0
    time_varying: bool = False
    codec: Optional[Codec] = None

    def __post_init__(self):
        validate_topology(self.topology, self.er_p, self.time_varying)
        if not self.time_varying:
            W = mixing_matrix(self.topology, self.n, er_p=self.er_p,
                              seed=self.seed)
            object.__setattr__(self, "_W", jnp.asarray(W))

    # -------------------------------------------------- mixing

    def matrix(self, round_id):
        """The round's [n, n] mixing matrix: a baked constant for static
        topologies, an in-program draw for time-varying ones (``round_id``
        may be traced — mega-scan feeds it from the scan counter)."""
        if not self.time_varying:
            return self._W
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed),
                               _TOPOLOGY_SALT), round_id)
        return sample_er_matrix(key, self.n, self.er_p)

    def host_matrix(self, round_id: int) -> np.ndarray:
        """The same matrix evaluated eagerly on the host (jax RNG is
        deterministic across eager/jit) — for edge billing and reporting."""
        return np.asarray(self.matrix(round_id))

    def mix(self, states, W):
        """One mixing step per leaf: ``x ← W @ x`` over the leading node
        axis, f32 accumulate, cast back (the [n]-batched ``weighted_mean``)."""
        return jax.tree.map(
            lambda a: jnp.tensordot(W, a.astype(jnp.float32),
                                    axes=1).astype(a.dtype), states)

    def combine(self, states, weights=None):
        """Gossip's ``combine`` is row-wise: every node gets its own mixed
        average ([n, ...] in → [n, ...] out)."""
        if weights is not None:
            raise ValueError("gossip mixes with the matrix, not a weight "
                             "vector — staleness weighting is a star-sync "
                             "policy")
        return self.mix(states, self.matrix(0))

    def server_step(self, server, avg):
        """Per-node server step: ``server`` is the stacked [n] server bank,
        ``avg`` the [n, ...] mixed states."""
        return jax.vmap(self.sync_update)(server, avg)

    node_sync = server_step

    # -------------------------------------------------- wire accounting

    def edges(self, round_id: int = 0) -> int:
        """Directed peer-message count of the round's graph (self-loops
        free — a node does not pay to keep its own state)."""
        return directed_edges(self.host_matrix(round_id))

    def wire_round(self, msg_b: int, down_b: int, *,
                   edges: int) -> Tuple[int, int]:
        """Per-edge pricing: every directed edge carries ONE codec-priced
        message — the sender's uplink is the receiver's downlink (there is
        no full-precision broadcast in a gossip round, so ``down_b`` is
        unused by construction)."""
        del down_b
        return edges * msg_b, edges * msg_b

    @property
    def gap(self) -> float:
        """Spectral gap of the round-0 mixing matrix."""
        return spectral_gap(self.host_matrix(0))


# ------------------------------------------------------------ round program

def make_gossip_round(local_step, agg: GossipAggregator, q: int):
    """Build the fused gossip round — the fifth engine's program, same
    shape as the star engines' (the mix that closes the PREVIOUS round,
    then this round's q local steps as one ``lax.scan``).

    ``local_step(bank, srv_bank, batch, key, ids)`` advances all n nodes
    one local step against their own server rows. Returns ``round(bank,
    srv_bank, ef, batches_q, key, round_id, *, n_steps, sync_first) ->
    (bank, srv_bank, ef)``; ``sync_first=False`` is round 0 (nothing to
    close). With a lossy codec the round ends by shipping each node's
    update through the codec against ``ref`` (the node's round-start
    state, which the previous mix made its peers' working copy); the bank
    row becomes the reconstruction — the shared public copy the NEXT mix
    consumes — and the per-node EF residual keeps the rest, exactly the
    population engine's bank-row convention (docs/topology.md)."""
    n = agg.n
    ids = jnp.arange(n, dtype=jnp.int32)
    codec = agg.codec
    lossy = codec is not None and codec.lossy

    def round_fn(bank, srv_bank, ef, batches_q, key, round_id, *,
                 n_steps=q, sync_first=True):
        if sync_first:
            with jax.named_scope("round/mix"):
                mixed = agg.mix(bank, agg.matrix(round_id - 1))
            with jax.named_scope("round/node_sync"):
                bank, srv_bank = agg.server_step(srv_bank, mixed)
        ref = bank                    # what the previous mix published

        def body(carry, batch):
            st, srv = carry
            st, srv = local_step(st, srv, batch, key, ids)
            return (st, srv), None

        with jax.named_scope("round/local_scan"):
            (bank, srv_bank), _ = jax.lax.scan(body, (bank, srv_bank),
                                               batches_q, length=n_steps)
        if lossy:
            with jax.named_scope("round/codec"):
                bank, ef = agg.messages(key, round_id, ids, ref, bank, ef)
        return bank, srv_bank, ef

    return round_fn
