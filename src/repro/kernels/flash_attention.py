"""Pallas TPU flash attention (GQA, causal, optional sliding window).

Target: TPU v5e. Grid = (B, H, Sq/bq); the KV dimension is looped inside the
kernel with VMEM-resident running max / denominator / accumulator, so the
per-step working set is (bq x D) + 2 x (bk x D) + (bq x bk) — tiled to fit
~VMEM with MXU-aligned (128) tile shapes. GQA maps q-head h to kv-head
h // (H/KV) in the BlockSpec index maps; repeated K/V heads are never
materialized. Validated against ``ref.flash_attention_ref`` in interpret mode
(this container is CPU-only; TPU is the compile target).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, sk: int, bk: int, bq: int,
            causal: bool, window: Optional[int], scale: float):
    qi = pl.program_id(2)
    q = q_ref[...].astype(jnp.float32) * scale          # [bq, D]
    d = q.shape[-1]
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, d), jnp.float32)
    nkv = sk // bk
    qpos = qi * bq + jax.lax.iota(jnp.int32, bq)

    def body(j, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(j * bk, bk), slice(None))
                    ).astype(jnp.float32)               # [bk, D]
        v = pl.load(v_ref, (pl.dslice(j * bk, bk), slice(None))
                    ).astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [bq,bk]
        kpos = j * bk + jax.lax.iota(jnp.int32, bk)
        msk = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            msk &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            msk &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(msk, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    # causal: kv blocks strictly above the diagonal contribute nothing
    hi = nkv if not causal else jnp.minimum(nkv, ((qi + 1) * bq + bk - 1) // bk)
    m, l, acc = jax.lax.fori_loop(0, hi, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: [B,H,Sq,D]; k,v: [B,KV,Sk,D]. Returns [B,H,Sq,D]."""
    b, h, sq, d = q.shape
    kv, sk = k.shape[1], k.shape[2]
    g = h // kv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0
    grid = (b, h, sq // bq)

    kernel = functools.partial(_kernel, sk=sk, bk=bk, bq=bq, causal=causal,
                               window=window, scale=d ** -0.5)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, bq, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((None, None, sk, d), lambda bi, hi, qi: (bi, hi // g, 0, 0)),
            pl.BlockSpec((None, None, sk, d), lambda bi, hi, qi: (bi, hi // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, bq, d),
                               lambda bi, hi, qi: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
