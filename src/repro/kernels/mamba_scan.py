"""Pallas TPU selective-scan kernel (mamba1 core recurrence).

Grid = (B, Di/bd); each grid cell owns a [bd] slice of the inner dimension
and walks the sequence in VMEM with the state h [bd, N] carried in registers/
VMEM scratch across a fori loop. Sequence chunks of the inputs are resident
as VMEM blocks ([S, bd] for x/dt, [S, N] for B/C). This mirrors the HBM->VMEM
chunking of the mamba CUDA kernel, re-tiled for the TPU VPU (the recurrence is
elementwise; the C-contraction is a [bd,N]x[N] reduce per step).

VMEM budget: bd=512, N=16, S-chunking via the grid's third dim would be the
next refinement; for the assigned configs S x (2*bd + 2*N) floats fit for
S <= 4096, which covers the train shape; serving uses the decode path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, *, s: int):
    # blocks: x/dt [S, bd]; a [bd, N]; b/c [S, N]; y [S, bd]; h out [bd, N]
    A = a_ref[...].astype(jnp.float32)                    # [bd, N]
    bd, n = A.shape
    h0 = jnp.zeros((bd, n), jnp.float32)

    def step(t, h):
        dt = dt_ref[t, :].astype(jnp.float32)             # [bd]
        x = x_ref[t, :].astype(jnp.float32)               # [bd]
        bt = b_ref[t, :].astype(jnp.float32)              # [N]
        ct = c_ref[t, :].astype(jnp.float32)              # [N]
        a = jnp.exp(dt[:, None] * A)                      # [bd, N]
        h = a * h + (dt * x)[:, None] * bt[None, :]
        y = jnp.sum(h * ct[None, :], axis=1)              # [bd]
        pl.store(y_ref, (t, slice(None)), y.astype(y_ref.dtype))
        return h

    h = jax.lax.fori_loop(0, s, step, h0)
    h_ref[...] = h


def mamba_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
               Cm: jax.Array, *, block_d: int = 512,
               interpret: bool = False):
    """x, dt: [B,S,Di]; A: [Di,N]; Bm,Cm: [B,S,N].
    Returns (y [B,S,Di], h_last [B,Di,N])."""
    b, s, di = x.shape
    n = A.shape[-1]
    bd = min(block_d, di)
    assert di % bd == 0
    grid = (b, di // bd)
    kernel = functools.partial(_kernel, s=s)
    y, h = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, s, bd), lambda bi, di_: (bi, 0, di_)),   # x
            pl.BlockSpec((None, s, bd), lambda bi, di_: (bi, 0, di_)),   # dt
            pl.BlockSpec((bd, n), lambda bi, di_: (di_, 0)),             # A
            pl.BlockSpec((None, s, n), lambda bi, di_: (bi, 0, 0)),      # B
            pl.BlockSpec((None, s, n), lambda bi, di_: (bi, 0, 0)),      # C
        ],
        out_specs=[
            pl.BlockSpec((None, s, bd), lambda bi, di_: (bi, 0, di_)),
            pl.BlockSpec((None, bd, n), lambda bi, di_: (bi, di_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, di), x.dtype),
            jax.ShapeDtypeStruct((b, di, n), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
    return y, h
