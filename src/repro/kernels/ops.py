"""jit'd public wrappers around the Pallas kernels.

``use_pallas`` selects the kernel; on this CPU-only container kernels run in
interpret mode (TPU is the compile target), so the default everywhere else in
the framework is the jnp reference path — the kernels are validated
against the oracles in tests/test_kernels.py and intended for the TPU build.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.tree_util import tree_pack, tree_unpack
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.mamba_scan import mamba_scan as _mamba
from repro.kernels.quantize import dequantize as _deq
from repro.kernels.quantize import quantize_stoch as _quant
from repro.kernels.storm_update import adafbio_update as _upd
from repro.kernels.storm_update import storm_update as _storm


def default_use_pallas() -> bool:
    """Pallas compiles for TPU; everywhere else the jnp reference path wins
    (interpret mode is an emulator, not a fast path)."""
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "use_pallas",
                                             "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, use_pallas=False,
                    interpret=True):
    if use_pallas:
        return _flash(q, k, v, causal=causal, window=window,
                      interpret=interpret)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def storm_update(g_new, g_old, est, beta, *, use_pallas=False, interpret=True):
    if use_pallas:
        return _storm(g_new, g_old, est, beta, interpret=interpret)
    return ref.storm_update_ref(g_new, g_old, est, beta)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def adafbio_update(p, w, a, lr_eta, rho, *, use_pallas=False, interpret=True):
    if use_pallas:
        return _upd(p, w, a, lr_eta, rho, interpret=interpret)
    return ref.adafbio_update_ref(p, w, a, lr_eta, rho)


@functools.partial(jax.jit, static_argnames=("qmax", "use_pallas",
                                             "interpret"))
def quantize_stoch(x, u, scale, *, qmax=127, use_pallas=False,
                   interpret=True):
    """Stochastic uniform quantization of a 1-D f32 buffer to int8 levels in
    [-qmax, qmax]; ``u`` is uniform[0, 1) rounding noise."""
    if use_pallas:
        return _quant(x, u, scale, qmax, interpret=interpret)
    return ref.quantize_stoch_ref(x, u, scale, qmax)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def dequantize(q, scale, *, use_pallas=False, interpret=True):
    """int8 levels * scale back to a 1-D f32 buffer."""
    if use_pallas:
        return _deq(q, scale, interpret=interpret)
    return ref.dequantize_ref(q, scale)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def mamba_scan(x, dt, A, Bm, Cm, *, use_pallas=False, interpret=True):
    if use_pallas:
        return _mamba(x, dt, A, Bm, Cm, interpret=interpret)
    return ref.mamba_scan_ref(x, dt, A, Bm, Cm)


# ------------------------------------------------------------ tree-level ops
#
# The flat-buffer path: pack a whole parameter pytree into ONE 1-D f32
# buffer (repro.core.tree_util.tree_pack) and run the fused elementwise
# kernel once over it, instead of one fused call per leaf. On TPU that is a
# single-pass single-launch update of the entire parameter vector; on CPU
# (and any non-TPU backend) the same math runs through the jnp reference on
# the packed buffer. Unpack casts back to each leaf's dtype.

def storm_update_tree(g_new, g_old, est, beta, *, use_pallas=None,
                      interpret=False, block: int = 65536):
    """STORM refresh (Eqs. 10-11) over a pytree via one flat buffer.

    Output leaves take ``est``'s dtypes (the estimator being refreshed).
    """
    if use_pallas is None:
        use_pallas = default_use_pallas()
    fl_est, spec = tree_pack(est)
    fl_new, _ = tree_pack(g_new, spec)
    fl_old, _ = tree_pack(g_old, spec)
    if use_pallas:
        out = _storm(fl_new, fl_old, fl_est, beta, block=block,
                     interpret=interpret)
    else:
        out = ref.storm_update_ref(fl_new, fl_old, fl_est, beta)
    return tree_unpack(out, spec)


def adafbio_update_tree(p, w, a, lr_eta, rho, *, use_pallas=None,
                        interpret=False, block: int = 65536):
    """Adaptive update (Eq. 14) over a pytree via one flat buffer."""
    if use_pallas is None:
        use_pallas = default_use_pallas()
    fl_p, spec = tree_pack(p)
    fl_w, _ = tree_pack(w, spec)
    fl_a, _ = tree_pack(a, spec)
    if use_pallas:
        out = _upd(fl_p, fl_w, fl_a, lr_eta, rho, block=block,
                   interpret=interpret)
    else:
        out = ref.adafbio_update_ref(fl_p, fl_w, fl_a, lr_eta, rho)
    return tree_unpack(out, spec)
