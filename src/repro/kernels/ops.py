"""jit'd public wrappers around the Pallas kernels.

``use_pallas`` selects the kernel; on this CPU-only container kernels run in
interpret mode (TPU is the compile target), so the default everywhere else in
the framework is the jnp reference path — the kernels are validated
against the oracles in tests/test_kernels.py and intended for the TPU build.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.mamba_scan import mamba_scan as _mamba
from repro.kernels.storm_update import adafbio_update as _upd
from repro.kernels.storm_update import storm_update as _storm


@functools.partial(jax.jit, static_argnames=("causal", "window", "use_pallas",
                                             "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, use_pallas=False,
                    interpret=True):
    if use_pallas:
        return _flash(q, k, v, causal=causal, window=window,
                      interpret=interpret)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def storm_update(g_new, g_old, est, beta, *, use_pallas=False, interpret=True):
    if use_pallas:
        return _storm(g_new, g_old, est, beta, interpret=interpret)
    return ref.storm_update_ref(g_new, g_old, est, beta)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def adafbio_update(p, w, a, lr_eta, rho, *, use_pallas=False, interpret=True):
    if use_pallas:
        return _upd(p, w, a, lr_eta, rho, interpret=interpret)
    return ref.adafbio_update_ref(p, w, a, lr_eta, rho)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def mamba_scan(x, dt, A, Bm, Cm, *, use_pallas=False, interpret=True):
    if use_pallas:
        return _mamba(x, dt, A, Bm, Cm, interpret=interpret)
    return ref.mamba_scan_ref(x, dt, A, Bm, Cm)
