"""Pallas TPU fused-dequant decode attention (int8 KV cache).

The memory-bound decode roofline term is HBM cache traffic; an int8 cache
halves it — but only if dequantization happens HBM->VMEM inside the kernel
(an XLA-level dequant materializes a bf16 copy and wins nothing). This kernel
reads int8 K/V tiles + per-token scales into VMEM, dequantizes in-register,
and runs the usual streaming-softmax decode attention.

Grid = (B, KV); the sequence is tiled with a fori loop over VMEM blocks.
Validated against ``ref.quant_decode_ref`` in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def quantize_kv(k: jax.Array):
    """[...] bf16 -> (int8, f32 scale over the last dim)."""
    kf = k.astype(jnp.float32)
    scale = jnp.max(jnp.abs(kf), axis=-1, keepdims=True) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(kf / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0]


def _kernel(q_ref, k_ref, ks_ref, v_ref, vs_ref, pos_ref, o_ref, *,
            smax: int, bs: int, g: int, dh: int):
    # blocks: q [G,D]; k/v [S,D] int8; ks/vs [S]; o [G,D]
    qv = q_ref[...].astype(jnp.float32) * dh ** -0.5       # [G, D]
    pos = pos_ref[0]
    m0 = jnp.full((g,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((g,), jnp.float32)
    a0 = jnp.zeros((g, dh), jnp.float32)

    def body(j, carry):
        m, l, acc = carry
        sl = pl.dslice(j * bs, bs)
        k8 = pl.load(k_ref, (sl, slice(None))).astype(jnp.float32)
        ks = pl.load(ks_ref, (sl,)).astype(jnp.float32)
        kb = k8 * ks[:, None]                              # dequant in VMEM
        s = jax.lax.dot_general(qv, kb, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [G,bs]
        slots = j * bs + jax.lax.iota(jnp.int32, bs)
        s = jnp.where((slots < pos)[None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        v8 = pl.load(v_ref, (sl, slice(None))).astype(jnp.float32)
        vs = pl.load(vs_ref, (sl,)).astype(jnp.float32)
        vb = v8 * vs[:, None]
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, vb, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        return m_new, l * corr + p.sum(axis=1), acc_new

    m, l, acc = jax.lax.fori_loop(0, smax // bs, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def quant_decode_attention(q: jax.Array, k8: jax.Array, k_scale: jax.Array,
                           v8: jax.Array, v_scale: jax.Array, pos, *,
                           block_s: int = 512, interpret: bool = False):
    """q: [B,H,Dh] (one token); k8/v8: [B,KV,S,Dh] int8;
    scales: [B,KV,S] f32; pos: valid length — scalar or [B] per-row vector
    (continuous batching). Returns [B,H,Dh]."""
    b, h, dh = q.shape
    kv, smax = k8.shape[1], k8.shape[2]
    g = h // kv
    bs = min(block_s, smax)
    assert smax % bs == 0
    q4 = q.reshape(b, kv, g, dh)
    pos_arr = jnp.broadcast_to(
        jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    kernel = functools.partial(_kernel, smax=smax, bs=bs, g=g, dh=dh)
    out = pl.pallas_call(
        kernel,
        grid=(b, kv),
        in_specs=[
            pl.BlockSpec((None, None, g, dh), lambda bi, ki: (bi, ki, 0, 0)),
            pl.BlockSpec((None, None, smax, dh), lambda bi, ki: (bi, ki, 0, 0)),
            pl.BlockSpec((None, None, smax), lambda bi, ki: (bi, ki, 0)),
            pl.BlockSpec((None, None, smax, dh), lambda bi, ki: (bi, ki, 0, 0)),
            pl.BlockSpec((None, None, smax), lambda bi, ki: (bi, ki, 0)),
            pl.BlockSpec((1,), lambda bi, ki: (bi,)),
        ],
        out_specs=pl.BlockSpec((None, None, g, dh), lambda bi, ki: (bi, ki, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, dh), q.dtype),
        interpret=interpret,
    )(q4, k8, k_scale, v8, v_scale, pos_arr)
    return out.reshape(b, h, dh)
