"""Pallas TPU stochastic uniform quantize/dequantize (comms compression).

The communication-compression codecs (``repro.fed.compress``) ship client
updates as b-bit integers + one f32 scale per tensor. The quantize leg is a
memory-bound elementwise pass (read f32, write int8 — a 4x HBM write saving
on TPU only if the rounding happens in-register); the dequantize leg is the
int8-read mirror. Both follow the repo's 1-D pad-to-block idiom
(``storm_update.py``): lane-aligned blocks over the flattened tensor,
zero-padded up to a block multiple and sliced back, so any buffer length
works.

Stochastic rounding noise is an explicit uniform[0, 1) input (drawn with
``jax.random`` outside the kernel) rather than the in-kernel TPU PRNG, so
the kernel is a deterministic function of its inputs and bit-matches the
``ref.quantize_stoch_ref`` oracle everywhere — including interpret mode on
CPU, where these are validated (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.storm_update import _pad_to_block, _padded


def _quantize_kernel(x_ref, u_ref, s_ref, out_ref, *, qmax: int):
    scale = s_ref[0]
    x = x_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    q = jnp.floor(x / scale + u)
    out_ref[...] = jnp.clip(q, -qmax, qmax).astype(jnp.int8)


def quantize_stoch(x: jax.Array, u: jax.Array, scale, qmax: int, *,
                   block: int = 65536, interpret: bool = False) -> jax.Array:
    """q = clip(floor(x / scale + u), -qmax, qmax) as int8, single pass.

    ``x``/``u`` are 1-D (any length; non-divisible lengths are zero-padded to
    a lane-aligned block multiple and sliced back), ``u`` is uniform[0, 1)
    rounding noise, ``scale`` a positive scalar. Unbiased:
    E_u[q * scale] = x whenever |x| <= qmax * scale.
    """
    (n,) = x.shape
    blk, padded = _pad_to_block(n, block)
    s = jnp.asarray([scale], jnp.float32)
    kernel = functools.partial(_quantize_kernel, qmax=qmax)
    out = pl.pallas_call(
        kernel,
        grid=(padded // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), jnp.int8),
        interpret=interpret,
    )(_padded(x, padded), _padded(u, padded), s)
    return out if padded == n else out[:n]


def _dequantize_kernel(q_ref, s_ref, out_ref):
    out_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[0]


def dequantize(q: jax.Array, scale, *, block: int = 65536,
               interpret: bool = False) -> jax.Array:
    """x = q * scale back to f32, single pass over a 1-D int8 buffer."""
    (n,) = q.shape
    blk, padded = _pad_to_block(n, block)
    s = jnp.asarray([scale], jnp.float32)
    out = pl.pallas_call(
        _dequantize_kernel,
        grid=(padded // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), jnp.float32),
        interpret=interpret,
    )(_padded(q, padded), s)
    return out if padded == n else out[:n]
