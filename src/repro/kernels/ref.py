"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None) -> jax.Array:
    """q: [B,H,Sq,D]; k,v: [B,KV,Sk,D] (unexpanded GQA). Returns [B,H,Sq,D]."""
    b, h, sq, d = q.shape
    kv = k.shape[1]
    g = h // kv
    q5 = q.reshape(b, kv, g, sq, d).astype(jnp.float32) * d ** -0.5
    logits = jnp.einsum("bkgqd,bksd->bkgqs", q5, k.astype(jnp.float32))
    sk = k.shape[2]
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    m = jnp.ones((sq, sk), bool)
    if causal:
        m &= kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    logits = jnp.where(m, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(b, h, sq, d).astype(q.dtype)


def storm_update_ref(g_new: jax.Array, g_old: jax.Array, est: jax.Array,
                     beta) -> jax.Array:
    """STORM (Eqs. 10-11): est' = g_new + (1-beta) * (est - g_old)."""
    f = jnp.float32
    out = g_new.astype(f) + (1.0 - beta) * (est.astype(f) - g_old.astype(f))
    return out.astype(est.dtype)


def adafbio_update_ref(p: jax.Array, w: jax.Array, a: jax.Array,
                       lr_eta, rho) -> jax.Array:
    """Fused adaptive step (Eq. 14): p' = p - lr_eta * w / (sqrt(a) + rho)."""
    f = jnp.float32
    upd = w.astype(f) / (jnp.sqrt(a.astype(f)) + rho)
    return (p.astype(f) - lr_eta * upd).astype(p.dtype)


def quantize_stoch_ref(x: jax.Array, u: jax.Array, scale,
                       qmax: int) -> jax.Array:
    """Stochastic uniform quantization: q = clip(floor(x/scale + u), ±qmax)
    as int8; ``u`` is uniform[0, 1) rounding noise. Unbiased:
    E_u[q * scale] = x whenever |x| <= qmax * scale."""
    f = jnp.float32
    q = jnp.floor(x.astype(f) / scale + u.astype(f))
    return jnp.clip(q, -qmax, qmax).astype(jnp.int8)


def dequantize_ref(q: jax.Array, scale) -> jax.Array:
    """x = q * scale back to f32."""
    return q.astype(jnp.float32) * scale


def quant_decode_ref(q: jax.Array, k8: jax.Array, k_scale: jax.Array,
                     v8: jax.Array, v_scale: jax.Array, pos) -> jax.Array:
    """Oracle for the fused-dequant decode kernel. q: [B,H,Dh];
    k8/v8: [B,KV,S,Dh] int8; scales [B,KV,S]."""
    b, h, dh = q.shape
    kv, smax = k8.shape[1], k8.shape[2]
    g = h // kv
    kf = k8.astype(jnp.float32) * k_scale[..., None]
    vf = v8.astype(jnp.float32) * v_scale[..., None]
    q4 = q.reshape(b, kv, g, dh).astype(jnp.float32) * dh ** -0.5
    logits = jnp.einsum("bkgd,bksd->bkgs", q4, kf)
    valid = jnp.arange(smax) < pos
    logits = jnp.where(valid[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", p, vf)
    return o.reshape(b, h, dh).astype(q.dtype)


def mamba_scan_ref(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                   Cm: jax.Array, h0: Optional[jax.Array] = None):
    """Selective scan (mamba1 core). x, dt: [B,S,Di]; A: [Di,N];
    Bm, Cm: [B,S,N]. Returns (y [B,S,Di], h_last [B,Di,N]). All f32 math."""
    b, s, di = x.shape
    n = A.shape[-1]
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf = Bm.astype(jnp.float32), Cm.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((b, di, n), jnp.float32)

    def step(h, t):
        a = jnp.exp(dtf[:, t, :, None] * Af)                  # [B,Di,N]
        bx = (dtf[:, t] * xf[:, t])[..., None] * Bf[:, t, None, :]
        h = a * h + bx
        y = jnp.einsum("bdn,bn->bd", h, Cf[:, t])
        return h, y

    h, ys = jax.lax.scan(step, h0, jnp.arange(s))
    return ys.swapaxes(0, 1).astype(x.dtype), h
