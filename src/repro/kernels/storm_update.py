"""Pallas TPU fused elementwise kernels for the paper's update rules.

These are the memory-bound hot spots of AdaFBiO: every local step touches
every parameter 3x (STORM refresh Eqs. 10-11, adaptive precondition + param
update Eq. 14). Fusing them into single-pass kernels halves HBM traffic vs
the unfused jnp ops. 1-D blocking over the flattened parameter vector with
VMEM tiles; lane-aligned (128) block sizes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _pad_to_block(n: int, block: int):
    """Pick a lane-aligned block and the padded length it divides."""
    blk = max(min(block, n), 1)
    if n % blk == 0:
        return blk, n
    blk = min(block, ((n + LANE - 1) // LANE) * LANE)
    padded = ((n + blk - 1) // blk) * blk
    return blk, padded


def _padded(x: jax.Array, padded: int) -> jax.Array:
    n = x.shape[0]
    if padded == n:
        return x
    return jnp.pad(x, (0, padded - n))


def _storm_kernel(gn_ref, go_ref, est_ref, beta_ref, out_ref):
    beta = beta_ref[0]
    gn = gn_ref[...].astype(jnp.float32)
    go = go_ref[...].astype(jnp.float32)
    est = est_ref[...].astype(jnp.float32)
    out_ref[...] = (gn + (1.0 - beta) * (est - go)).astype(out_ref.dtype)


def storm_update(g_new: jax.Array, g_old: jax.Array, est: jax.Array, beta,
                 *, block: int = 65536, interpret: bool = False) -> jax.Array:
    """est' = g_new + (1-beta)(est - g_old), single pass. 1-D inputs.

    Non-divisible ``n`` is zero-padded up to a lane-aligned block multiple and
    sliced back, so any flat-buffer length works.
    """
    (n,) = est.shape
    blk, padded = _pad_to_block(n, block)
    beta_arr = jnp.asarray([beta], jnp.float32)
    out = pl.pallas_call(
        _storm_kernel,
        grid=(padded // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), est.dtype),
        interpret=interpret,
    )(_padded(g_new, padded), _padded(g_old, padded), _padded(est, padded),
      beta_arr)
    return out if padded == n else out[:n]


def _update_kernel(p_ref, w_ref, a_ref, s_ref, out_ref):
    lr_eta, rho = s_ref[0], s_ref[1]
    p = p_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)
    out_ref[...] = (p - lr_eta * w / (jnp.sqrt(a) + rho)).astype(out_ref.dtype)


def adafbio_update(p: jax.Array, w: jax.Array, a: jax.Array, lr_eta, rho,
                   *, block: int = 65536, interpret: bool = False) -> jax.Array:
    """Fused Eq. (14): p' = p - lr_eta * A_t^{-1} w with A = diag(sqrt(a)+rho)."""
    (n,) = p.shape
    blk, padded = _pad_to_block(n, block)
    s = jnp.asarray([lr_eta, rho], jnp.float32)
    out = pl.pallas_call(
        _update_kernel,
        grid=(padded // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), p.dtype),
        interpret=interpret,
    )(_padded(p, padded), _padded(w, padded), _padded(a, padded), s)
    return out if padded == n else out[:n]
