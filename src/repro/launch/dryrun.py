import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init). This module is the ONLY place the 512 placeholder
# devices exist; tests/benchmarks see the real single CPU device.

"""Multi-pod dry-run: .lower().compile() every (arch x input-shape x mesh)
combination on the production meshes, record memory/cost/collective stats.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch a] [--shape s]
      [--mesh single|multi|both] [--force] [--out results/dryrun]

Results are cached per-cell as JSON; reruns skip finished cells.
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import FedConfig, INPUT_SHAPES, get_arch, list_arch_ids
from repro.fed.runtime import FederatedTrainer, client_batch_specs
from repro.fed.serve import build_serve_fns
from repro.launch.mesh import make_production_mesh

COLLECTIVE_RE = re.compile(
    r"^\s*%?\S+\s*=\s*\(?([a-z0-9]+)\[([0-9,]*)\][^ ]*\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)",
)
GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
               "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
               "u64": 8, "c64": 8}


BODY_RE = re.compile(r"body=%?([\w.\-]+)")
COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->")


def parse_collectives(hlo_text: str):
    """Sum result bytes per collective type (+ approximate group sizes).

    Also reports ``_in_loops_wire_bytes``: collectives that live inside
    while-loop body computations (our scans over layers / microbatches) — the
    roofline multiplies those by the trip count since the text shows one
    iteration.
    """
    loop_bodies = set(BODY_RE.findall(hlo_text))
    current = None
    in_loop_wire = 0.0
    out = {}
    for line in hlo_text.splitlines():
        comp = COMP_RE.match(line)
        if comp and "=" not in line.split("(")[0]:
            current = comp.group(1)
        m = COLLECTIVE_RE.match(line)
        if not m or "-done" in line.split("=", 1)[0]:
            continue
        dt, dims, op = m.groups()
        nbytes = DTYPE_BYTES.get(dt, 4)
        for d in dims.split(","):
            if d.strip():
                nbytes *= int(d)
        g = GROUPS_RE.search(line)
        gi = GROUPS_IOTA_RE.search(line)
        if g:
            gsize = len(g.group(1).split(","))
        elif gi:
            gsize = int(gi.group(2))
        else:
            gsize = 2
        rec = out.setdefault(op, {"count": 0, "bytes": 0, "wire_bytes": 0})
        rec["count"] += 1
        rec["bytes"] += nbytes
        in_body = current is not None and current in loop_bodies
        # ring-model bytes on the wire per participating device
        if op == "all-reduce":
            wire = 2 * nbytes * (gsize - 1) / max(gsize, 1)
        elif op == "all-gather":
            wire = nbytes * (gsize - 1) / max(gsize, 1)
        elif op == "reduce-scatter":
            wire = nbytes * (gsize - 1)
        elif op == "all-to-all":
            wire = nbytes * (gsize - 1) / max(gsize, 1)
        else:  # collective-permute
            wire = nbytes
        rec["wire_bytes"] += wire
        if in_body:
            in_loop_wire += wire
    out["_in_loops_wire_bytes"] = in_loop_wire
    return out


UPCAST_RE = re.compile(
    r"= f32\[([0-9,]+)\][^ ]*\s+convert\("
    r"%(?:param|Arg|arg|get-tuple-element)[^,)]*\)")


def cpu_f32_upcast_bytes(hlo_text: str) -> int:
    """CPU-backend artifact: bf16 dot operands are upcast to f32 and the
    converts of whole (loop-invariant) weight/cache stacks get hoisted,
    creating f32 copies that a TPU build (native bf16 MXU) does not have.

    Estimate: each DISTINCT converted shape is counted once (the same weight
    stack re-converted in several loop bodies shares liveness in practice);
    this is the number subtracted for "temp_bytes_tpu_adj" — a best-effort
    TPU-equivalent reading, reported alongside the raw CPU number.
    """
    from collections import Counter
    seen = Counter()
    total = 0
    for line in hlo_text.splitlines():
        m = UPCAST_RE.search(line)
        if not m:
            continue
        dims = m.group(1)
        # liveness cap: at most TWO simultaneous f32 copies per shape (e.g.
        # the K and V caches, or one fwd+bwd weight pair) — repeated converts
        # of the same source across loop bodies share liveness.
        if seen[dims] >= 2:
            continue
        n = 4
        for d in dims.split(","):
            n *= int(d)
        if n >= 64 * 2**20:
            seen[dims] += 1
            total += n
    return total


def _mem_stats(compiled, hlo_text=None):
    try:
        ma = compiled.memory_analysis()
        out = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "generated_code_bytes": int(ma.generated_code_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        }
        if hlo_text is not None:
            upc = cpu_f32_upcast_bytes(hlo_text)
            out["cpu_f32_upcast_bytes"] = upc
            out["temp_bytes_tpu_adj"] = max(out["temp_bytes"] - upc, 0)
        return out
    except Exception as e:  # backend-dependent
        return {"error": repr(e)}


def _cost_stats(compiled):
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and (
                    "flops" in k or "bytes" in k or "utilization" not in k)}
    except Exception as e:
        return {"error": repr(e)}


def lower_cell(arch_id: str, shape_id: str, multi_pod: bool):
    cfg = get_arch(arch_id)
    shape = INPUT_SHAPES[shape_id]
    mesh = make_production_mesh(multi_pod=multi_pod)
    fed = FedConfig()
    rec = {"arch": arch_id, "shape": shape_id,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "fed_mode": cfg.fed_mode, "params": cfg.param_count(),
           "active_params": cfg.active_param_count()}
    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            tr = FederatedTrainer(cfg, fed, shape, mesh=mesh)
            rec["n_clients"] = tr.m
            bspecs, baxes = client_batch_specs(cfg, shape, tr.m, fed)
            key = jax.ShapeDtypeStruct((2,), jnp.uint32)
            parts = {}
            for which in ("local", "sync"):
                fn = tr.jitted(which, bspecs, baxes, donate=False)
                if which == "local":
                    lowered = fn.lower(tr.abstract_client_states(),
                                       tr.abstract_server_state(), bspecs, key)
                else:
                    lowered = fn.lower(tr.abstract_client_states(),
                                       tr.abstract_server_state())
                compiled = lowered.compile()
                txt = compiled.as_text()
                parts[which] = {
                    "memory": _mem_stats(compiled, txt),
                    "cost": _cost_stats(compiled),
                    "collectives": parse_collectives(txt),
                }
            rec["steps"] = parts
        else:
            fns = build_serve_fns(cfg, shape, mesh)
            fn = fns["prefill"] if shape.kind == "prefill" else fns["decode"]
            lowered = fn.lower(*fns["in_abs"])
            compiled = lowered.compile()
            txt = compiled.as_text()
            rec["steps"] = {shape.kind: {
                "memory": _mem_stats(compiled, txt),
                "cost": _cost_stats(compiled),
                "collectives": parse_collectives(txt),
                "window": fns["window"],
            }}
    rec["compile_seconds"] = round(time.time() - t0, 1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    archs = [args.arch] if args.arch else list(list_arch_ids())
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                name = f"{arch}__{shape}__{'multi' if mp else 'single'}.json"
                path = out / name
                if path.exists() and not args.force:
                    n_skip += 1
                    continue
                print(f"[dryrun] {name} ...", flush=True)
                try:
                    rec = lower_cell(arch, shape, mp)
                    rec["ok"] = True
                    n_ok += 1
                except Exception as e:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single", "ok": False,
                           "error": repr(e),
                           "traceback": traceback.format_exc()[-4000:]}
                    n_fail += 1
                    print(f"  FAILED: {e!r}", flush=True)
                path.write_text(json.dumps(rec, indent=1))
                if rec.get("ok"):
                    mems = {k: (v["memory"].get("argument_bytes", -1)
                                + v["memory"].get("temp_bytes", 0)) / 2**30
                            for k, v in rec["steps"].items()}
                    print(f"  ok in {rec['compile_seconds']}s; arg-GiB/dev "
                          f"{ {k: round(v,2) for k,v in mems.items()} }", flush=True)
    print(f"[dryrun] done: ok={n_ok} fail={n_fail} skip={n_skip}")


if __name__ == "__main__":
    main()
