"""Production mesh builders (functions, not module constants, so importing
never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e: 16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever this host has (CPU smoke runs): data x model = (n, 1)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
