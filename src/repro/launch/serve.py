"""Serving launcher: continuous-batching greedy decode over a trained
checkpoint (docs/serving.md).

CPU usage (this container):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-4b --reduced \
      --ckpt /tmp/ck --slots 8 --requests 32 --metrics-out serve.jsonl

Without --ckpt the engine serves a seed-initialized model (smoke runs).
On a real cluster the same entry point takes --mesh local for sharded
params/cache via ``build_serve_fns``.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_arch, reduced
from repro.launch.mesh import make_local_mesh
from repro.models import init_params, model_specs
from repro.obs import make_telemetry
from repro.serve import Engine, LoadSpec, generate_requests, load_serve_params, replay
from repro.serve.engine import KV_KERNELS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-size variant of the same family")
    ap.add_argument("--ckpt", default=None,
                    help="launch/train.py checkpoint to serve (dense or "
                         "--ckpt-shards layout; repro.serve.bridge maps "
                         "the trained global state into serve params). "
                         "Omitted: seed-initialized params")
    ap.add_argument("--codec", default="none",
                    help="the TRAINING run's codec (none/int8/topk) — "
                         "needed to match lossy checkpoints' EF-bank "
                         "layout, lossless checkpoints ignore it")
    ap.add_argument("--slots", type=int, default=8,
                    help="continuous-batching slot-pool size (the shared "
                         "decode step's batch)")
    ap.add_argument("--max-len", type=int, default=256,
                    help="per-slot KV-cache capacity (prompt + generated)")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV-cache pool: prefill rows quantize on "
                         "the way in, decode attends through the fused "
                         "dequant path (attention families only)")
    ap.add_argument("--kv-kernel", default="auto", choices=list(KV_KERNELS),
                    help="int8 decode attention path: pallas (TPU fused "
                         "kernel), xla (reference dequant), interpret "
                         "(the kernel in Pallas interpret mode, CPU-safe); "
                         "auto = pallas on TPU else xla")
    ap.add_argument("--mesh", default="none", choices=["none", "local"])
    ap.add_argument("--requests", type=int, default=32,
                    help="synthetic open-loop request count")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate, requests/sec (0 = all "
                         "arrive at t=0: max-throughput drain)")
    ap.add_argument("--prompt-lens", default="8,16,32",
                    help="comma-separated prompt-length buckets (each "
                         "bucket compiles one prefill program)")
    ap.add_argument("--max-new", type=int, default=32,
                    help="per-request generation budget cap")
    ap.add_argument("--mean-new", type=float, default=16.0,
                    help="mean of the geometric output-length draw")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="retire a slot when this token is generated "
                         "(default: budget/capacity retirement only)")
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed (params init when no --ckpt, and the "
                         "load generator's arrivals/prompts/budgets)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the serve telemetry stream (manifest + "
                         "request/tick records + span summary) to this "
                         "JSONL file; render/validate it with "
                         "scripts/report.py")
    ap.add_argument("--metrics-every", type=int, default=8,
                    help="flush buffered request/tick records every K "
                         "engine ticks")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if args.slots < 1:
        raise SystemExit("--slots must be >= 1")
    prompt_lens = tuple(int(x) for x in args.prompt_lens.split(","))
    if max(prompt_lens) >= args.max_len:
        raise SystemExit(f"--prompt-lens {max(prompt_lens)} must stay below "
                         f"--max-len {args.max_len} (the cache holds prompt "
                         f"+ generated tokens)")
    mesh = make_local_mesh() if args.mesh == "local" else None

    if args.ckpt:
        params, info = load_serve_params(args.ckpt, cfg, codec=args.codec)
        print(f"loaded {args.ckpt}: layout={info['layout']} "
              f"clients={info['clients']} step={info['step']}")
    else:
        params = init_params(model_specs(cfg), jax.random.PRNGKey(args.seed),
                             cfg.dtype)
        print("no --ckpt: serving seed-initialized params")

    tele = make_telemetry(args.metrics_out, args.metrics_every)
    tele.manifest(config=vars(args), seed=args.seed)
    try:
        engine = Engine(cfg, params, slots=args.slots, max_len=args.max_len,
                        kv_quant=args.kv_quant, kv_kernel=args.kv_kernel,
                        mesh=mesh, eos_id=args.eos_id, telemetry=tele)
        spec = LoadSpec(n_requests=args.requests, rate=args.rate,
                        prompt_lens=prompt_lens,
                        mean_new_tokens=args.mean_new,
                        max_new_cap=args.max_new, seed=args.seed)
        enc = ((args.max_len, cfg.d_model) if cfg.family == "encdec"
               else None)
        pre = ((cfg.n_prefix_embeds, cfg.d_model) if cfg.n_prefix_embeds
               else None)
        reqs = generate_requests(spec, cfg.vocab, enc_shape=enc,
                                 prefix_shape=pre)
        t0 = time.perf_counter()
        done = replay(engine, reqs)
        wall = time.perf_counter() - t0
        toks = sum(len(c.tokens) for c in done)
        lats = sorted(c.latency_s for c in done)
        p = lambda q: lats[min(int(q * len(lats)), len(lats) - 1)]
        print(f"served {len(done)} requests in {wall:.2f}s — "
              f"{len(done) / wall:.2f} req/s, {toks / wall:.1f} tok/s, "
              f"p50 {p(0.5):.3f}s, p99 {p(0.99):.3f}s")
        tele.note(requests=len(done), wall_s=round(wall, 4),
                  requests_per_s=round(len(done) / wall, 4),
                  tokens_per_s=round(toks / wall, 3),
                  p50_s=round(p(0.5), 6), p99_s=round(p(0.99), 6))
    finally:
        tele.close()


if __name__ == "__main__":
    main()
