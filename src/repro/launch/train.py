"""Training launcher: AdaFBiO (or any baseline) on an assigned architecture.

CPU usage (this container):
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b --reduced \
      --steps 50 --seq 64 --batch 8

On a real cluster the same entry point takes --mesh prod / prod-multi, which
builds the 16x16 / 2x16x16 mesh and the full-size config.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import FedConfig, get_arch, reduced
from repro.configs.base import TOPOLOGIES, ShapeConfig
from repro.data.synthetic import (FederatedLMData, make_client_batch,
                                  make_cohort_batch)
from repro.fed.population import (DELAY_MODELS, accum_staleness_hist,
                                  accum_tier_hists, make_delay_model,
                                  parse_tier_spec)
from repro.fed.round import ENGINES
from repro.fed.runtime import FederatedTrainer, client_batch_specs
from repro.fed.sampling import (SAMPLERS, in_scan_cohort_fn,
                                load_delay_trace, make_sampler)
from repro.core.tree_util import tree_stack
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.obs import NULL, StatAccum, make_telemetry, progress_line


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--algorithm", default="adafbio")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-size variant of the same family")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8, help="global batch")
    ap.add_argument("--q", type=int, default=4)
    ap.add_argument("--neumann-k", type=int, default=2)
    ap.add_argument("--mesh", default="none", choices=["none", "local", "prod",
                                                       "prod-multi"])
    ap.add_argument("--seed", type=int, default=0,
                    help="run PRNG seed (init, data, samplers, codec "
                         "dither all derive from it)")
    ap.add_argument("--spill", default="none", choices=["none", "host"],
                    help="host: keep the [N, ...] population bank in host "
                         "memory and move only each round's cohort to "
                         "device (sync population mode only; "
                         "docs/sharding.md)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--eval-every", type=int, default=10)
    ap.add_argument("--engine", default="scan", choices=list(ENGINES),
                    help="scan: each q-step round + sync compiles as ONE "
                         "program; eager: one jitted call per local step")
    ap.add_argument("--rounds-per-scan", type=int, default=1,
                    help="mega-scan tier: compile R full rounds into ONE "
                         "program and loop over ceil(rounds/R) chunks, "
                         "draining metrics/stats once per chunk (1 = "
                         "per-round programs; docs/megascan.md)")
    ap.add_argument("--population", type=int, default=0,
                    help="client population size N: keep N persistent client "
                         "states and compute only a sampled cohort per round "
                         "(0 = legacy all-clients-every-round mode)")
    ap.add_argument("--cohort", type=int, default=8,
                    help="per-round compute cohort size C (population mode)")
    ap.add_argument("--sampler", default="uniform", choices=list(SAMPLERS),
                    help="cohort sampling policy (population mode)")
    ap.add_argument("--topology", default="ring", choices=list(TOPOLOGIES),
                    help="gossip communication graph (--engine gossip): "
                         "ring, torus2d, complete, or erdos; the mixing "
                         "matrix is the Metropolis weighting of the graph "
                         "(docs/topology.md)")
    ap.add_argument("--er-p", type=float, default=0.4,
                    help="erdos topology edge probability (a ring backbone "
                         "keeps the static graph connected)")
    ap.add_argument("--time-varying", action="store_true",
                    help="redraw the erdos gossip graph every round inside "
                         "the round program (erdos only; per-round edge "
                         "billing replays the same draw on host)")
    ap.add_argument("--topology-seed", type=int, default=0,
                    help="seed of the erdos graph draw (static and "
                         "time-varying)")
    ap.add_argument("--ckpt-shards", type=int, default=1,
                    help="split bank-sized checkpoint leaves over K "
                         "<path>.shard{k}.npz files (row-contiguous); 1 = "
                         "the legacy single-file layout. Sharded and dense "
                         "runs resume from each other's files")
    ap.add_argument("--trace-file", default=None,
                    help="JSONL availability trace replayed by the "
                         "trace-file sampler (format: docs/async.md)")
    ap.add_argument("--max-staleness", type=float, default=0.0,
                    help="0 = synchronous rounds; > 0 enables async "
                         "execution and drops arrivals staler than this "
                         "many rounds (inf = no gating)")
    ap.add_argument("--max-delay", type=int, default=1,
                    help="async dispatch return delay is uniform over "
                         "[1, max-delay] rounds (> 1 overlaps cohorts)")
    ap.add_argument("--delay-eta", type=float, default=0.0,
                    help="delay-adaptive server step: scale model movement "
                         "by 1/(1 + delay_eta*(mean_staleness - 1))")
    ap.add_argument("--delay-model", default="uniform",
                    choices=list(DELAY_MODELS),
                    help="async per-client delay model: uniform U[1, "
                         "max-delay]; tiers (permanent speed tiers, see "
                         "--tiers); lognormal (permanent per-client latency"
                         " quantized to rounds); trace (replay the "
                         "--trace-file's per-client 'delay' field)")
    ap.add_argument("--tiers", default=None,
                    help="tiers delay model spec frac:lo:hi[,frac:lo:hi"
                         "...], e.g. 0.2:1:1,0.6:2:4,0.2:4:8 (the default "
                         "20/60/20 fast/medium/straggler split)")
    ap.add_argument("--delay-mu", type=float, default=0.0,
                    help="lognormal delay model: log-latency location "
                         "(rounds)")
    ap.add_argument("--delay-sigma", type=float, default=0.5,
                    help="lognormal delay model: log-latency scale")
    ap.add_argument("--codec", default="none",
                    choices=["none", "int8", "topk"],
                    help="client→server update codec (population modes): "
                         "none (full precision), int8 (stochastic uniform "
                         "quantization), topk (magnitude sparsification "
                         "with error feedback); docs/compression.md")
    ap.add_argument("--codec-bits", type=int, default=8,
                    help="int8 codec quantization bit width (2..8; levels "
                         "shipped bit-packed, one f32 scale per tensor)")
    ap.add_argument("--topk-frac", type=float, default=0.1,
                    help="topk codec: fraction of each tensor's entries "
                         "transmitted (1.0 matches none to float rounding)")
    ap.add_argument("--ef", default="on", choices=["on", "off"],
                    help="error feedback: carry per-client compression "
                         "residuals into the next transmission")
    ap.add_argument("--metrics-out", default=None,
                    help="write the run's telemetry stream (manifest + "
                         "per-round records + on-device stats + summary) "
                         "to this JSONL file; render/validate it with "
                         "scripts/report.py (docs/observability.md)")
    ap.add_argument("--metrics-every", type=int, default=8,
                    help="drain the on-device stat accumulator (and flush "
                         "buffered round records) every K rounds — one "
                         "host transfer per K rounds")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="dump a TensorBoard-viewable jax.profiler trace "
                         "of the whole run into DIR (gather/round/scatter "
                         "show up as named regions; docs/observability.md)")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mesh = {"none": None, "local": make_local_mesh,
            "prod": make_production_mesh,
            "prod-multi": lambda: make_production_mesh(multi_pod=True)}[args.mesh]
    mesh = mesh() if callable(mesh) else mesh

    fed = FedConfig(q=args.q, neumann_k=args.neumann_k, lr_x=1e-2, lr_y=1e-1,
                    codec=args.codec, codec_bits=args.codec_bits,
                    topk_frac=args.topk_frac,
                    error_feedback=args.ef == "on")
    if args.codec != "none" and not args.population and args.engine != "scan":
        raise SystemExit("--codec int8/topk rides the fused round programs: "
                         "run with --population N (EF residuals live in "
                         "the bank) or the plain --engine scan path "
                         "(per-client EF rides the round carry, "
                         "docs/compression.md)")
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    tr = FederatedTrainer(cfg, fed, shape, mesh=mesh,
                          algorithm=args.algorithm)
    key = jax.random.PRNGKey(args.seed)
    tele = make_telemetry(args.metrics_out, args.metrics_every,
                          args.profile)
    tele.manifest(config=vars(args), seed=args.seed, mesh=tr.mesh)
    try:
        run_cli(args, cfg, fed, shape, tr, key, tele)
    finally:
        # writes the closing summary record and stops the profiler trace
        tele.close()


def run_cli(args, cfg, fed, shape, tr: FederatedTrainer, key, tele):
    if args.spill != "none" and not args.population:
        raise SystemExit("--spill host spills the population bank: run "
                         "with --population N")
    if args.rounds_per_scan < 1:
        raise SystemExit("--rounds-per-scan must be >= 1")
    if args.rounds_per_scan > 1:
        if args.spill != "none":
            raise SystemExit("--spill host streams the bank through host "
                             "memory round-by-round: the mega-scan tier "
                             "needs device-resident rounds (set "
                             "--rounds-per-scan 1 or --spill none)")
        if not args.population and args.engine != "scan":
            raise SystemExit("--rounds-per-scan > 1 fuses whole rounds into "
                             "one program: use --engine scan or a "
                             "--population mode")
    if args.engine == "gossip":
        if not args.population:
            raise SystemExit("--engine gossip is decentralized over a "
                             "population bank: run with --population N "
                             "(full participation, docs/topology.md)")
        if args.max_staleness != 0:
            raise SystemExit("--engine gossip runs synchronous lockstep "
                             "rounds: set --max-staleness 0")
        if args.spill != "none":
            raise SystemExit("--engine gossip mixes the whole bank every "
                             "round: the bank must stay device-resident "
                             "(--spill none)")
        run_gossip(args, cfg, fed, shape, tr, key, tele)
        return
    if args.population:
        run_population(args, cfg, fed, shape, tr, key, tele)
        return
    specs, axes = client_batch_specs(cfg, shape, tr.m, fed)
    data = FederatedLMData(vocab=cfg.vocab, n_clients=tr.m)
    batch = make_client_batch(data, cfg, specs, 0)
    states, server = tr.init_states(key, batch)
    # plain-path codec (docs/compression.md): the fused scan round carries
    # (ref, ef) — ref is the last broadcast every client started from, and
    # since each round ends by broadcasting the new global state, ref ==
    # states at every round boundary; only the EF residual checkpoints
    lossy = tr.codec.lossy
    ef = tr.init_ef_bank(tr.m) if lossy else None
    start = 0
    if args.resume and args.ckpt:
        tmpl = (states, server, ef) if ef is not None else (states, server)
        loaded, start = load_checkpoint(args.ckpt, tmpl)
        if ef is not None:
            states, server, ef = loaded
        else:
            states, server = loaded
        print(f"resumed from step {start}")

    ev = jax.jit(tr.eval_fn())

    t0 = time.time()
    steps_done = args.steps
    if args.engine == "scan":
        # fused round engine: q local steps + sync in one program per round
        n_rounds = max((args.steps - start) // fed.q, 1)
        steps_done = start + n_rounds * fed.q
        if steps_done != args.steps:
            print(f"engine=scan runs whole rounds: {steps_done - start} steps "
                  f"instead of the requested {args.steps - start} "
                  f"(use --steps divisible by q={fed.q})", flush=True)
        acc = (StatAccum.create(states, tele.metrics_every, tele.consensus)
               if tele.sinks else None)
        R = args.rounds_per_scan
        if R > 1:
            # mega-scan tier (docs/megascan.md): fuse R whole rounds into
            # ONE donated-carry program and loop over ceil(rounds/R)
            # chunks; stats sample chunk boundaries, one row per chunk
            from repro.fed.round import make_multi_round
            round0 = start // fed.q
            if lossy:
                base_c = tr.round_step_codec_fn()

                def one(carry, _ids, batch_q, kk, rid):
                    # ref == the round-start broadcast == the carried
                    # states at every boundary, so it never rides the
                    # carry (a duplicate would alias under donation)
                    st, srv, ef_ = carry
                    st, srv, _, ef_ = base_c(st, srv, st, ef_, batch_q,
                                             kk, rid)
                    return (st, srv, ef_), None
            else:
                base = tr.round_step_fn()

                def one(carry, _ids, batch_q, kk, _rid):
                    return base(carry[0], carry[1], batch_q, kk), None

            multi = jax.jit(make_multi_round(one), donate_argnums=(0,))
            r = 0
            while r < n_rounds:
                L = min(R, n_rounds - r)
                t = start + r * fed.q
                with tele.span("batch_build"):
                    batch_R = tree_stack([
                        tree_stack([make_client_batch(data, cfg, specs,
                                                      t + j * fed.q + jj)
                                    for jj in range(fed.q)])
                        for j in range(L)])
                r0 = time.time()
                with tele.span("round_program"):
                    if lossy:
                        (states, server, ef), _ = multi(
                            (states, server, ef), None, batch_R, key,
                            jnp.int32(round0 + r))
                    else:
                        (states, server), _ = multi((states, server), None,
                                                    batch_R, key,
                                                    jnp.int32(r))
                    jax.block_until_ready(states)
                dt = time.time() - r0
                for j in range(L):
                    tele.round(r + j, step=t + j * fed.q + fed.q - 1,
                               round_seconds=dt / L)
                if acc is not None:
                    acc.update(states)
                    if acc.ready:
                        tele.stats(**acc.drain())
                rr = r + L - 1
                if (any((r + j) % max(args.eval_every // fed.q, 1) == 0
                        for j in range(L)) or rr == n_rounds - 1):
                    last = jax.tree.map(lambda x: x[-1, -1], batch_R)
                    loss = float(ev(states, last))
                    print(progress_line(loss=loss, elapsed=time.time() - t0,
                                        step=t + (L - 1) * fed.q + fed.q - 1,
                                        round=rr, round_seconds=dt / L),
                          flush=True)
                r += L
        else:
            round0 = start // fed.q
            round_fn = jax.jit(tr.round_step_codec_fn() if lossy
                               else tr.round_step_fn())
            for r in range(n_rounds):
                t = start + r * fed.q
                with tele.span("batch_build"):
                    batch_q = tree_stack([make_client_batch(data, cfg, specs,
                                                            t + j)
                                          for j in range(fed.q)])
                r0 = time.time()
                with tele.span("round_program"):
                    if lossy:
                        states, server, _, ef = round_fn(
                            states, server, states, ef, batch_q, key,
                            jnp.int32(round0 + r))
                    else:
                        states, server = round_fn(states, server, batch_q,
                                                  key)
                    jax.block_until_ready(states)
                dt = time.time() - r0
                tele.round(r, step=t + fed.q - 1, round_seconds=dt)
                if acc is not None:
                    acc.update(states)
                    if acc.ready:
                        tele.stats(**acc.drain())
                if (r % max(args.eval_every // fed.q, 1) == 0
                        or r == n_rounds - 1):
                    last = jax.tree.map(lambda x: x[-1], batch_q)
                    loss = float(ev(states, last))
                    print(progress_line(loss=loss, elapsed=time.time() - t0,
                                        step=t + fed.q - 1, round=r,
                                        round_seconds=dt), flush=True)
        if acc is not None and acc.pending:
            tele.stats(**acc.drain())
    else:
        local = jax.jit(tr.local_step_fn())
        sync = jax.jit(tr.sync_step_fn())
        for t in range(start, args.steps):
            if t > 0 and t % fed.q == 0:
                states, server = sync(states, server)
            batch = make_client_batch(data, cfg, specs, t)
            states, server = local(states, server, batch, key)
            if t % args.eval_every == 0 or t == args.steps - 1:
                loss = float(ev(states, batch))
                print(progress_line(loss=loss, elapsed=time.time() - t0,
                                    step=t), flush=True)
    if args.ckpt:
        state = (states, server, ef) if ef is not None else (states, server)
        save_checkpoint(args.ckpt, state, steps_done,
                        shards=args.ckpt_shards)
        print(f"saved checkpoint to {args.ckpt} at step {steps_done}")


def run_population(args, cfg, fed, shape, tr: FederatedTrainer, key,
                   tele=NULL):
    """Population mode: N persistent client states, C-client cohort rounds.

    Each round: sample C global ids, build ONLY their batches (O(C) host
    work), then gather → fused scan round → aggregate → scatter as one
    jitted program (jits once for cohort shape [C, ...])."""
    n, c = args.population, args.cohort
    # per-client batch sizes derive from the cohort (the compute unit);
    # the bank-init batch reuses the same per-client shapes with leading N
    specs_c, axes_c = client_batch_specs(cfg, shape, c, fed)
    specs_n = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape[1:], s.dtype), specs_c)
    data = FederatedLMData(vocab=cfg.vocab, n_clients=n)
    sampler = make_sampler(args.sampler, n, c, jax.random.fold_in(key, 23),
                           trace_file=args.trace_file)
    if args.max_staleness != 0:
        if args.spill != "none":
            raise SystemExit("--spill host replays the synchronous "
                             "broadcast rounds: the async pending buffer "
                             "is device-resident (set --max-staleness 0)")
        run_population_async(args, cfg, fed, tr, key, data, specs_c,
                             axes_c, specs_n, sampler, tele)
        return
    if args.delay_model != "uniform" or args.tiers is not None:
        raise SystemExit("--delay-model / --tiers are async knobs: set "
                         "--max-staleness != 0 to enable asynchronous "
                         "execution")
    if args.spill != "none":
        run_population_spill(args, cfg, fed, tr, key, data, specs_c,
                             specs_n, sampler, tele)
        return
    bank, last_sync, server = tr.init_population_states(
        key, make_client_batch(data, cfg, specs_n, 0), n)
    lossy = tr.codec.lossy
    ef = tr.init_ef_bank(n)          # None unless the codec keeps EF state
    start = 0
    if args.resume and args.ckpt:
        tmpl = (bank, last_sync, ef, server) if lossy else (bank, last_sync,
                                                            server)
        loaded, start = load_checkpoint(args.ckpt, tmpl)
        if lossy:
            bank, last_sync, ef, server = loaded
        else:
            bank, last_sync, server = loaded
        print(f"resumed population run from step {start}")
    R = args.rounds_per_scan
    # mega-scan tier: uniform/roundrobin cohorts re-draw inside the scanned
    # program; host-state samplers (trace/trace-file) stay host-side and
    # prefetch the chunk's L cohorts up front (docs/megascan.md)
    cohort_fn = in_scan_cohort_fn(sampler) if R > 1 else None
    if tr.mesh is not None:
        # partition the bank rows (and EF stack / [N] bookkeeping) over the
        # mesh's client axes; the jitted round keeps the layout, so the
        # cohort gather is the only cross-shard op (docs/sharding.md)
        bank = jax.device_put(bank, tr.population_state_shardings(n))
        last_sync = jax.device_put(last_sync, tr.bank_vector_sharding(n))
        if ef is not None:
            ef = jax.device_put(ef, tr.population_state_shardings(n))
        if R > 1:
            round_fn = tr.jitted("multi_population_round", specs_c, axes_c,
                                 population_n=n, rounds_per_scan=R,
                                 cohort_fn=cohort_fn)
        else:
            round_fn = tr.jitted("population_round", specs_c, axes_c,
                                 population_n=n)
    elif R > 1:
        round_fn = jax.jit(
            tr.multi_population_round_fn(n, cohort_fn=cohort_fn),
            donate_argnums=(0, 2) if tr.codec.stateful else (0,))
    else:
        round_fn = jax.jit(tr.population_round_fn(n))
    ev = jax.jit(tr.eval_fn())
    msg_b, down_b = wire_costs(tr, n)
    bytes_up = bytes_down = 0

    start_round = start // fed.q
    n_rounds = max(args.steps // fed.q, start_round + 1)
    if n_rounds * fed.q != args.steps:
        print(f"population mode runs whole rounds: {n_rounds * fed.q} steps "
              f"instead of the requested {args.steps} "
              f"(use --steps divisible by q={fed.q})", flush=True)
    print(f"population mode: N={n} clients, C={c} cohort/round "
          f"({args.sampler} sampler), rounds {start_round}..{n_rounds - 1} "
          f"of q={fed.q}", flush=True)
    acc = (StatAccum.create(bank, tele.metrics_every, tele.consensus)
           if tele.sinks else None)
    eval_rounds = max(args.eval_every // fed.q, 1)
    t0 = time.time()
    if R > 1:
        r = start_round
        while r < n_rounds:
            L = min(R, n_rounds - r)
            # host always draws the cohorts (batch building + wire
            # accounting need the ids); in-scan draws replay the exact
            # same sequence (pinned by tests/test_property.py)
            ids_l = [np.asarray(sampler.cohort(r + j), np.int32)
                     for j in range(L)]
            with tele.span("batch_build"):
                batch_R = tree_stack([
                    tree_stack([make_cohort_batch(data, cfg, specs_c,
                                                  (r + j) * fed.q + jj,
                                                  ids_l[j])
                                for jj in range(fed.q)])
                    for j in range(L)])
            ids_R = (None if cohort_fn is not None
                     else jnp.asarray(np.stack(ids_l)))
            r0 = time.time()
            with tele.span("round_program"):
                if lossy:
                    bank, last_sync, ef, server = round_fn(
                        bank, last_sync, ef, server, ids_R, batch_R, key,
                        jnp.int32(r))
                else:
                    bank, last_sync, server = round_fn(
                        bank, last_sync, server, ids_R, batch_R, key,
                        jnp.int32(r))
                jax.block_until_ready(bank)
            dt = time.time() - r0
            for j in range(L):
                bytes_up += int(np.unique(ids_l[j]).size) * msg_b
                bytes_down += n * down_b
                tele.round(r + j, step=(r + j) * fed.q + fed.q - 1,
                           round_seconds=dt / L, bytes_up=bytes_up,
                           bytes_down=bytes_down)
            if acc is not None:
                # mega mode samples the on-device stats once per chunk
                acc.update(bank)
                if acc.ready:
                    tele.stats(**acc.drain())
            rr = r + L - 1
            if (any((r + j) % eval_rounds == 0 for j in range(L))
                    or rr == n_rounds - 1):
                last = jax.tree.map(lambda x: x[-1, -1], batch_R)
                loss = float(ev(bank, last))
                print(progress_line(loss=loss, elapsed=time.time() - t0,
                                    step=rr * fed.q + fed.q - 1, round=rr,
                                    round_seconds=dt / L,
                                    bytes_up=bytes_up,
                                    bytes_down=bytes_down,
                                    cohort=ids_l[-1].tolist()),
                      flush=True)
            r += L
    else:
        for r in range(start_round, n_rounds):
            t = r * fed.q
            ids = sampler.cohort(r)
            with tele.span("batch_build"):
                batch_q = tree_stack([make_cohort_batch(data, cfg, specs_c,
                                                        t + j, ids)
                                      for j in range(fed.q)])
            r0 = time.time()
            with tele.span("round_program"):
                if lossy:
                    bank, last_sync, ef, server = round_fn(
                        bank, last_sync, ef, server, ids, batch_q, key,
                        jnp.int32(r))
                else:
                    bank, last_sync, server = round_fn(bank, last_sync,
                                                       server, ids, batch_q,
                                                       key, jnp.int32(r))
                jax.block_until_ready(bank)
            dt = time.time() - r0
            # make_population_round closes every round with one sync: each
            # UNIQUE cohort member uploads one codec message (a duplicate
            # id — trace shortfall cycling — fills two aggregation slots
            # but one client shipped one message, docs/sharding.md wire
            # conventions); every bank row downloads the broadcast
            # (sync_mode="broadcast")
            bytes_up += int(np.unique(np.asarray(ids)).size) * msg_b
            bytes_down += n * down_b
            tele.round(r, step=t + fed.q - 1, round_seconds=dt,
                       bytes_up=bytes_up, bytes_down=bytes_down)
            if acc is not None:
                acc.update(bank)
                if acc.ready:
                    tele.stats(**acc.drain())
            if r % eval_rounds == 0 or r == n_rounds - 1:
                last = jax.tree.map(lambda x: x[-1], batch_q)
                loss = float(ev(bank, last))
                print(progress_line(loss=loss, elapsed=time.time() - t0,
                                    step=t + fed.q - 1, round=r,
                                    round_seconds=dt, bytes_up=bytes_up,
                                    bytes_down=bytes_down,
                                    cohort=np.asarray(ids).tolist()),
                      flush=True)
    if acc is not None and acc.pending:
        tele.stats(**acc.drain())
    print(f"wire totals ({tr.codec.name}): bytes_up={bytes_up} "
          f"bytes_down={bytes_down}", flush=True)
    if args.ckpt:
        state = (bank, last_sync, ef, server) if lossy else (bank, last_sync,
                                                             server)
        save_checkpoint(args.ckpt, state, n_rounds * fed.q,
                        shards=args.ckpt_shards)
        print(f"saved population checkpoint to {args.ckpt}")


def run_population_spill(args, cfg, fed, tr: FederatedTrainer, key, data,
                         specs_c, specs_n, sampler, tele):
    """Host-spill population mode (--spill host, docs/sharding.md): the
    [N, ...] bank lives in HOST memory (``repro.fed.spill.HostSpillBank``),
    only each round's C sampled rows travel to device, and the round
    program is the cohort-only ``tr.cohort_round_fn`` — same math as the
    dense broadcast rounds, so the trajectory matches bit-for-bit. The
    next round's cohort prefetches (async ``jax.device_put``) while this
    round's batches build on host. Checkpoints materialize the dense bank,
    so spilled and dense runs resume from each other's files."""
    from repro.fed.spill import HostSpillBank

    n, c = args.population, args.cohort
    bank, last_sync, server = tr.init_population_states(
        key, make_client_batch(data, cfg, specs_n, 0), n)
    lossy = tr.codec.lossy
    ef = tr.init_ef_bank(n)
    start = 0
    if args.resume and args.ckpt:
        tmpl = (bank, last_sync, ef, server) if lossy else (bank, last_sync,
                                                            server)
        loaded, start = load_checkpoint(args.ckpt, tmpl)
        if lossy:
            bank, last_sync, ef, server = loaded
        else:
            bank, last_sync, server = loaded
        print(f"resumed spilled population run from step {start}")
    spill = HostSpillBank.from_device(bank)
    ef_spill = HostSpillBank.from_device(ef) if ef is not None else None
    del bank, ef                     # host copies are now authoritative
    last_sync = np.asarray(last_sync).copy()
    round_fn = jax.jit(tr.cohort_round_fn(n))
    ev = jax.jit(tr.eval_fn())
    msg_b, down_b = wire_costs(tr, n)
    bytes_up = bytes_down = 0

    start_round = start // fed.q
    n_rounds = max(args.steps // fed.q, start_round + 1)
    if n_rounds * fed.q != args.steps:
        print(f"population mode runs whole rounds: {n_rounds * fed.q} steps "
              f"instead of the requested {args.steps} "
              f"(use --steps divisible by q={fed.q})", flush=True)
    print(f"spilled population mode: N={n} clients "
          f"({spill.nbytes / 1e6:.1f}MB host bank), C={c} cohort/round "
          f"({args.sampler} sampler), rounds {start_round}..{n_rounds - 1} "
          f"of q={fed.q}", flush=True)
    t0 = time.time()
    ids = np.asarray(sampler.cohort(start_round), np.int32)
    for r in range(start_round, n_rounds):
        t = r * fed.q
        with tele.span("batch_build"):
            batch_q = tree_stack([make_cohort_batch(data, cfg, specs_c,
                                                    t + j, ids)
                                  for j in range(fed.q)])
        r0 = time.time()
        with tele.span("spill_gather"):
            cur = spill.gather(ids)
            ls_c = jnp.asarray(last_sync[ids])
            jids = jnp.asarray(ids)
            ef_c = (ef_spill.gather(ids)
                    if lossy and ef_spill is not None else None)
        with tele.span("round_program"):
            if lossy:
                new_client, ef_c, server = round_fn(cur, ls_c, ef_c, server,
                                                    jids, batch_q, key,
                                                    jnp.int32(r))
            else:
                new_client, server = round_fn(cur, ls_c, server, jids,
                                              batch_q, key, jnp.int32(r))
            jax.block_until_ready(new_client)
        with tele.span("spill_scatter"):
            # dense broadcast write-back, host-side: every row := new_client
            # (lazy base + fresh-mask clear), stamp last_sync = r + 1
            spill.broadcast(new_client)
            last_sync[:] = r + 1
            if lossy and ef_spill is not None:
                ef_spill.scatter(ids, ef_c)
        next_ids = (np.asarray(sampler.cohort(r + 1), np.int32)
                    if r + 1 < n_rounds else None)
        if next_ids is not None:
            # overlap the next cohort's host->device copy with this round's
            # logging and the next round's host batch building
            with tele.span("spill_prefetch"):
                spill.prefetch(next_ids)
                if ef_spill is not None:
                    ef_spill.prefetch(next_ids)
        dt = time.time() - r0
        bytes_up += int(np.unique(ids).size) * msg_b
        bytes_down += n * down_b
        tele.round(r, step=t + fed.q - 1, round_seconds=dt,
                   bytes_up=bytes_up, bytes_down=bytes_down)
        if r % max(args.eval_every // fed.q, 1) == 0 or r == n_rounds - 1:
            last = jax.tree.map(lambda x: x[-1], batch_q)
            loss = float(ev(jax.tree.map(lambda v: v[None], new_client),
                            last))
            print(progress_line(loss=loss, elapsed=time.time() - t0,
                                step=t + fed.q - 1, round=r,
                                round_seconds=dt, bytes_up=bytes_up,
                                bytes_down=bytes_down,
                                cohort=ids.tolist()), flush=True)
        if next_ids is not None:
            ids = next_ids
    print(f"wire totals ({tr.codec.name}): bytes_up={bytes_up} "
          f"bytes_down={bytes_down}", flush=True)
    if args.ckpt:
        # lazy leaves: save_checkpoint pulls one shard's row range at a
        # time, so the spilled bank checkpoints without a dense
        # materialize (with --ckpt-shards 1 it still writes the legacy
        # single-file layout in one pull)
        bank_l = spill.lazy_leaves()
        ef_l = ef_spill.lazy_leaves() if ef_spill is not None else None
        state = ((bank_l, jnp.asarray(last_sync), ef_l, server) if lossy
                 else (bank_l, jnp.asarray(last_sync), server))
        save_checkpoint(args.ckpt, state, n_rounds * fed.q,
                        shards=args.ckpt_shards)
        print(f"saved population checkpoint to {args.ckpt}")


def run_gossip(args, cfg, fed, shape, tr: FederatedTrainer, key, tele=NULL):
    """Decentralized gossip mode (--engine gossip, docs/topology.md): no
    central server — every bank row steps every round (full participation;
    --cohort/--sampler are unused) and each round opens with one
    doubly-stochastic Metropolis mixing step over --topology that closes
    the previous round. Wire accounting prices every directed edge's
    codec message on BOTH legs (the sender's uplink is the receiver's
    downlink; there is no full-precision broadcast)."""
    n = args.population
    specs_n, axes_n = client_batch_specs(cfg, shape, n, fed)
    data = FederatedLMData(vocab=cfg.vocab, n_clients=n)
    topo = dict(topology=args.topology, er_p=args.er_p,
                seed=args.topology_seed, time_varying=args.time_varying)
    try:
        agg = tr.gossip_aggregator(n, **topo)
    except ValueError as e:          # bad topology spec → CLI-style exit
        raise SystemExit(str(e))
    bank, srv_bank = tr.init_gossip_states(
        key, make_client_batch(data, cfg, specs_n, 0), n)
    ef = tr.init_ef_bank(n)          # None unless the codec keeps EF state
    start = 0
    if args.resume and args.ckpt:
        tmpl = (bank, srv_bank, ef) if ef is not None else (bank, srv_bank)
        loaded, start = load_checkpoint(args.ckpt, tmpl)
        if ef is not None:
            bank, srv_bank, ef = loaded
        else:
            bank, srv_bank = loaded
        print(f"resumed gossip run from step {start}")
    if tr.mesh is not None:
        # bank rows, per-node server bank, and EF stack all partition over
        # the mesh's client axes; the mixing step is the only cross-shard op
        bank = jax.device_put(bank, tr.population_state_shardings(n))
        srv_bank = jax.device_put(srv_bank, tr.gossip_server_shardings(n))
        if ef is not None:
            ef = jax.device_put(ef, tr.population_state_shardings(n))
    R = args.rounds_per_scan
    round_fn = tr.jitted("gossip_round", specs_n, axes_n, population_n=n,
                         async_opts=topo)
    multi_fn = (tr.jitted("multi_gossip_round", specs_n, axes_n,
                          population_n=n, rounds_per_scan=R,
                          async_opts=topo) if R > 1 else None)
    ev = jax.jit(tr.eval_fn())
    msg_b, down_b = wire_costs(tr, n)
    # static graphs bill a constant edge count; time-varying replays each
    # round's deterministic draw on host (jax RNG matches eager vs jit)
    static_edges = None if args.time_varying else agg.edges(0)
    edges_of = (agg.edges if static_edges is None
                else (lambda rid: static_edges))
    bytes_up = bytes_down = 0

    start_round = start // fed.q
    n_rounds = max(args.steps // fed.q, start_round + 1)
    if n_rounds * fed.q != args.steps:
        print(f"gossip mode runs whole rounds: {n_rounds * fed.q} steps "
              f"instead of the requested {args.steps} "
              f"(use --steps divisible by q={fed.q})", flush=True)
    print(f"gossip mode: N={n} nodes over {args.topology} "
          f"(spectral gap {agg.gap:.4f}"
          f"{', time-varying' if args.time_varying else ''}), "
          f"rounds {start_round}..{n_rounds - 1} of q={fed.q}", flush=True)
    acc = (StatAccum.create(bank, tele.metrics_every, tele.consensus)
           if tele.sinks else None)
    eval_rounds = max(args.eval_every // fed.q, 1)
    t0 = time.time()
    r = start_round
    while r < n_rounds:
        # round 0 has no previous round to close, so it peels off as a
        # single round with the opening mix skipped — exactly the star
        # mega-scan's opening-round convention
        L = min(R, n_rounds - r) if (R > 1 and r > 0) else 1
        t = r * fed.q
        with tele.span("batch_build"):
            if L > 1:
                batch = tree_stack([
                    tree_stack([make_client_batch(data, cfg, specs_n,
                                                  (r + j) * fed.q + jj)
                                for jj in range(fed.q)])
                    for j in range(L)])
            else:
                batch = tree_stack([make_client_batch(data, cfg, specs_n,
                                                      t + j)
                                    for j in range(fed.q)])
        r0 = time.time()
        with tele.span("round_program"):
            if L > 1:
                bank, srv_bank, ef = multi_fn(bank, srv_bank, ef, batch,
                                              key, jnp.int32(r))
            else:
                bank, srv_bank, ef = round_fn(bank, srv_bank, ef, batch,
                                              key, jnp.int32(r),
                                              sync_first=r > 0)
            jax.block_until_ready(bank)
        dt = time.time() - r0
        for j in range(L):
            rj = r + j
            if rj > 0:
                # round rj's opening mix closes round rj - 1
                up, down = agg.wire_round(msg_b, down_b,
                                          edges=edges_of(rj - 1))
                bytes_up += up
                bytes_down += down
            tele.round(rj, step=rj * fed.q + fed.q - 1, round_seconds=dt / L,
                       bytes_up=bytes_up, bytes_down=bytes_down)
        if acc is not None:
            acc.update(bank)
            if acc.ready:
                tele.stats(**acc.drain())
        rr = r + L - 1
        if (any((r + j) % eval_rounds == 0 for j in range(L))
                or rr == n_rounds - 1):
            last = jax.tree.map(lambda x: x[-1, -1] if L > 1 else x[-1],
                                batch)
            loss = float(ev(bank, last))
            print(progress_line(loss=loss, elapsed=time.time() - t0,
                                step=rr * fed.q + fed.q - 1, round=rr,
                                round_seconds=dt / L, bytes_up=bytes_up,
                                bytes_down=bytes_down), flush=True)
        r += L
    if acc is not None and acc.pending:
        tele.stats(**acc.drain())
    print(f"wire totals ({tr.codec.name}): bytes_up={bytes_up} "
          f"bytes_down={bytes_down}", flush=True)
    if args.ckpt:
        state = (bank, srv_bank, ef) if ef is not None else (bank, srv_bank)
        save_checkpoint(args.ckpt, state, n_rounds * fed.q,
                        shards=args.ckpt_shards)
        print(f"saved gossip checkpoint to {args.ckpt}")


def wire_costs(tr: FederatedTrainer, n: int):
    """(uplink bytes per client→server message, downlink bytes per
    receiving client) for one client state of this trainer — the shared
    pricing helper of repro.fed.compress (docs/compression.md)."""
    from repro.fed.compress import wire_costs as _wire
    return _wire(tr.codec, tr.abstract_population_states(n))


def make_cli_delay_model(args, n: int):
    """The DelayModel the CLI delay flags describe (loads the per-client
    delay table from --trace-file for --delay-model trace)."""
    tier_fracs = tier_delays = None
    if args.tiers is not None:
        if args.delay_model != "tiers":
            raise SystemExit("--tiers only applies to --delay-model tiers "
                             f"(got --delay-model {args.delay_model})")
        tier_fracs, tier_delays = parse_tier_spec(args.tiers)
    table = None
    if args.delay_model == "trace":
        if not args.trace_file:
            raise SystemExit("--delay-model trace replays the trace file's "
                             "per-client 'delay' field: pass --trace-file "
                             "(format: docs/async.md)")
        table = load_delay_trace(args.trace_file, n)
    return make_delay_model(args.delay_model, args.max_delay,
                            tier_fracs=tier_fracs, tier_delays=tier_delays,
                            mu=args.delay_mu, sigma=args.delay_sigma,
                            table=table)


def run_population_async(args, cfg, fed, tr: FederatedTrainer, key, data,
                         specs_c, axes_c, specs_n, sampler, tele):
    """Asynchronous population mode: overlapping cohorts with delayed
    arrivals (per-client delays from the pluggable --delay-model),
    server-side bounded-staleness gating, delay-adaptive server steps
    (docs/async.md). Prints per-eval arrival/staleness stats and a final
    accepted-staleness histogram (split by speed tier for --delay-model
    tiers)."""
    n, c = args.population, args.cohort
    # resolve() bakes the permanent per-client delay quantities into the
    # round program as constants (the same run key is passed every round)
    dm = make_cli_delay_model(args, n).resolve(key, n)
    state = tr.init_async_population_states(
        key, make_client_batch(data, cfg, specs_n, 0), n)
    start = 0
    if args.resume and args.ckpt:
        state, start = load_checkpoint(args.ckpt, state)
        print(f"resumed async population run from step {start}")
    opts = dict(max_staleness=args.max_staleness, max_delay=args.max_delay,
                delay_eta=args.delay_eta, delay_model=dm)
    R = args.rounds_per_scan
    cohort_fn = in_scan_cohort_fn(sampler) if R > 1 else None
    if tr.mesh is not None:
        # bank / pending buffer / EF stack / [N] bookkeeping partition over
        # the client mesh axes; arrival masks compute shard-locally
        state = jax.device_put(state, tr.async_state_shardings(n))
        if R > 1:
            round_fn = tr.jitted("multi_async_population_round", specs_c,
                                 axes_c, population_n=n, async_opts=opts,
                                 rounds_per_scan=R, cohort_fn=cohort_fn)
        else:
            round_fn = tr.jitted("async_population_round", specs_c, axes_c,
                                 population_n=n, async_opts=opts)
    elif R > 1:
        round_fn = jax.jit(
            tr.multi_async_population_round_fn(n, cohort_fn=cohort_fn,
                                               **opts),
            donate_argnums=(0,))
    else:
        round_fn = jax.jit(tr.async_population_round_fn(n, **opts))
    ev = jax.jit(tr.eval_fn())

    start_round = start // fed.q
    n_rounds = max(args.steps // fed.q, start_round + 1)
    if n_rounds * fed.q != args.steps:
        print(f"async population mode runs whole rounds: {n_rounds * fed.q} "
              f"steps instead of the requested {args.steps} "
              f"(use --steps divisible by q={fed.q})", flush=True)
    print(f"async population mode: N={n} clients, C={c} cohort/round "
          f"({args.sampler} sampler), max_staleness={args.max_staleness}, "
          f"delay_model={args.delay_model} (bound {dm.bound}), "
          f"delay_eta={args.delay_eta}, "
          f"rounds {start_round}..{n_rounds - 1} of q={fed.q}", flush=True)
    tier_of = (np.asarray(dm.tiers(key, n))
               if args.delay_model == "tiers" else None)
    hist = np.zeros(0, np.int64)
    hist_by_tier = {}
    msg_b, down_b = wire_costs(tr, n)
    bytes_up = bytes_down = 0
    statacc = (StatAccum.create(state["bank"], tele.metrics_every,
                                tele.consensus) if tele.sinks else None)
    eval_rounds = max(args.eval_every // fed.q, 1)

    def note_round(r, stats_np, dt, idx=None):
        """Host-side bookkeeping for one round's stats (idx selects a row
        of a chunk's stacked stats in mega mode): staleness histograms,
        wire accounting, the tele.round record. Returns the scalar dict
        the progress line prints."""
        nonlocal hist, bytes_up, bytes_down
        pick = (lambda v: v) if idx is None else (lambda v: v[idx])
        stale = pick(stats_np["staleness"])
        acc = stale[stale >= 0]
        if acc.size:
            hist = accum_staleness_hist(hist, acc)
        if tier_of is not None:
            accum_tier_hists(hist_by_tier, stale, tier_of,
                             len(dm.tier_fracs))
        # uplink per arrival (dropped ones shipped before the gate),
        # downlink per row that received the new global model
        row = {k: int(pick(stats_np[k])) for k in
               ("arrived", "accepted", "dropped", "dispatched", "synced")}
        row["mean_staleness"] = float(pick(stats_np["mean_staleness"]))
        row["eta_scale"] = float(pick(stats_np["eta_scale"]))
        bytes_up += row["arrived"] * msg_b
        bytes_down += row["synced"] * down_b
        tele.round(r, step=r * fed.q + fed.q - 1,
                   round_seconds=dt, bytes_up=bytes_up,
                   bytes_down=bytes_down, **row)
        return row

    t0 = time.time()
    if R > 1:
        r = start_round
        while r < n_rounds:
            L = min(R, n_rounds - r)
            ids_l = [np.asarray(sampler.cohort(r + j), np.int32)
                     for j in range(L)]
            with tele.span("batch_build"):
                batch_R = tree_stack([
                    tree_stack([make_cohort_batch(data, cfg, specs_c,
                                                  (r + j) * fed.q + jj,
                                                  ids_l[j])
                                for jj in range(fed.q)])
                    for j in range(L)])
            ids_R = (None if cohort_fn is not None
                     else jnp.asarray(np.stack(ids_l)))
            r0 = time.time()
            with tele.span("round_program"):
                state, stats_R = round_fn(state, ids_R, batch_R, key,
                                          jnp.int32(r))
                jax.block_until_ready(state)
            dt = time.time() - r0
            stats_np = {k2: np.asarray(v) for k2, v in stats_R.items()}
            for j in range(L):
                row = note_round(r + j, stats_np, dt / L, idx=j)
            if statacc is not None:
                # mega mode samples the on-device stats once per chunk
                statacc.update(state["bank"])
                if statacc.ready:
                    tele.stats(**statacc.drain())
            rr = r + L - 1
            if (any((r + j) % eval_rounds == 0 for j in range(L))
                    or rr == n_rounds - 1):
                last = jax.tree.map(lambda x: x[-1, -1], batch_R)
                loss = float(ev(state["bank"], last))
                print(progress_line(loss=loss, elapsed=time.time() - t0,
                                    step=rr * fed.q + fed.q - 1, round=rr,
                                    round_seconds=dt / L,
                                    arrived=row["arrived"],
                                    dropped=row["dropped"],
                                    mean_staleness=row["mean_staleness"],
                                    eta_scale=row["eta_scale"],
                                    bytes_up=bytes_up,
                                    bytes_down=bytes_down), flush=True)
            r += L
    else:
        for r in range(start_round, n_rounds):
            t = r * fed.q
            ids = sampler.cohort(r)
            with tele.span("batch_build"):
                batch_q = tree_stack([make_cohort_batch(data, cfg, specs_c,
                                                        t + j, ids)
                                      for j in range(fed.q)])
            r0 = time.time()
            with tele.span("round_program"):
                state, stats = round_fn(state, ids, batch_q, key,
                                        jnp.int32(r))
                jax.block_until_ready(state)
            dt = time.time() - r0
            row = note_round(r, {k2: np.asarray(v)
                                 for k2, v in stats.items()}, dt)
            if statacc is not None:
                statacc.update(state["bank"])
                if statacc.ready:
                    tele.stats(**statacc.drain())
            if r % eval_rounds == 0 or r == n_rounds - 1:
                last = jax.tree.map(lambda x: x[-1], batch_q)
                loss = float(ev(state["bank"], last))
                print(progress_line(loss=loss, elapsed=time.time() - t0,
                                    step=t + fed.q - 1, round=r,
                                    round_seconds=dt,
                                    arrived=row["arrived"],
                                    dropped=row["dropped"],
                                    mean_staleness=row["mean_staleness"],
                                    eta_scale=row["eta_scale"],
                                    bytes_up=bytes_up,
                                    bytes_down=bytes_down), flush=True)
    if statacc is not None and statacc.pending:
        tele.stats(**statacc.drain())
    tele.note(staleness_hist=[int(k) for k in hist])
    print(f"wire totals ({tr.codec.name}): bytes_up={bytes_up} "
          f"bytes_down={bytes_down}", flush=True)
    print("accepted-staleness histogram (rounds): "
          + " ".join(f"{s}:{int(k)}" for s, k in enumerate(hist) if k),
          flush=True)
    if tier_of is not None:
        for ti in range(len(dm.tier_fracs)):
            lo, hi = dm.tier_delays[ti]
            print(f"  tier {ti} (delay {lo}..{hi}, "
                  f"{int((tier_of == ti).sum())} clients): "
                  + (" ".join(f"{s}:{int(k)}" for s, k in
                              enumerate(hist_by_tier.get(ti, ())) if k)
                     or "-"),
                  flush=True)
    if args.ckpt:
        save_checkpoint(args.ckpt, state, n_rounds * fed.q,
                        shards=args.ckpt_shards)
        print(f"saved async population checkpoint to {args.ckpt}")


if __name__ == "__main__":
    main()
