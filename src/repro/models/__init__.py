from repro.models.model import (ModelCtx, features, forward, head_logits,
                                model_specs)
from repro.models.decode import cache_spec, decode_step, init_cache, prefill
from repro.models.params import (abstract_params, axes_tree, init_params,
                                 param_count)

__all__ = [
    "ModelCtx", "features", "forward", "head_logits", "model_specs",
    "cache_spec", "decode_step", "init_cache", "prefill",
    "abstract_params", "axes_tree", "init_params", "param_count",
]
