"""Attention primitives: GQA/MQA/MHA with RoPE, optional QKV bias, optional
sliding window; full path (train, S<=8k), flash-chunked path (long prefill),
and decode-vs-cache path (flash-decode friendly).

GQA is computed in grouped form — q reshaped to [B,S,KV,G,Dh] and einsummed
directly against unexpanded K/V — so repeated K/V heads are never
materialized (at 64q/8kv heads that expansion costs 8x the KV bytes).
Activations are sequence-sharded (q's S dim over `model`), so scores shard
over Sq while K/V stay whole; GSPMD inserts the seq all-gathers.

These are the pure-jnp reference paths used by the XLA/GSPMD pipeline; the
Pallas kernel in ``repro.kernels.flash_attention`` mirrors ``attend_flash``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, Dh]; pos: [..., S] int32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos[..., None].astype(jnp.float32) * freqs          # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]                          # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def _grouped(q: jax.Array, kv_heads: int) -> jax.Array:
    """[B,S,H,Dh] -> [B,S,KV,G,Dh]."""
    b, s, h, dh = q.shape
    return q.reshape(b, s, kv_heads, h // kv_heads, dh)


def _mask(sq: int, sk: int, causal: bool, window: Optional[int],
          q_offset=0, k_offset=0):
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk) + k_offset
    m = jnp.ones((sq, sk), bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        m &= kpos[None, :] > qpos[:, None] - window
    return m


def attend_full(q: jax.Array, k: jax.Array, v: jax.Array, *,
                causal: bool = True, window: Optional[int] = None,
                q_offset: int = 0) -> jax.Array:
    """Plain softmax attention. q: [B,Sq,H,Dh]; k,v: [B,Sk,KV,Dh]."""
    b, sq, h, dh = q.shape
    sk, kv = k.shape[1], k.shape[2]
    # operands stay in input dtype (bf16 -> MXU); accumulation is f32 via
    # preferred_element_type, so no f32 copies of K/V are materialized.
    q5 = _grouped(q, kv) * jnp.asarray(dh ** -0.5, q.dtype)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", q5, k,
                        preferred_element_type=jnp.float32)
    m = _mask(sq, sk, causal, window, q_offset)
    logits = jnp.where(m[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def attend_flash(q: jax.Array, k: jax.Array, v: jax.Array, *,
                 causal: bool = True, window: Optional[int] = None,
                 chunk: int = 1024) -> jax.Array:
    """Chunked (flash-style) attention over KV blocks: O(Sq*chunk) live scores.

    Forward-only usage (prefill); the train path uses attend_full under
    per-layer remat.
    """
    b, sq, h, dh = q.shape
    sk, kv = k.shape[1], k.shape[2]
    if sk <= chunk:
        return attend_full(q, k, v, causal=causal, window=window)
    assert sk % chunk == 0, (sk, chunk)
    nkv = sk // chunk
    g = h // kv
    q5 = _grouped(q, kv) * jnp.asarray(dh ** -0.5, q.dtype)
    qpos = jnp.arange(sq)

    kc = k.reshape(b, nkv, chunk, kv, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nkv, chunk, kv, dh).transpose(1, 0, 2, 3, 4)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, idx = xs
        kpos = idx * chunk + jnp.arange(chunk)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", q5, kb,
                            preferred_element_type=jnp.float32)
        msk = jnp.ones((sq, chunk), bool)
        if causal:
            msk &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            msk &= kpos[None, :] > qpos[:, None] - window
        logits = jnp.where(msk[None, None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((b, kv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kv, g, sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), (m0, l0, a0),
                                  (kc, vc, jnp.arange(nkv)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dh)
    return out.astype(q.dtype)


def attend_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, *,
                  pos: jax.Array, ring: bool = False) -> jax.Array:
    """One-token attention vs a cache.

    q: [B,1,H,Dh]; k_cache/v_cache: [B,Smax,KV,Dh]; pos: count of valid tokens
    *including* the current one — a scalar shared by every row, or a ``[B]``
    vector for per-row positions (continuous batching). With ``ring=True`` the
    cache is a ring buffer (sliding window); positions were RoPE'd at write
    time so slot order is irrelevant.
    """
    b, smax, kv, dh = k_cache.shape
    h = q.shape[2]
    q5 = _grouped(q, kv) * jnp.asarray(dh ** -0.5, q.dtype)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", q5.astype(k_cache.dtype), k_cache,
                        preferred_element_type=jnp.float32)
    slots = jnp.arange(smax)
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        valid = slots < jnp.minimum(pos, smax)                   # [Smax]
        valid = valid[None, None, None, None, :]
    else:
        valid = slots[None, :] < jnp.minimum(pos, smax)[:, None]  # [B,Smax]
        valid = valid[:, None, None, None, :]
    logits = jnp.where(valid, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, dh).astype(q.dtype)
