"""Serving paths: prefill (build cache) + single-token decode against a cache.

Cache layouts (leading dim scans over layers):
  dense/moe/vlm : {"k","v": [L,B,W,KV,Dh]}            W = window (ring) or max_len
  ssm           : {"h": [L,B,di,N] f32, "conv": [L,B,cw-1,di]}
  hybrid        : mamba2 state + shared-attn KV [nseg,B,W,KV,Dh]
  encdec        : self KV [L,...] + cross KV [L,B,Senc,KV,Dh] (built at prefill)

``pos`` is the number of tokens already in the cache; RoPE uses absolute
positions, so ring buffers (sliding window) stay correct without rotation.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_lib
from repro.models import ssm as ssm_lib
from repro.models.model import (ModelCtx, _mlp_block, embed_tokens,
                                encoder_forward, head_logits, rmsnorm)


# ------------------------------------------------------------------ cache init

def cache_spec(cfg: ArchConfig, batch: int, max_len: int,
               window: Optional[int] = None, enc_len: int = 0,
               dtype=jnp.bfloat16,
               quant: bool = False) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Returns (ShapeDtypeStruct pytree, logical-axes pytree).

    ``quant=True``: int8 K/V with per-(token, head) f32 scales — halves the
    cache's HBM footprint/traffic; dequantization is fused HBM->VMEM by
    ``kernels.quant_decode`` on TPU (the XLA reference path dequantizes one
    layer slice at a time inside the scan)."""
    L = cfg.n_layers
    hd = cfg.resolved_head_dim if cfg.n_heads else 0
    w = min(window or max_len, max_len)
    spec: Dict[str, Any] = {}
    axes: Dict[str, Any] = {}
    kv_ax = ("layers", "batch", "cache_seq", "kv_heads", "head_dim")
    kv_dtype = jnp.int8 if quant else dtype

    def sds(shape, dt=dtype):
        return jax.ShapeDtypeStruct(shape, dt)

    fam = cfg.family
    if fam in ("dense", "vlm", "moe", "encdec"):
        kvs = (L, batch, w, cfg.n_kv_heads, hd)
        spec["k"], spec["v"] = sds(kvs, kv_dtype), sds(kvs, kv_dtype)
        axes["k"] = axes["v"] = kv_ax
        if quant:
            scs = (L, batch, w, cfg.n_kv_heads)
            spec["k_scale"] = sds(scs, jnp.float32)
            spec["v_scale"] = sds(scs, jnp.float32)
            axes["k_scale"] = axes["v_scale"] = kv_ax[:-1]
    if fam == "encdec":
        ckvs = (L, batch, enc_len, cfg.n_kv_heads, hd)
        spec["ck"], spec["cv"] = sds(ckvs), sds(ckvs)
        axes["ck"] = axes["cv"] = kv_ax
    if fam in ("ssm", "hybrid"):
        s = cfg.ssm
        di = s.expand * cfg.d_model
        if s.version == 1:
            spec["h"] = sds((L, batch, di, s.state_dim), jnp.float32)
            axes["h"] = ("layers", "batch", "ssm_inner", "ssm_state")
            conv_ch = di
        else:
            nh = di // s.head_dim
            spec["h"] = sds((L, batch, nh, s.head_dim, s.state_dim), jnp.float32)
            axes["h"] = ("layers", "batch", "ssm_inner", None, "ssm_state")
            conv_ch = di + 2 * s.state_dim
        spec["conv"] = sds((L, batch, s.conv_width - 1, conv_ch))
        axes["conv"] = ("layers", "batch", None, "ssm_inner")
    if fam == "hybrid":
        nseg = cfg.n_layers // cfg.shared_attn_every
        kvs = (max(nseg, 1), batch, w, cfg.n_kv_heads, hd)
        spec["k"], spec["v"] = sds(kvs), sds(kvs)
        axes["k"] = axes["v"] = kv_ax
    return spec, axes


def init_cache(cfg, batch, max_len, window=None, enc_len=0, dtype=jnp.bfloat16,
               quant=False):
    spec, _ = cache_spec(cfg, batch, max_len, window, enc_len, dtype, quant)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)


# ------------------------------------------------------------------ helpers

def _qkv(cfg, p, hn, prefix=""):
    q = jnp.einsum("bsd,dhk->bshk", hn, p[prefix + "wq"])
    k = jnp.einsum("bsd,dhk->bshk", hn, p[prefix + "wk"])
    v = jnp.einsum("bsd,dhk->bshk", hn, p[prefix + "wv"])
    if cfg.qkv_bias and (prefix + "bq") in p:
        q, k, v = q + p[prefix + "bq"], k + p[prefix + "bk"], v + p[prefix + "bv"]
    return q, k, v


def _attn_decode_block(cfg, p, h, ck, cv, pos, window, prefix="",
                       scales=None, kv_kernel="xla"):
    """One-token self-attention vs cache. h: [B,1,d]. Returns h', new (ck, cv)
    [, new scales]. ``scales``: (k_scale, v_scale) when the cache is int8.

    ``pos`` is a scalar shared by every row or a ``[B]`` vector of per-row
    positions (continuous batching). ``kv_kernel`` selects the int8 attention
    path: "xla" (reference dequant), "pallas" (fused HBM->VMEM dequant kernel)
    or "interpret" (same kernel, Pallas interpret mode — CPU-safe).
    """
    from repro.kernels.quant_decode import quant_decode_attention, quantize_kv
    b, w = ck.shape[0], ck.shape[1]
    hn = rmsnorm(h, p[prefix + "ln_attn"], cfg.norm_eps)
    q, k, v = _qkv(cfg, p, hn, prefix)
    pos = jnp.asarray(pos, jnp.int32)
    vec = pos.ndim == 1
    posv = pos[:, None] if vec else jnp.full((1, 1), pos, jnp.int32)
    q = attn_lib.rope(q, posv, cfg.rope_theta)
    k = attn_lib.rope(k, posv, cfg.rope_theta)
    slot = pos % w if window else jnp.minimum(pos, w - 1)

    def write(buf, val):
        """Scatter one token per row at ``slot``. val: [B,1,...]."""
        if vec:
            return buf.at[jnp.arange(b), slot].set(val[:, 0])
        return jax.lax.dynamic_update_slice_in_dim(buf, val, slot, axis=1)

    if scales is not None:
        ks, vs = scales
        k8, ksc = quantize_kv(k)
        v8, vsc = quantize_kv(v)
        ck, cv = write(ck, k8), write(cv, v8)
        ks, vs = write(ks, ksc), write(vs, vsc)
        if kv_kernel != "xla" and window is None:
            # Fused path: dequant happens HBM->VMEM inside the Pallas kernel
            # (interpret mode executes the same kernel on CPU).
            o = quant_decode_attention(
                q[:, 0], ck.transpose(0, 2, 1, 3), ks.transpose(0, 2, 1),
                cv.transpose(0, 2, 1, 3), vs.transpose(0, 2, 1), pos + 1,
                block_s=128 if w % 128 == 0 else w,
                interpret=kv_kernel == "interpret")[:, None]
        else:
            # XLA path: dequantize this layer's slice (transient); the TPU
            # build fuses dequant HBM->VMEM via kernels.quant_decode.
            kd = (ck.astype(jnp.float32) * ks[..., None]).astype(k.dtype)
            vd = (cv.astype(jnp.float32) * vs[..., None]).astype(v.dtype)
            o = attn_lib.attend_decode(q, kd, vd, pos=pos + 1,
                                       ring=window is not None)
        out = jnp.einsum("bshk,hkd->bsd", o, p[prefix + "wo"])
        return h + out, ck, cv, (ks, vs)
    ck = write(ck, k.astype(ck.dtype))
    cv = write(cv, v.astype(cv.dtype))
    o = attn_lib.attend_decode(q, ck, cv, pos=pos + 1, ring=window is not None)
    out = jnp.einsum("bshk,hkd->bsd", o, p[prefix + "wo"])
    return h + out, ck, cv


def _cross_decode_block(cfg, p, h, ck, cv, enc_len):
    hn = rmsnorm(h, p["cln_attn"], cfg.norm_eps)
    q, _, _ = _qkv(cfg, p, hn, "c")
    o = attn_lib.attend_decode(q, ck, cv, pos=enc_len)
    return h + jnp.einsum("bshk,hkd->bsd", o, p["cwo"])


def _fill_ring(k_seq, w, window):
    """[B,S,KV,Dh] -> ring buffer [B,w,KV,Dh] holding the last w positions at
    slot = pos % w (window) or the first w positions (full cache)."""
    s = k_seq.shape[1]
    if not window or s <= w:
        pad = w - min(s, w)
        out = k_seq[:, :w]
        if pad:
            out = jnp.pad(out, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return out
    tail = k_seq[:, -w:]                             # positions s-w .. s-1
    slots = (jnp.arange(s - w, s)) % w
    buf = jnp.zeros((k_seq.shape[0], w) + k_seq.shape[2:], k_seq.dtype)
    return buf.at[:, slots].set(tail)


# ------------------------------------------------------------------ prefill

def prefill(cfg: ArchConfig, params, batch, cache, ctx: ModelCtx):
    """Run the prompt, fill the cache. Returns (last-position logits, cache)."""
    xp, yp = params["x"], params["y"]
    tokens = batch["tokens"]
    b, S = tokens.shape
    pos = jnp.arange(S)
    h = embed_tokens(cfg, xp, tokens, batch.get("prefix_embeds"))
    fam = cfg.family
    w = cache["k"].shape[2] if "k" in cache else 0
    window = ctx.window

    if fam in ("dense", "vlm", "moe", "encdec"):
        enc_out = None
        if fam == "encdec":
            enc_out = encoder_forward(cfg, xp, batch["enc_embeds"], ctx)

        def body(carry, lp):
            hh = carry
            lp = jax.lax.optimization_barrier(lp)   # see model._scan_layers
            hn = rmsnorm(hh, lp["ln_attn"], cfg.norm_eps)
            q, k, v = _qkv(cfg, lp, hn)
            q = attn_lib.rope(q, pos, cfg.rope_theta)
            k = attn_lib.rope(k, pos, cfg.rope_theta)
            if ctx.kind == "prefill" and S > 4096:
                o = attn_lib.attend_flash(q, k, v, causal=True, window=window,
                                          chunk=ctx.attn_chunk)
            else:
                o = attn_lib.attend_full(q, k, v, causal=True, window=window)
            hh = hh + jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
            ys = {"k": _fill_ring(k, w, window), "v": _fill_ring(v, w, window)}
            if fam == "encdec":
                hn2 = rmsnorm(hh, lp["cln_attn"], cfg.norm_eps)
                cq = jnp.einsum("bsd,dhk->bshk", hn2, lp["cwq"])
                ck = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cwk"])
                cv = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cwv"])
                if ck.shape[1] > ctx.attn_chunk:
                    o2 = attn_lib.attend_flash(cq, ck, cv, causal=False,
                                               chunk=ctx.attn_chunk)
                else:
                    o2 = attn_lib.attend_full(cq, ck, cv, causal=False)
                hh = hh + jnp.einsum("bshk,hkd->bsd", o2, lp["cwo"])
                ys["ck"], ys["cv"] = ck.astype(cache["ck"].dtype), \
                    cv.astype(cache["cv"].dtype)
            hh = _mlp_block(cfg, lp, hh, ctx)
            return hh, jax.tree.map(lambda a: a, ys)

        h, ys = jax.lax.scan(body, h, xp["layers"])
        cache = dict(cache)
        cache["k"] = ys["k"].astype(cache["k"].dtype)
        cache["v"] = ys["v"].astype(cache["v"].dtype)
        if fam == "encdec":
            cache["ck"], cache["cv"] = ys["ck"], ys["cv"]

    elif fam == "ssm":
        def body(carry, lp):
            lp = jax.lax.optimization_barrier(lp)   # see model._scan_layers
            hn = rmsnorm(carry, lp["ln"], cfg.norm_eps)
            y, (hst, conv) = ssm_lib.mamba1_seq(cfg, lp, hn, chunk=ctx.ssm_chunk)
            return carry + y, {"h": hst, "conv": conv}
        h, ys = jax.lax.scan(body, h, xp["layers"])
        cache = {"h": ys["h"], "conv": ys["conv"].astype(cache["conv"].dtype)}

    elif fam == "hybrid":
        h, cache = _hybrid_prefill(cfg, xp, h, cache, ctx, pos, w, window)
    else:
        raise ValueError(fam)

    logits = head_logits(cfg, yp, h[:, -1:])
    return logits, cache


def _hybrid_prefill(cfg, xp, h, cache, ctx, pos, w, window):
    every = cfg.shared_attn_every
    nseg, rem = divmod(cfg.n_layers, every)
    layers = xp["layers"]

    def mamba_body(carry, lp):
        hn = rmsnorm(carry, lp["ln"], cfg.norm_eps)
        y, (hst, conv) = ssm_lib.mamba2_seq(cfg, lp, hn, chunk=ctx.ssm_chunk)
        return carry + y, {"h": hst, "conv": conv}

    def seg_body(carry, seg_params):
        hh, ys = jax.lax.scan(mamba_body, carry, seg_params)
        hn = rmsnorm(hh, xp["shared"]["ln_attn"], cfg.norm_eps)
        q, k, v = _qkv(cfg, xp["shared"], hn)
        q = attn_lib.rope(q, pos, cfg.rope_theta)
        k = attn_lib.rope(k, pos, cfg.rope_theta)
        if k.shape[1] > ctx.attn_chunk:
            o = attn_lib.attend_flash(q, k, v, causal=True, window=window,
                                      chunk=ctx.attn_chunk)
        else:
            o = attn_lib.attend_full(q, k, v, causal=True, window=window)
        hh = hh + jnp.einsum("bshk,hkd->bsd", o, xp["shared"]["wo"])
        hh = _mlp_block(cfg, xp["shared"], hh, ctx)
        ys.update({"k": _fill_ring(k, w, window), "v": _fill_ring(v, w, window)})
        return hh, ys

    states_h, states_c = [], []
    ks, vs = [], []
    if nseg:
        seg_stack = jax.tree.map(
            lambda a: a[: nseg * every].reshape((nseg, every) + a.shape[1:]),
            layers)
        h, ys = jax.lax.scan(seg_body, h, seg_stack)
        states_h.append(ys["h"].reshape((-1,) + ys["h"].shape[2:]))
        states_c.append(ys["conv"].reshape((-1,) + ys["conv"].shape[2:]))
        ks.append(ys["k"])
        vs.append(ys["v"])
    if rem:
        tail = jax.tree.map(lambda a: a[nseg * every:], layers)
        h, ys = jax.lax.scan(mamba_body, h, tail)
        states_h.append(ys["h"])
        states_c.append(ys["conv"])
    new = dict(cache)
    new["h"] = jnp.concatenate(states_h, 0)
    new["conv"] = jnp.concatenate(states_c, 0).astype(cache["conv"].dtype)
    if ks:
        new["k"] = ks[0].astype(cache["k"].dtype)
        new["v"] = vs[0].astype(cache["v"].dtype)
    return h, new


# ------------------------------------------------------------------ decode

def decode_step(cfg: ArchConfig, params, cache, token, pos, ctx: ModelCtx):
    """token: [B,1] int32; pos: int32 tokens already cached — scalar (all rows
    at the same position) or [B] per-row (continuous batching).
    Returns (logits [B,1,V], new cache)."""
    xp, yp = params["x"], params["y"]
    h = jnp.take(xp["embed"], token, axis=0)
    fam = cfg.family
    window = ctx.window
    new = dict(cache)

    if fam in ("dense", "vlm", "moe", "encdec"):
        enc_len = cache["ck"].shape[2] if fam == "encdec" else 0

        quant = "k_scale" in cache

        def body(carry, xs):
            xs = jax.lax.optimization_barrier(xs)   # see model._scan_layers
            lp = xs["p"]
            if quant:
                hh, ck, cv, (ks, vs) = _attn_decode_block(
                    cfg, lp, carry, xs["k"], xs["v"], pos, window,
                    scales=(xs["ks"], xs["vs"]), kv_kernel=ctx.kv_kernel)
                ys = {"k": ck, "v": cv, "ks": ks, "vs": vs}
            else:
                hh, ck, cv = _attn_decode_block(cfg, lp, carry, xs["k"],
                                                xs["v"], pos, window)
                ys = {"k": ck, "v": cv}
            if fam == "encdec":
                hh = _cross_decode_block(cfg, lp, hh, xs["ck"], xs["cv"], enc_len)
            hh = _mlp_block(cfg, lp, hh, ctx)
            return hh, ys

        xs = {"p": xp["layers"], "k": cache["k"], "v": cache["v"]}
        if quant:
            xs["ks"], xs["vs"] = cache["k_scale"], cache["v_scale"]
        if fam == "encdec":
            xs["ck"], xs["cv"] = cache["ck"], cache["cv"]
        h, ys = jax.lax.scan(body, h, xs)
        new["k"], new["v"] = ys["k"], ys["v"]
        if quant:
            new["k_scale"], new["v_scale"] = ys["ks"], ys["vs"]

    elif fam == "ssm":
        def body(carry, xs):
            xs = jax.lax.optimization_barrier(xs)   # see model._scan_layers
            hn = rmsnorm(carry, xs["p"]["ln"], cfg.norm_eps)
            y, (hst, conv) = ssm_lib.mamba1_decode(cfg, xs["p"], hn, xs["h"],
                                                   xs["conv"])
            return carry + y, {"h": hst, "conv": conv}
        h, ys = jax.lax.scan(body, h, {"p": xp["layers"], "h": cache["h"],
                                       "conv": cache["conv"]})
        new["h"], new["conv"] = ys["h"], ys["conv"]

    elif fam == "hybrid":
        h, new = _hybrid_decode(cfg, xp, h, cache, pos, window, ctx)
    else:
        raise ValueError(fam)

    return head_logits(cfg, yp, h), new


def _hybrid_decode(cfg, xp, h, cache, pos, window, ctx):
    every = cfg.shared_attn_every
    nseg, rem = divmod(cfg.n_layers, every)

    def mamba_body(carry, xs):
        hn = rmsnorm(carry, xs["p"]["ln"], cfg.norm_eps)
        y, (hst, conv) = ssm_lib.mamba2_decode(cfg, xs["p"], hn, xs["h"],
                                               xs["conv"])
        return carry + y, {"h": hst, "conv": conv}

    def seg_body(carry, xs):
        hh, ys = jax.lax.scan(mamba_body, carry, xs["m"])
        hh, ck, cv = _attn_decode_block(cfg, xp["shared"], hh, xs["k"], xs["v"],
                                        pos, window)
        hh = _mlp_block(cfg, xp["shared"], hh, ctx)
        ys.update({"k": ck, "v": cv})
        return hh, ys

    layers = xp["layers"]
    new = dict(cache)
    hs, cs = [], []
    if nseg:
        seg_m = jax.tree.map(
            lambda a: a[: nseg * every].reshape((nseg, every) + a.shape[1:]),
            layers)
        mstate = {
            "p": seg_m,
            "h": cache["h"][: nseg * every].reshape(
                (nseg, every) + cache["h"].shape[1:]),
            "conv": cache["conv"][: nseg * every].reshape(
                (nseg, every) + cache["conv"].shape[1:]),
        }
        h, ys = jax.lax.scan(seg_body, h,
                             {"m": mstate, "k": cache["k"], "v": cache["v"]})
        hs.append(ys["h"].reshape((-1,) + ys["h"].shape[2:]))
        cs.append(ys["conv"].reshape((-1,) + ys["conv"].shape[2:]))
        new["k"], new["v"] = ys["k"], ys["v"]
    if rem:
        tail = {"p": jax.tree.map(lambda a: a[nseg * every:], layers),
                "h": cache["h"][nseg * every:], "conv": cache["conv"][nseg * every:]}
        h, ys = jax.lax.scan(mamba_body, h, tail)
        hs.append(ys["h"])
        cs.append(ys["conv"])
    new["h"] = jnp.concatenate(hs, 0)
    new["conv"] = jnp.concatenate(cs, 0)
    return h, new
