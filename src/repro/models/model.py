"""Unified model covering all assigned architecture families.

Param pytree layout (bilevel split is structural):
  {"x": {"embed", "layers", ["shared"], ["encoder"]},      # UL variable (backbone)
   "y": {"final_norm", "head"}}                            # LL variable (head)

All stacks scan over stacked layer params with per-layer remat (train), so HLO
size is O(1) in depth. Families:
  dense/vlm  : GQA attention + gated MLP (optional qkv bias / window / prefix fusion)
  moe        : GQA attention + top-k MoE (optional shared FFN)
  ssm        : mamba1 mixer only
  hybrid     : mamba2 mixers + ONE weight-tied shared attention block every k layers
  encdec     : whisper-style encoder (stubbed frontend embeds) + cross-attn decoder
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.params import ParamSpec
from repro.sharding import shard_act


@dataclasses.dataclass(frozen=True)
class ModelCtx:
    """Per-call context: sharding rules + attention/window/runtime options."""
    rules: Optional[dict] = None
    window: Optional[int] = None      # sliding-window attention (long-context)
    kind: str = "train"               # train | prefill | decode
    attn_chunk: int = 1024
    ssm_chunk: int = 256
    kv_kernel: str = "xla"            # int8-KV decode path: xla | pallas | interpret


# ------------------------------------------------------------------ specs

def _attn_specs(cfg: ArchConfig, L: int, prefix="") -> Dict[str, ParamSpec]:
    d, h, kv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    ax = ("layers",) if L else ()
    shp = (L,) if L else ()
    s = {
        prefix + "ln_attn": ParamSpec(shp + (d,), ax + ("embed",), init="ones",
                                      dtype="float32"),
        prefix + "wq": ParamSpec(shp + (d, h, hd), ax + ("embed", "heads", "head_dim")),
        prefix + "wk": ParamSpec(shp + (d, kv, hd), ax + ("embed", "kv_heads", "head_dim")),
        prefix + "wv": ParamSpec(shp + (d, kv, hd), ax + ("embed", "kv_heads", "head_dim")),
        prefix + "wo": ParamSpec(shp + (h, hd, d), ax + ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        s[prefix + "bq"] = ParamSpec(shp + (h, hd), ax + ("heads", "head_dim"), init="zeros")
        s[prefix + "bk"] = ParamSpec(shp + (kv, hd), ax + ("kv_heads", "head_dim"), init="zeros")
        s[prefix + "bv"] = ParamSpec(shp + (kv, hd), ax + ("kv_heads", "head_dim"), init="zeros")
    return s


def _mlp_specs(cfg: ArchConfig, L: int, d_ff: int) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    ax = ("layers",) if L else ()
    shp = (L,) if L else ()
    return {
        "ln_mlp": ParamSpec(shp + (d,), ax + ("embed",), init="ones", dtype="float32"),
        "wi": ParamSpec(shp + (d, d_ff), ax + ("embed", "mlp")),
        "wu": ParamSpec(shp + (d, d_ff), ax + ("embed", "mlp")),
        "wd": ParamSpec(shp + (d_ff, d), ax + ("mlp", "embed")),
    }


def model_specs(cfg: ArchConfig) -> Dict[str, Any]:
    d = cfg.d_model
    L = cfg.n_layers
    x: Dict[str, Any] = {
        # vocab_in -> None everywhere: a vocab-sharded table turns every
        # embedding gather into cross-client all-reduces inside LOCAL steps
        # (measured: 20 MiB x microbatches x passes on the 2-pod mesh). The
        # table replicates over vocab; zero-mode FSDP shards its embed dim.
        "embed": ParamSpec((cfg.vocab, d), ("vocab_in", "embed")),
    }
    fam = cfg.family
    if fam in ("dense", "vlm"):
        layers = {**_attn_specs(cfg, L), **_mlp_specs(cfg, L, cfg.d_ff)}
    elif fam == "moe":
        layers = {**_attn_specs(cfg, L), **moe_lib.moe_specs(cfg, L)}
        layers["ln_mlp"] = ParamSpec((L, d), ("layers", "embed"), init="ones",
                                     dtype="float32")
    elif fam == "ssm":
        layers = ssm_lib.mamba1_specs(cfg, L)
    elif fam == "hybrid":
        layers = ssm_lib.mamba2_specs(cfg, L)
        x["shared"] = {**_attn_specs(cfg, 0), **_mlp_specs(cfg, 0, cfg.d_ff)}
    elif fam == "encdec":
        layers = {**_attn_specs(cfg, L), **_mlp_specs(cfg, L, cfg.d_ff)}
        layers.update(_attn_specs(cfg, L, prefix="c"))          # cross-attention
        x["encoder"] = {
            "layers": {**_attn_specs(cfg, cfg.encoder.n_layers),
                       **_mlp_specs(cfg, cfg.encoder.n_layers, cfg.d_ff)},
            "ln_out": ParamSpec((d,), ("embed",), init="ones", dtype="float32"),
        }
    else:
        raise ValueError(fam)
    x["layers"] = layers
    y = {
        "final_norm": ParamSpec((d,), ("embed",), init="ones", dtype="float32"),
        "head": ParamSpec((d, cfg.vocab), ("embed", "vocab")),
    }
    return {"x": x, "y": y}


# ------------------------------------------------------------------ primitives

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    # statistics accumulated in f32 via the dot (no f32 copy of x exists, so
    # autodiff/XLA residuals of the layer stay bf16), multiply in x.dtype.
    xx = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)[..., None]
    r = jax.lax.rsqrt(xx / x.shape[-1] + eps)
    return x * (r.astype(x.dtype) * w.astype(x.dtype))


def _attn_block(cfg: ArchConfig, p, h, ctx: ModelCtx, *, pos, causal=True,
                prefix="", kv_h=None, kv_pos=None):
    """Self- or cross-attention block. h: [B,S,d]. kv_h: source for K/V (cross)."""
    hn = rmsnorm(h, p[prefix + "ln_attn"], cfg.norm_eps)
    src = hn if kv_h is None else kv_h
    q = jnp.einsum("bsd,dhk->bshk", hn, p[prefix + "wq"])
    k = jnp.einsum("bsd,dhk->bshk", src, p[prefix + "wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p[prefix + "wv"])
    if cfg.qkv_bias and (prefix + "bq") in p:
        q = q + p[prefix + "bq"]
        k = k + p[prefix + "bk"]
        v = v + p[prefix + "bv"]
    if kv_h is None:                                      # RoPE for self-attn only
        q = attn_lib.rope(q, pos, cfg.rope_theta)
        kp = pos if kv_pos is None else kv_pos
        k = attn_lib.rope(k, kp, cfg.rope_theta)
    q = shard_act(q, ("batch", "seq", "heads", None), ctx.rules)
    s_kv = src.shape[1]
    if s_kv > ctx.attn_chunk:
        # chunked flash path: bounds live scores to O(Sq*chunk) in fwd AND bwd
        # (checkpointed chunk body), for train, prefill and cross-attention.
        o = attn_lib.attend_flash(q, k, v, causal=causal and kv_h is None,
                                  window=ctx.window, chunk=ctx.attn_chunk)
    elif kv_h is not None:
        o = attn_lib.attend_full(q, k, v, causal=False)
    else:
        o = attn_lib.attend_full(q, k, v, causal=causal, window=ctx.window)
    out = jnp.einsum("bshk,hkd->bsd", o, p[prefix + "wo"])
    return h + out, (k, v)


def _mlp_block(cfg: ArchConfig, p, h, ctx: ModelCtx):
    hn = rmsnorm(h, p["ln_mlp"], cfg.norm_eps)
    if cfg.family == "moe":
        # tokens enter the MoE block seq-UNsharded: dispatch from seq-sharded
        # tokens into expert-sharded buffers makes GSPMD all-reduce scatter
        # partials over `model` (measured 17 GiB wire on the 32k prefill);
        # localizing tokens first yields the classic expert all-to-all.
        hn = shard_act(hn, ("batch", None, "act_embed"), ctx.rules)
        out = moe_lib.apply_moe(cfg, p, hn)
        out = shard_act(out, ("batch", "seq", "act_embed"), ctx.rules)
    else:
        g = jnp.einsum("bsd,df->bsf", hn, p["wi"])
        u = jnp.einsum("bsd,df->bsf", hn, p["wu"])
        out = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, p["wd"])
    return h + out


def _transformer_layer(cfg, p, h, ctx, pos, *, causal=True, cross_src=None):
    h, _ = _attn_block(cfg, p, h, ctx, pos=pos, causal=causal)
    if cross_src is not None:
        h, _ = _attn_block(cfg, p, h, ctx, pos=pos, causal=False,
                           prefix="c", kv_h=cross_src)
    h = _mlp_block(cfg, p, h, ctx)
    return shard_act(h, ("batch", "seq", "act_embed"), ctx.rules)


def _scan_layers(body, stacked, h, remat: bool):
    fn = jax.checkpoint(body) if remat else body

    def step(carry, xs):
        # barrier: stops XLA from hoisting per-layer weight dtype-conversions
        # out of the loop (the CPU backend upcasts bf16 dot operands to f32;
        # hoisted, that materializes an f32 copy of EVERY layer's weights).
        xs = jax.lax.optimization_barrier(xs)
        return fn(carry, xs), None

    h, _ = jax.lax.scan(step, h, stacked)
    return h


# ------------------------------------------------------------------ features

def embed_tokens(cfg, xp, tokens, prefix_embeds):
    h = jnp.take(xp["embed"], tokens, axis=0)
    if prefix_embeds is not None and cfg.n_prefix_embeds:
        npfx = prefix_embeds.shape[1]
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h[:, npfx:]], axis=1)
    return h


def encoder_forward(cfg, xp, enc_embeds, ctx: ModelCtx):
    """Whisper-style encoder over stubbed frame embeddings [B,Senc,d]."""
    ep = xp["encoder"]
    h = enc_embeds
    pos = jnp.arange(h.shape[1])

    def body(carry, lp):
        return _transformer_layer(cfg, lp, carry, ctx, pos, causal=False)

    h = _scan_layers(body, ep["layers"], h, remat=ctx.kind == "train")
    return rmsnorm(h, ep["ln_out"], cfg.norm_eps)


def features(cfg: ArchConfig, xp, batch: Dict[str, jax.Array],
             ctx: ModelCtx) -> jax.Array:
    """Backbone features [B,S,d] (everything except final norm + LM head)."""
    tokens = batch["tokens"]
    h = embed_tokens(cfg, xp, tokens, batch.get("prefix_embeds"))
    h = shard_act(h, ("batch", "seq", "act_embed"), ctx.rules)
    b, S = tokens.shape
    pos = jnp.arange(S)
    remat = ctx.kind == "train"
    fam = cfg.family

    if fam in ("dense", "vlm", "moe"):
        def body(carry, lp):
            return _transformer_layer(cfg, lp, carry, ctx, pos)
        h = _scan_layers(body, xp["layers"], h, remat)

    elif fam == "ssm":
        def body(carry, lp):
            hn = rmsnorm(carry, lp["ln"], cfg.norm_eps)
            y, _ = ssm_lib.mamba1_seq(cfg, lp, hn, chunk=ctx.ssm_chunk)
            out = carry + y
            return shard_act(out, ("batch", "seq", "act_embed"), ctx.rules)
        h = _scan_layers(body, xp["layers"], h, remat)

    elif fam == "hybrid":
        h = _hybrid_seq(cfg, xp, h, ctx, pos, remat)

    elif fam == "encdec":
        enc_out = encoder_forward(cfg, xp, batch["enc_embeds"], ctx)

        def body(carry, lp):
            return _transformer_layer(cfg, lp, carry, ctx, pos, cross_src=enc_out)
        h = _scan_layers(body, xp["layers"], h, remat)
    else:
        raise ValueError(fam)
    return h


def _hybrid_seq(cfg, xp, h, ctx, pos, remat):
    """zamba2: scan segments of `every` mamba2 layers; after each segment apply
    the single weight-tied shared attention+MLP block."""
    every = cfg.shared_attn_every
    L = cfg.n_layers
    nseg, rem = divmod(L, every)
    layers = xp["layers"]

    def mamba_body(carry, lp):
        hn = rmsnorm(carry, lp["ln"], cfg.norm_eps)
        y, _ = ssm_lib.mamba2_seq(cfg, lp, hn, chunk=ctx.ssm_chunk)
        out = carry + y
        return shard_act(out, ("batch", "seq", "act_embed"), ctx.rules)

    def seg_body(carry, seg_params):
        hh = _scan_layers(mamba_body, seg_params, carry, remat)
        hh = _transformer_layer(cfg, xp["shared"], hh, ctx, pos)
        return hh, None

    if nseg:
        seg_stack = jax.tree.map(
            lambda a: a[: nseg * every].reshape((nseg, every) + a.shape[1:]),
            layers)
        h, _ = jax.lax.scan(seg_body, h, seg_stack)
    if rem:
        tail = jax.tree.map(lambda a: a[nseg * every:], layers)
        h = _scan_layers(mamba_body, tail, h, remat)
    return h


def head_logits(cfg: ArchConfig, yp, feats: jax.Array) -> jax.Array:
    h = rmsnorm(feats, yp["final_norm"], cfg.norm_eps)
    return jnp.einsum("bsd,dv->bsv", h, yp["head"])


def forward(cfg, params, batch, ctx: ModelCtx) -> jax.Array:
    return head_logits(cfg, params["y"], features(cfg, params["x"], batch, ctx))
