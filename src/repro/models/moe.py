"""Mixture-of-Experts layer: top-k token-choice routing with capacity-based
scatter dispatch (GShard-style semantics without the dense one-hot einsum).

FLOPs are the honest active FLOPs (E x C x d x f); dispatch/combine are
scatter/gather. Experts are sharded over the ``model`` mesh axis (logical axis
``experts``); with fed_mode="zero" the expert FFN dim additionally shards over
``data`` (logical axis ``expert_mlp``).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.params import ParamSpec


def moe_specs(cfg: ArchConfig, n_layers: int) -> Dict[str, ParamSpec]:
    e = cfg.moe
    d = cfg.d_model
    L = n_layers
    specs = {
        "router": ParamSpec((L, d, e.n_experts), ("layers", "embed", None)),
        "we_gate": ParamSpec((L, e.n_experts, d, e.d_ff_expert),
                             ("layers", "experts", "embed", "expert_mlp")),
        "we_up": ParamSpec((L, e.n_experts, d, e.d_ff_expert),
                           ("layers", "experts", "embed", "expert_mlp")),
        "we_down": ParamSpec((L, e.n_experts, e.d_ff_expert, d),
                             ("layers", "experts", "expert_mlp", "embed")),
    }
    if e.d_ff_shared:
        specs.update({
            "ws_gate": ParamSpec((L, d, e.d_ff_shared), ("layers", "embed", "mlp")),
            "ws_up": ParamSpec((L, d, e.d_ff_shared), ("layers", "embed", "mlp")),
            "ws_down": ParamSpec((L, e.d_ff_shared, d), ("layers", "mlp", "embed")),
        })
    return specs


def _moe_group(cfg: ArchConfig, p: Dict[str, jax.Array], xf: jax.Array,
               capacity: int) -> jax.Array:
    """Dispatch/compute/combine for ONE group of tokens. xf: [n, d]."""
    e = cfg.moe
    n, d = xf.shape
    logits = jnp.einsum("nd,de->ne", xf, p["router"]).astype(jnp.float32)
    gates, eids = jax.lax.top_k(logits, e.top_k)                 # [n, k]
    gates = jax.nn.softmax(gates, axis=-1).astype(xf.dtype)

    flat_eids = eids.reshape(-1)                                 # [n*k]
    onehot = jax.nn.one_hot(flat_eids, e.n_experts, dtype=jnp.int32)
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - 1)             # [n*k, E]
    slot = jnp.take_along_axis(pos_in_expert, flat_eids[:, None], axis=1)[:, 0]
    keep = slot < capacity
    slot = jnp.minimum(slot, capacity - 1)

    # dispatch: [E, C, d]
    src = jnp.repeat(xf, e.top_k, axis=0) * keep[:, None].astype(xf.dtype)
    buf = jnp.zeros((e.n_experts, capacity, d), xf.dtype)
    buf = buf.at[flat_eids, slot].add(src)

    h_g = jnp.einsum("ecd,edf->ecf", buf, p["we_gate"])
    h_u = jnp.einsum("ecd,edf->ecf", buf, p["we_up"])
    h = jax.nn.silu(h_g) * h_u
    # combine traffic crosses the expert (model) axis: keep it in xf.dtype
    out_buf = jnp.einsum("ecf,efd->ecd", h,
                         p["we_down"]).astype(xf.dtype)          # [E, C, d]

    # combine: gather each token's k slots
    gathered = out_buf[flat_eids, slot]                          # [n*k, d]
    gathered = gathered * (gates.reshape(-1)[:, None]
                           * keep[:, None].astype(xf.dtype))
    return gathered.reshape(n, e.top_k, d).sum(axis=1)


def apply_moe(cfg: ArchConfig, p: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    """x: [B, S, d] -> [B, S, d]. ``p`` holds one layer's (unstacked) params.

    GShard-style grouping: each batch row is its own dispatch group (vmapped),
    so all dispatch buffers carry the sharded batch dim — a single global
    group makes the one-hot/cumsum/scatter buffers scale with GLOBAL tokens
    and replicates them across the 512-chip mesh (measured: 74 GiB/device on
    the 2-pod MoE prefill)."""
    e = cfg.moe
    b, s, d = x.shape
    capacity = max(int(s * e.top_k * e.capacity_factor / e.n_experts), 4)
    out = jax.vmap(lambda row: _moe_group(cfg, p, row, capacity))(x)

    if e.d_ff_shared:
        hs = jax.nn.silu(x @ p["ws_gate"]) * (x @ p["ws_up"])
        out = out + hs @ p["ws_down"]
    return out


def aux_load_balance_loss(logits: jax.Array, eids: jax.Array, n_experts: int):
    """Switch-style load-balance auxiliary loss (returned for monitoring)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = probs.mean(axis=0)
    ce = jnp.bincount(eids.reshape(-1), length=n_experts) / eids.size
    return n_experts * jnp.sum(me * ce)
