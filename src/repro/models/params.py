"""Parameter specs: shapes + logical axes + initializers, as plain pytrees.

Models declare a pytree of ``ParamSpec``; ``init_params`` materializes arrays and
``axes_tree`` yields the parallel pytree of logical-axis tuples that
``repro.sharding`` maps onto the mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]     # logical axis name per dim (None = replicated)
    init: str = "normal"                # normal | zeros | ones | ssm_a | ssm_dt
    scale: float = 0.02
    dtype: Optional[str] = None         # override model dtype (e.g. fp32 for norms)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _materialize(spec: ParamSpec, key, default_dtype) -> jax.Array:
    dtype = jnp.dtype(spec.dtype or default_dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "ssm_a":
        # mamba A_log init: log(1..N) broadcast over inner dim
        n = spec.shape[-1]
        a = jnp.tile(jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)),
                     spec.shape[:-1] + (1,))
        return a.astype(dtype)
    if spec.init == "ssm_dt":
        # softplus^-1 of dt in [1e-3, 1e-1]
        lo, hi = 1e-3, 1e-1
        u = jax.random.uniform(key, spec.shape, jnp.float32)
        dt = jnp.exp(u * (np.log(hi) - np.log(lo)) + np.log(lo))
        inv = dt + jnp.log(-jnp.expm1(-dt))
        return inv.astype(dtype)
    fan_scale = spec.scale
    return (jax.random.normal(key, spec.shape, jnp.float32) * fan_scale).astype(dtype)


def init_params(specs, key, default_dtype="bfloat16"):
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_materialize(s, k, default_dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(specs, default_dtype="bfloat16"):
    """ShapeDtypeStruct pytree (for dry-run lowering without allocation)."""
    def f(s: ParamSpec):
        return jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype or default_dtype))
    return jax.tree.map(f, specs, is_leaf=is_spec)


def axes_tree(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def param_count(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) for s in leaves)
