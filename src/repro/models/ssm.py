"""Selective state-space layers.

- Mamba1 (falcon-mamba-7b): per-channel state, chunked associative scan.
- Mamba2 (zamba2): multi-head scalar-A SSD with the chunked dual form
  (intra-chunk quadratic + inter-chunk recurrence), which is both the honest
  FLOPs form and the memory-feasible one.

Both expose train/prefill paths (full sequence -> outputs [+ final state]) and
decode paths (single-token state update).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.params import ParamSpec


# ---------------------------------------------------------------- mamba1

def mamba1_specs(cfg: ArchConfig, n_layers: int) -> Dict[str, ParamSpec]:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    dtr = max(d // 16, 1)
    L = n_layers
    ax = ("layers",)
    return {
        "ln": ParamSpec((L, d), ax + ("embed",), init="ones", dtype="float32"),
        "in_proj": ParamSpec((L, d, 2 * di), ax + ("embed", "ssm_inner")),
        "conv_w": ParamSpec((L, s.conv_width, di), ax + ("conv", "ssm_inner")),
        "conv_b": ParamSpec((L, di), ax + ("ssm_inner",), init="zeros"),
        "x_proj": ParamSpec((L, di, dtr + 2 * s.state_dim),
                            ax + ("ssm_inner", None)),
        "dt_w": ParamSpec((L, dtr, di), ax + (None, "ssm_inner")),
        "dt_b": ParamSpec((L, di), ax + ("ssm_inner",), init="ssm_dt",
                          dtype="float32"),
        "A_log": ParamSpec((L, di, s.state_dim), ax + ("ssm_inner", "ssm_state"),
                           init="ssm_a", dtype="float32"),
        "D": ParamSpec((L, di), ax + ("ssm_inner",), init="ones", dtype="float32"),
        "out_proj": ParamSpec((L, di, d), ax + ("ssm_inner", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """x: [B,S,di]; w: [cw,di]; depthwise causal conv. Returns (y, new_state)
    where state holds the trailing cw-1 inputs."""
    cw = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(cw))
    new_state = xp[:, -(cw - 1):] if cw > 1 else state
    return jax.nn.silu(y + b), new_state


def _selective_scan_chunk(a: jax.Array, bx: jax.Array, h0: jax.Array):
    """Linear recurrence h_t = a_t * h_{t-1} + bx_t within one chunk via an
    associative scan. a, bx: [B, c, di, N]; h0: [B, di, N]."""
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl
    a_all, b_all = jax.lax.associative_scan(combine, (a, bx), axis=1)
    h = a_all * h0[:, None] + b_all                 # [B, c, di, N]
    return h, h[:, -1]


def mamba1_seq(cfg: ArchConfig, p: Dict[str, jax.Array], x: jax.Array,
               h0: Optional[jax.Array] = None, conv0=None, chunk: int = 256):
    """Full-sequence mamba1 mixer. x: [B,S,d] -> (y [B,S,d], (h, conv_state))."""
    s = cfg.ssm
    b, S, d = x.shape
    di = s.expand * d
    n = s.state_dim
    dtr = max(d // 16, 1)
    xz = x @ p["in_proj"]
    xi, z = xz[..., :di], xz[..., di:]
    xi, conv_state = _causal_conv(xi, p["conv_w"], p["conv_b"], conv0)

    proj = xi @ p["x_proj"]
    dt = jax.nn.softplus(proj[..., :dtr] @ p["dt_w"]
                         + p["dt_b"]).astype(jnp.float32)        # [B,S,di]
    Bm = proj[..., dtr:dtr + n].astype(jnp.float32)              # [B,S,N]
    Cm = proj[..., dtr + n:].astype(jnp.float32)                 # [B,S,N]
    A = -jnp.exp(p["A_log"])                                     # [di,N]

    if h0 is None:
        h0 = jnp.zeros((b, di, n), jnp.float32)
    nchunks = max(S // chunk, 1)
    chunk = S // nchunks

    def body(h, xs):
        dt_c, B_c, x_c, C_c = xs                                 # [B,c,...]
        x_c = x_c.astype(jnp.float32)        # converted per chunk, not hoisted
        a = jnp.exp(dt_c[..., None] * A)                         # [B,c,di,N]
        bx = (dt_c * x_c)[..., None] * B_c[:, :, None, :]        # [B,c,di,N]
        hs, h_last = _selective_scan_chunk(a, bx, h)
        y = jnp.einsum("bcdn,bcn->bcd", hs, C_c)
        return h_last, y

    def split(t):  # [B,S,...] -> [nchunks,B,c,...]
        return t.reshape(b, nchunks, chunk, *t.shape[2:]).swapaxes(0, 1)

    h_last, ys = jax.lax.scan(body, h0,
                              (split(dt), split(Bm), split(xi), split(Cm)))
    y = ys.swapaxes(0, 1).reshape(b, S, di)
    y = y + xi.astype(jnp.float32) * p["D"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ p["out_proj"], (h_last, conv_state)


def mamba1_decode(cfg: ArchConfig, p: Dict[str, jax.Array], x: jax.Array,
                  h: jax.Array, conv_state: jax.Array):
    """x: [B,1,d]; single-step state update."""
    s = cfg.ssm
    b, _, d = x.shape
    di = s.expand * d
    n = s.state_dim
    dtr = max(d // 16, 1)
    xz = x @ p["in_proj"]
    xi, z = xz[..., :di], xz[..., di:]
    xi, conv_state = _causal_conv(xi, p["conv_w"], p["conv_b"], conv_state)
    proj = xi @ p["x_proj"]
    dt = jax.nn.softplus(proj[..., :dtr] @ p["dt_w"] + p["dt_b"]).astype(jnp.float32)
    Bm = proj[..., dtr:dtr + n].astype(jnp.float32)
    Cm = proj[..., dtr + n:].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[:, 0, :, None] * A)                           # [B,di,N]
    bx = (dt[:, 0] * xi[:, 0].astype(jnp.float32))[..., None] * Bm[:, 0, None, :]
    h = a * h + bx
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0])[:, None]
    y = y + xi.astype(jnp.float32) * p["D"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ p["out_proj"], (h, conv_state)


# ---------------------------------------------------------------- mamba2 (SSD)

def mamba2_specs(cfg: ArchConfig, n_layers: int) -> Dict[str, ParamSpec]:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    nh = di // s.head_dim
    n = s.state_dim
    L = n_layers
    ax = ("layers",)
    # in_proj packs [z, x, B, C, dt]
    proj_out = 2 * di + 2 * n + nh
    return {
        "ln": ParamSpec((L, d), ax + ("embed",), init="ones", dtype="float32"),
        "in_proj": ParamSpec((L, d, proj_out), ax + ("embed", "ssm_inner")),
        "conv_w": ParamSpec((L, s.conv_width, di + 2 * n),
                            ax + ("conv", "ssm_inner")),
        "conv_b": ParamSpec((L, di + 2 * n), ax + ("ssm_inner",), init="zeros"),
        "A_log": ParamSpec((L, nh), ax + (None,), init="ssm_a", dtype="float32"),
        "dt_b": ParamSpec((L, nh), ax + (None,), init="ssm_dt", dtype="float32"),
        "D": ParamSpec((L, nh), ax + (None,), init="ones", dtype="float32"),
        "gate_ln": ParamSpec((L, di), ax + ("ssm_inner",), init="ones",
                             dtype="float32"),
        "out_proj": ParamSpec((L, di, d), ax + ("ssm_inner", "embed")),
    }


def _ssd_chunk_dual(xh, Bc, Cc, dtc, A, h0, chunk):
    """SSD chunked dual form.

    xh: [B,S,H,P]; Bc,Cc: [B,S,N]; dtc: [B,S,H] (softplus'd); A: [H] (negative).
    Returns y [B,S,H,P] and final state [B,H,P,N]. All float32.
    """
    b, S, H, P = xh.shape
    n = Bc.shape[-1]
    nchunks = max(S // chunk, 1)
    c = S // nchunks

    def split(t):
        return t.reshape(b, nchunks, c, *t.shape[2:]).swapaxes(0, 1)

    xs = (split(xh), split(Bc), split(Cc), split(dtc))

    def body(h, xs_c):
        x_c, B_c, C_c, dt_c = xs_c                               # [B,c,...]
        da = dt_c * A                                            # [B,c,H] (<=0)
        seg = jnp.cumsum(da, axis=1)                             # [B,c,H]
        # intra-chunk: scores[i,j] = C_i.B_j * exp(seg_i - seg_j), j <= i
        gap = seg[:, :, None, :] - seg[:, None, :, :]            # [B,c,c,H]
        mask = jnp.tril(jnp.ones((c, c), bool))
        decay = jnp.where(mask[None, :, :, None], jnp.exp(gap), 0.0)
        cb = jnp.einsum("bin,bjn->bij", C_c, B_c)                # [B,c,c]
        scores = cb[..., None] * decay                           # [B,c,c,H]
        xdt = x_c * dt_c[..., None]                              # [B,c,H,P]
        y = jnp.einsum("bijh,bjhp->bihp", scores, xdt)
        # inter-chunk: contribution of the carried state
        y = y + jnp.einsum("bin,bhpn,bih->bihp", C_c, h, jnp.exp(seg))
        # new carried state
        last = seg[:, -1:, :]                                    # [B,1,H]
        w = jnp.exp(last - seg)                                  # [B,c,H]
        h_new = (h * jnp.exp(last)[:, 0, :, None, None]
                 + jnp.einsum("bch,bchp,bcn->bhpn", w * dt_c, x_c, B_c))
        return h_new, y

    h_last, ys = jax.lax.scan(body, h0, xs)
    y = ys.swapaxes(0, 1).reshape(b, S, H, P)
    return y, h_last


def _mamba2_project(cfg, p, x, conv0):
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    n = s.state_dim
    nh = di // s.head_dim
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * n]
    dt = zxbcdt[..., di + di + 2 * n:]
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv0)
    xi = xbc[..., :di]
    Bc = xbc[..., di:di + n].astype(jnp.float32)
    Cc = xbc[..., di + n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_b"])     # [B,S,H]
    xh = xi.astype(jnp.float32).reshape(*xi.shape[:-1], nh, s.head_dim)
    return z, xi, xh, Bc, Cc, dt, conv_state


def _mamba2_out(cfg, p, y, xh, dt, z, x_dtype):
    y = y + xh * p["D"][:, None]                                 # D skip per head
    b, S = y.shape[:2]
    y = y.reshape(b, S, -1)
    # gated RMSNorm (mamba2 norm-before-out_proj)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    rms = jnp.sqrt(jnp.mean(y ** 2, axis=-1, keepdims=True) + 1e-5)
    y = (y / rms) * p["gate_ln"]
    return y.astype(x_dtype) @ p["out_proj"]


def mamba2_seq(cfg: ArchConfig, p: Dict[str, jax.Array], x: jax.Array,
               h0: Optional[jax.Array] = None, conv0=None, chunk: int = 256):
    s = cfg.ssm
    b, S, d = x.shape
    di = s.expand * d
    nh = di // s.head_dim
    z, xi, xh, Bc, Cc, dt, conv_state = _mamba2_project(cfg, p, x, conv0)
    A = -jnp.exp(p["A_log"])                                     # [H]
    if h0 is None:
        h0 = jnp.zeros((b, nh, s.head_dim, s.state_dim), jnp.float32)
    chunk = min(chunk, S)
    y, h_last = _ssd_chunk_dual(xh, Bc, Cc, dt, A, h0, chunk)
    return _mamba2_out(cfg, p, y, xh, dt, z, x.dtype), (h_last, conv_state)


def mamba2_decode(cfg: ArchConfig, p: Dict[str, jax.Array], x: jax.Array,
                  h: jax.Array, conv_state: jax.Array):
    s = cfg.ssm
    z, xi, xh, Bc, Cc, dt, conv_state = _mamba2_project(cfg, p, x, conv_state)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[:, 0] * A)                                    # [B,H]
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt[:, 0], xh[:, 0], Bc[:, 0])
    h = a[..., None, None] * h + upd
    y = jnp.einsum("bhpn,bn->bhp", h, Cc[:, 0])[:, None]         # [B,1,H,P]
    return _mamba2_out(cfg, p, y, xh, dt, z, x.dtype), (h, conv_state)
