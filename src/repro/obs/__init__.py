"""repro.obs — unified telemetry: structured metrics, spans, profiler hooks.

See docs/observability.md for the record schema, span semantics and the
profiler workflow. Entry points:

  Telemetry / make_telemetry   the bus (sinks, rounds, stats, spans, close)
  NULL                         shared no-op bus for uninstrumented runs
  JsonlSink / StdoutSink / MemorySink
  run_manifest                 the schema-versioned run header
  StatAccum                    on-device [K, S] stat ring, one transfer per K
  progress_line                the shared launcher progress formatter
"""
from repro.obs.telemetry import (NULL, SCHEMA, JsonlSink, MemorySink,
                                 NullTelemetry, StdoutSink, Telemetry,
                                 make_telemetry, run_manifest)
from repro.obs.devstats import STAT_FIELDS, StatAccum, stat_row
from repro.obs.progress import progress_line

__all__ = [
    "NULL", "SCHEMA", "JsonlSink", "MemorySink", "NullTelemetry",
    "StdoutSink", "Telemetry", "make_telemetry", "run_manifest",
    "STAT_FIELDS", "StatAccum", "stat_row", "progress_line",
]
