"""On-device per-round stat accumulation, drained every K rounds.

The driver round loops are host loops: naively instrumenting them means a
device→host transfer per round (exactly the pattern the ROADMAP's mega-scan
item is trying to kill). :class:`StatAccum` instead keeps a ``[K, S]`` f32
ring on device and appends one row of scalars per round with a single jitted
update program whose carry is **donated** — per round the host dispatches one
tiny kernel and transfers nothing. Every K rounds (``--metrics-every``) the
buffer is drained with ONE host transfer and handed to the telemetry bus as
a ``stats`` record.

Deliberate design point: the stats are computed by a SEPARATE jitted program
run on each round's *output* states, not folded into the round programs
themselves. That keeps the compiled round programs byte-identical whether
telemetry is on or off — the parity guarantee tests/test_obs.py pins — while
still meeting the one-transfer-per-K-rounds budget. (Folding them into a
future R-round mega-scan is then a carry-threading exercise, not a numerics
change.)

Fields (order = column order in the buffer):

  global_norm   ‖avg(states)‖ over all state leaves (x, y, v, w, lr state)
  update_norm   ‖avg_t − avg_{t−1}‖ — the per-round server update magnitude
  consensus     optional: Σ_θ (1/M)Σ_m ‖θ^m − θ̄‖² (Lemmas 20-21's quantity);
                O(N) work per round, so opt-in via ``consensus=True``
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core.metrics import consensus_error
from repro.core.tree_util import (tree_mean_axis0, tree_norm, tree_sub)

# column order of a stat row without the opt-in consensus tail
STAT_FIELDS = ("global_norm", "update_norm")


def stat_row(states, prev_avg, consensus: bool = False):
    """One ``[S]`` f32 stats row for a bank/state pytree (leading client
    axis) against the previous round's client average: ``(row, new_avg)``.

    The shared math of :class:`StatAccum` and the mega-scan tier — the mega
    programs thread ``prev_avg`` through their scan carry and emit one row
    per round as a scan output, so the fused program computes exactly the
    rows StatAccum would have (and, because the rows are unconditionally
    part of the program, it is byte-identical with telemetry on or off).
    """
    avg = tree_mean_axis0(states)
    cols = [tree_norm(avg), tree_norm(tree_sub(avg, prev_avg))]
    if consensus:
        ce = consensus_error(states)
        cols.append(sum(ce.values()))
    return jnp.stack([c.astype(jnp.float32) for c in cols]), avg


class StatAccum:
    """Device-resident ``[K, S]`` scalar ring + donated-carry update program.

    Usage (one instance per run)::

        acc = StatAccum.create(states, k=8, consensus=False)
        for r in range(rounds):
            states = round_program(states, ...)
            acc.update(states)            # dispatch-only, no transfer
            if acc.ready:
                tele.stats(**acc.drain()) # ONE transfer per k rounds
        if acc.pending:
            tele.stats(**acc.drain())     # partial tail window
    """

    def __init__(self, k: int, fields: Tuple[str, ...], carry, update_fn):
        self.k = k
        self.fields = fields
        self._carry = carry
        self._update = update_fn
        self.pending = 0          # rows written since last drain
        self._round0 = 0          # round id of the first pending row

    # ------------------------------------------------------------ factory

    @classmethod
    def create(cls, states, k: int, consensus: bool = False) -> "StatAccum":
        """Build the accumulator for a bank/state pytree with leading client
        axis. ``k`` is the drain window (``--metrics-every``)."""
        if k < 1:
            raise ValueError(f"stat window must be >= 1, got {k}")
        fields = ("global_norm", "update_norm") + (
            ("consensus",) if consensus else ())
        s = len(fields)

        def _update(carry, states):
            row, avg = stat_row(states, carry["prev"], consensus)
            return {"buf": carry["buf"].at[carry["i"]].set(row),
                    "i": (carry["i"] + 1) % k,
                    "prev": avg}

        init_prev = jax.jit(tree_mean_axis0)(states)
        carry = {"buf": jnp.zeros((k, s), jnp.float32),
                 "i": jnp.zeros((), jnp.int32),
                 "prev": init_prev}
        update_fn = jax.jit(_update, donate_argnums=(0,))
        return cls(k, fields, carry, update_fn)

    # ------------------------------------------------------------ per round

    def update(self, states) -> None:
        """Append one row for this round's output states. Dispatch only —
        nothing crosses to the host here."""
        self._carry = self._update(self._carry, states)
        self.pending += 1

    @property
    def ready(self) -> bool:
        return self.pending >= self.k

    # ------------------------------------------------------------ drain

    def drain(self) -> Dict[str, Any]:
        """ONE host transfer: fetch the buffer, return ``round_start`` plus a
        python list per field (columns of the valid rows, oldest first)."""
        import numpy as np
        buf = np.asarray(self._carry["buf"])   # the single transfer
        n = self.pending
        i = int(np.asarray(self._carry["i"]))
        # rows were written at slots (i-n)..(i-1) mod k, oldest first
        idx = [(i - n + j) % self.k for j in range(n)]
        rows = buf[idx]
        out: Dict[str, Any] = {"round_start": self._round0}
        for c, name in enumerate(self.fields):
            out[name] = [float(v) for v in rows[:, c]]
        self._round0 += n
        self.pending = 0
        return out
