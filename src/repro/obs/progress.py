"""One progress formatter for every launcher loop.

``launch/train.py`` used to carry four near-duplicate per-round ``print``
f-strings (eager, scan, dense population, spilled population, async) that
drifted independently as engines gained fields. :func:`progress_line`
renders all of them from the same per-round record the telemetry bus
receives — optional fields switch the engine-specific segments on, and the
output strings are pinned character-for-character against the legacy
formats by tests/test_obs.py.

Layout: segments joined by two spaces, fields within a segment by one —

  round 12 (step 47) | f(x̄,ȳ) = 0.1234 | round=12.3ms
  [arrived=3 dropped=1 tau=1.50 eta_scale=0.870] [up=0.12MB down=0.45MB]
  [cohort=[0, 3, 5]...] | (4.2s)

(eager runs render ``step N`` with no round/dt segments).
"""
from __future__ import annotations

from typing import Optional, Sequence


def progress_line(*, loss: float, elapsed: float, step: int,
                  round: Optional[int] = None,
                  round_seconds: Optional[float] = None,
                  bytes_up: Optional[int] = None,
                  bytes_down: Optional[int] = None,
                  cohort: Optional[Sequence[int]] = None,
                  arrived: Optional[int] = None,
                  dropped: Optional[int] = None,
                  mean_staleness: Optional[float] = None,
                  eta_scale: Optional[float] = None) -> str:
    """Render one per-round (or per-step) progress line.

    ``round=None`` gives the eager per-step form; ``arrived`` &c. add the
    async segment; ``bytes_up``/``bytes_down`` the wire segment; ``cohort``
    the sampled-ids segment. ``cohort`` shows at most its first 8 ids
    (callers pass the full cohort)."""
    segs = []
    if round is None:
        segs.append(f"step {step:5d}")
    else:
        segs.append(f"round {round:4d} (step {step:5d})")
    segs.append(f"f(x̄,ȳ) = {loss:.4f}")
    if round_seconds is not None:
        segs.append(f"round={round_seconds*1e3:.1f}ms")
    if arrived is not None:
        segs.append(f"arrived={int(arrived)} dropped={int(dropped)} "
                    f"tau={float(mean_staleness):.2f} "
                    f"eta_scale={float(eta_scale):.3f}")
    if bytes_up is not None:
        segs.append(f"up={bytes_up/1e6:.2f}MB down={bytes_down/1e6:.2f}MB")
    if cohort is not None:
        segs.append(f"cohort={list(cohort[:8])}...")
    segs.append(f"({elapsed:.1f}s)")
    return "  ".join(segs)
