"""Unified telemetry bus: structured run metrics, phase spans, profiler hooks.

Every run artifact the repo produces — the per-round progress lines of
``launch/train.py``, the driver's ``RunResult`` counters, the BENCH JSONs —
tracks the inputs of the paper's headline cost curves (samples, comms,
bytes on the wire, staleness). This module gives them ONE schema-versioned
stream instead of ad-hoc lists and print blocks:

  * a :class:`Telemetry` bus with pluggable sinks (:class:`JsonlSink`,
    :class:`StdoutSink`, :class:`MemorySink`) emitting a run **manifest**
    (:func:`run_manifest`: config, git SHA, jax version, device topology,
    seed) followed by per-round ``round`` records, device-drained ``stats``
    records and a closing ``summary``;
  * :meth:`Telemetry.span` phase timers — the caller fences with
    ``jax.block_until_ready`` (or :meth:`Span.fence`) INSIDE the span so the
    timer measures completion, not dispatch — that double as
    ``jax.profiler.TraceAnnotation`` regions, so gather / round-program /
    scatter / spill-prefetch show up as named regions in a profiler trace;
  * profiler hooks: ``Telemetry(profile_dir=...)`` starts a
    ``jax.profiler`` trace (TensorBoard-viewable) and stops it at
    :meth:`close`.

Record kinds (one JSON object per line in a metrics JSONL):

  manifest   first record of every stream; ``schema`` = :data:`SCHEMA`
  round      one per communication round: ``round``, ``step``,
             ``round_seconds``, cumulative ``samples``/``comms``/
             ``bytes_up``/``bytes_down``, engine extras (async arrival
             stats), buffered and flushed every ``metrics_every`` rounds
  stats      a drained on-device accumulator window
             (``repro.obs.devstats``): ``round_start`` + one list per
             scalar field, one host transfer per ``metrics_every`` rounds
  summary    aggregates at close: steady rounds/sec, phase span totals,
             wire totals, staleness histogram when the run recorded one

``scripts/report.py`` renders (or ``--check`` validates) any such stream;
the schema spec lives in docs/observability.md. Telemetry is strictly
observational: enabling it never changes a trajectory
(tests/test_obs.py pins bit-identical ``RunResult`` across all four
engines).
"""
from __future__ import annotations

import dataclasses
import json
import os
import platform
import subprocess
import sys
import time
import uuid
from typing import Any, Dict, List, Optional

# bumped whenever a record kind gains/changes a required field
SCHEMA = 1

KINDS = ("manifest", "round", "stats", "summary", "bench_row",
         "request", "tick")


# ------------------------------------------------------------------ sinks

class JsonlSink:
    """Append records to a JSONL file, one JSON object per line."""

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "w")

    def write(self, record: Dict[str, Any]) -> None:
        self._f.write(json.dumps(record) + "\n")

    def flush(self) -> None:
        self._f.flush()

    def close(self) -> None:
        self._f.close()


class StdoutSink:
    """Print each record as one JSON line (debugging / piping)."""

    def write(self, record: Dict[str, Any]) -> None:
        print(json.dumps(record), flush=True)

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


class MemorySink:
    """Keep records in a list — the test/driver-embedding sink."""

    def __init__(self):
        self.records: List[Dict[str, Any]] = []

    def write(self, record: Dict[str, Any]) -> None:
        self.records.append(record)

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass

    def of_kind(self, kind: str) -> List[Dict[str, Any]]:
        return [r for r in self.records if r.get("kind") == kind]


# ------------------------------------------------------------------ manifest

def _git_sha() -> Optional[str]:
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"],
                             capture_output=True, text=True, timeout=5,
                             cwd=os.path.dirname(os.path.abspath(__file__)))
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else None
    except Exception:
        return None


def _json_safe(x):
    """Best-effort conversion of config values to JSON-encodable types."""
    if isinstance(x, dict):
        return {str(k): _json_safe(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_json_safe(v) for v in x]
    if isinstance(x, (str, int, float, bool)) or x is None:
        return x
    return repr(x)


def run_manifest(config: Optional[Dict[str, Any]] = None,
                 seed: Optional[int] = None, **extra) -> Dict[str, Any]:
    """The schema-versioned run manifest: everything needed to know WHAT
    produced a metrics stream — config, git SHA, jax version, device
    topology, seed. Emitted as the first record of every telemetry stream
    and embedded as the ``manifest`` header of the BENCH JSON artifacts."""
    import jax
    devices = jax.devices()
    mesh = extra.pop("mesh", None)
    man = {
        "kind": "manifest",
        "schema": SCHEMA,
        "run_id": uuid.uuid4().hex[:12],
        "created": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "argv": list(sys.argv),
        "host": platform.node(),
        "python": platform.python_version(),
        "jax_version": jax.__version__,
        "platform": devices[0].platform if devices else "none",
        "device_count": len(devices),
        "devices": [str(d) for d in devices[:16]],
        "mesh": (dict(zip(mesh.axis_names, map(int, mesh.devices.shape)))
                 if mesh is not None else None),
        "git_sha": _git_sha(),
        "seed": seed,
        "config": _json_safe(config) if config is not None else None,
    }
    man.update(_json_safe(extra))
    return man


# ------------------------------------------------------------------ spans

class Span:
    """One timed phase region: wall-clock via ``perf_counter`` plus a
    ``jax.profiler.TraceAnnotation`` so the phase shows up as a named
    region in a profiler trace. Fence async work INSIDE the span (either
    explicitly or via :meth:`fence`) so the timer measures completion, not
    dispatch."""

    __slots__ = ("name", "_tele", "_ann", "_t0")

    def __init__(self, name: str, tele: "Telemetry"):
        self.name = name
        self._tele = tele

    def fence(self, x):
        """``jax.block_until_ready`` passthrough — the phase ends when the
        device work it dispatched is DONE."""
        import jax
        return jax.block_until_ready(x)

    def __enter__(self) -> "Span":
        import jax
        self._ann = jax.profiler.TraceAnnotation(self.name)
        self._ann.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dt = time.perf_counter() - self._t0
        self._ann.__exit__(*exc)
        self._tele._note_span(self.name, dt)
        return False


class _NullSpan:
    """Reusable no-op span for the disabled-telemetry path."""

    __slots__ = ()

    def fence(self, x):
        import jax
        return jax.block_until_ready(x)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


# ------------------------------------------------------------------ the bus

class Telemetry:
    """The telemetry bus: buffers records, aggregates round/phase totals,
    flushes to every sink each ``metrics_every`` rounds and at close.

    ``metrics_every`` is both the flush cadence AND the on-device stat
    drain window the drivers use (``repro.obs.devstats``); ``consensus``
    asks the device accumulator for the (O(N) compute) consensus-error
    scalar as well. ``profile_dir`` starts a ``jax.profiler`` trace
    immediately and stops it at :meth:`close` — load with
    ``tensorboard --logdir <dir>``."""

    def __init__(self, sinks=(), metrics_every: int = 8,
                 profile_dir: Optional[str] = None,
                 consensus: bool = False):
        if metrics_every < 1:
            raise ValueError(f"metrics_every must be >= 1 round, got "
                             f"{metrics_every}")
        self.sinks = list(sinks)
        self.metrics_every = metrics_every
        self.consensus = consensus
        self.profile_dir = profile_dir
        self._buf: List[Dict[str, Any]] = []
        self._phases: Dict[str, List[float]] = {}   # name -> [count, secs]
        self._rounds = 0
        self._round_seconds: List[float] = []
        self._last: Dict[str, Any] = {}
        self._notes: Dict[str, Any] = {}
        self._closed = False
        self._profiling = False
        if profile_dir:
            import jax
            os.makedirs(profile_dir, exist_ok=True)
            jax.profiler.start_trace(profile_dir)
            self._profiling = True

    # ------------------------------------------------------------ records

    def emit(self, record: Dict[str, Any]) -> None:
        self._buf.append(record)

    def manifest(self, config=None, seed=None, **extra) -> Dict[str, Any]:
        man = run_manifest(config, seed, **extra)
        self.emit(man)
        self.flush()
        return man

    def round(self, round: int, **fields) -> None:
        """One per-round record; buffered, flushed every ``metrics_every``
        rounds. Cumulative counters (``samples``/``comms``/``bytes_up``/
        ``bytes_down``) are remembered for the closing summary."""
        rec = {"kind": "round", "round": int(round)}
        rec.update(fields)
        self.emit(rec)
        self._rounds += 1
        if "round_seconds" in fields:
            self._round_seconds.append(float(fields["round_seconds"]))
        for k in ("samples", "comms", "bytes_up", "bytes_down", "step"):
            if k in fields:
                self._last[k] = fields[k]
        if self._rounds % self.metrics_every == 0:
            self.flush()

    def stats(self, round_start: int, **columns) -> None:
        """A drained on-device accumulator window: ``round_start`` plus one
        equal-length list per scalar field (``repro.obs.devstats``)."""
        rec = {"kind": "stats", "round_start": int(round_start)}
        rec.update({k: [float(v) for v in vs] for k, vs in columns.items()})
        self.emit(rec)

    def note(self, **kw) -> None:
        """Stash extra fields (e.g. the final staleness histogram) into the
        closing summary record."""
        self._notes.update(kw)

    # ------------------------------------------------------------ serving

    def request(self, rid: int, **fields) -> None:
        """One completed serve request (``repro.serve.engine``): prompt/new
        token counts, finish reason, latency. Buffered like rounds."""
        rec = {"kind": "request", "rid": int(rid)}
        rec.update(_json_safe(fields))
        self.emit(rec)

    def tick(self, tick: int, **fields) -> None:
        """One engine scheduler tick (slot occupancy, admissions,
        completions); flushed every ``metrics_every`` ticks."""
        rec = {"kind": "tick", "tick": int(tick)}
        rec.update(_json_safe(fields))
        self.emit(rec)
        if self.sinks and (tick + 1) % self.metrics_every == 0:
            self.flush()

    # ------------------------------------------------------------ spans

    def span(self, name: str) -> Span:
        return Span(name, self)

    def _note_span(self, name: str, dt: float) -> None:
        agg = self._phases.setdefault(name, [0, 0.0])
        agg[0] += 1
        agg[1] += dt

    @property
    def phase_totals(self) -> Dict[str, Dict[str, float]]:
        return {k: {"count": int(c), "seconds": round(s, 6)}
                for k, (c, s) in sorted(self._phases.items())}

    # ------------------------------------------------------------ lifecycle

    def flush(self) -> None:
        for rec in self._buf:
            for s in self.sinks:
                s.write(rec)
        self._buf.clear()
        for s in self.sinks:
            s.flush()

    def summary(self) -> Dict[str, Any]:
        # steady-state excludes the first recorded round — it carries the
        # compile (the drivers' RunResult.compile_seconds convention)
        steady = self._round_seconds[1:] or self._round_seconds
        per = sum(steady) / len(steady) if steady else None
        rec = {"kind": "summary",
               "rounds": self._rounds,
               "round_seconds_mean": (round(per, 6)
                                      if per is not None else None),
               "rounds_per_sec": (round(1.0 / per, 3)
                                  if per else None),
               "phases": self.phase_totals}
        rec.update({k: v for k, v in self._last.items()})
        rec.update(_json_safe(self._notes))
        return rec

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self._rounds or self._phases or self._notes:
            self.emit(self.summary())
        self.flush()
        for s in self.sinks:
            s.close()
        if self._profiling:
            import jax
            jax.profiler.stop_trace()
            self._profiling = False


class NullTelemetry:
    """Do-nothing stand-in so instrumented call sites never branch; spans
    are reusable no-ops (still usable as fences)."""

    sinks = ()
    metrics_every = 0
    consensus = False

    def emit(self, record) -> None:
        pass

    def manifest(self, config=None, seed=None, **extra):
        return None

    def round(self, round, **fields) -> None:
        pass

    def stats(self, round_start, **columns) -> None:
        pass

    def note(self, **kw) -> None:
        pass

    def request(self, rid, **fields) -> None:
        pass

    def tick(self, tick, **fields) -> None:
        pass

    def span(self, name):
        return _NULL_SPAN

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


NULL = NullTelemetry()


def make_telemetry(metrics_out: Optional[str] = None,
                   metrics_every: int = 8,
                   profile_dir: Optional[str] = None,
                   consensus: bool = False,
                   stdout: bool = False) -> Telemetry:
    """The launcher-facing constructor: a JSONL sink when ``metrics_out``
    is set, a stdout sink on request, profiling when ``profile_dir`` is
    set. With nothing enabled the bus still aggregates spans (so phase
    totals can be printed) at negligible cost."""
    sinks = []
    if metrics_out:
        sinks.append(JsonlSink(metrics_out))
    if stdout:
        sinks.append(StdoutSink())
    return Telemetry(sinks, metrics_every=metrics_every,
                     profile_dir=profile_dir, consensus=consensus)
