"""Serving subsystem: continuous-batching decode engine for the trained
global model (docs/serving.md).

- ``engine``: fixed-slot continuous-batching scheduler over ONE shared
  jitted decode step (per-slot positions, EOS/budget retirement, immediate
  refill), optional int8 KV cache via the quant_decode Pallas kernel.
- ``bridge``: launch/train.py checkpoint -> serve params (x̄, ȳ).
- ``loadgen``: synthetic open-loop request generator (Poisson arrivals)
  and the replay driver the ``--bench serve`` sweep runs on.
"""
from repro.serve.bridge import load_serve_params
from repro.serve.engine import Completion, Engine, Request
from repro.serve.loadgen import LoadSpec, generate_requests, replay

__all__ = ["Completion", "Engine", "LoadSpec", "Request",
           "generate_requests", "load_serve_params", "replay"]
