"""Checkpoint -> serve-params bridge (docs/serving.md).

``launch/train.py`` checkpoints carry a whole training state — a client
bank (or plain client states), server state, and mode-specific extras — in
one of several tuple layouts. Serving needs only the trained global model
(x̄, ȳ). This module reconstructs candidate abstract templates from the
requested ``ArchConfig``, matches the stored treedef/shapes against them
via :func:`repro.checkpoint.load_checkpoint` (which validates every leaf
and raises ``ValueError`` naming the mismatched leaf path — the PR 4
convention), and returns the client-mean ``{"x": x̄, "y": ȳ}`` params the
serve engine consumes. Every sync engine broadcasts the aggregate back to
the bank each round, so the rows agree at checkpoint time and the mean is
the canonical global model.

Dense and ``--ckpt-shards K`` layouts both load (``load_checkpoint``
reassembles shards transparently).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.checkpoint import load_checkpoint
from repro.configs.base import ArchConfig, FedConfig, ShapeConfig

ADAPTIVE_VARIANTS = ("adam", "none", "adabelief")


def _abstractify(tree):
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(jnp.shape(a), a.dtype), tree)


def _candidate_templates(cfg: ArchConfig, n: int, codec: str,
                         codec_bits: int, topk_frac: float):
    """(name, template) pairs for every checkpoint layout train.py writes,
    at population/client count ``n``. The structures come from
    FederatedTrainer itself (single source of truth), enumerated over the
    server's adaptive variants and — when ``codec`` is lossy — the EF-bank
    layouts."""
    from repro.fed.runtime import FederatedTrainer
    shape = ShapeConfig("bridge", 8, 1, "train")
    out = []
    for adaptive in ADAPTIVE_VARIANTS:
        fed = FedConfig(adaptive=adaptive, codec=codec,
                        codec_bits=codec_bits, topk_frac=topk_frac,
                        error_feedback=codec != "none")
        tr = FederatedTrainer(cfg, fed, shape, mesh=None)
        bank = tr.abstract_population_states(n)
        server = tr.abstract_server_state()
        last_sync = jax.ShapeDtypeStruct((n,), jnp.int32)
        ef = tr.init_ef_bank(n) if tr.codec.lossy else None
        ef = _abstractify(ef) if ef is not None else None
        tag = f"adaptive={adaptive}"
        out.append((f"population[{tag}]", (bank, last_sync, server)))
        srv_bank = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), server)
        out.append((f"gossip[{tag}]", (bank, srv_bank)))
        out.append((f"plain[{tag}]", (bank, server)))
        if ef is not None:
            out.append((f"population+ef[{tag}]", (bank, last_sync, ef,
                                                  server)))
            out.append((f"gossip+ef[{tag}]", (bank, srv_bank, ef)))
            out.append((f"plain+ef[{tag}]", (bank, server, ef)))
    return out


def _tree_mean_axis0(tree):
    return jax.tree.map(lambda a: jnp.mean(a, axis=0), tree)


def load_serve_params(path, cfg: ArchConfig, *, codec: str = "none",
                      codec_bits: int = 8, topk_frac: float = 0.05,
                      ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Load a ``launch/train.py`` checkpoint and extract serve params.

    Returns ``(params, info)`` where ``params = {"x": x̄, "y": ȳ}`` matches
    ``model_specs(cfg)`` (the engine's expected pytree) and ``info`` names
    the matched layout, the client count, and the training step. A
    checkpoint whose leaf shapes don't fit ``cfg`` raises ``ValueError``
    naming the mismatched leaf path; a checkpoint whose structure matches
    no known layout raises ``ValueError`` listing the candidates tried.
    ``codec`` must name the training run's codec for lossy (EF-bank)
    checkpoints — lossless checkpoints carry no EF bank and load with the
    default.
    """
    meta_path = Path(str(path) + ".json")
    if not meta_path.is_file():
        raise ValueError(f"checkpoint {path}: no {meta_path.name} sidecar "
                         f"(is this a launch/train.py checkpoint?)")
    meta = json.loads(meta_path.read_text())
    leaf0 = meta.get("shapes", {}).get("leaf_0")
    if not leaf0:
        raise ValueError(f"checkpoint {path}: sidecar records no leaf "
                         f"shapes — cannot infer the client count")
    # every layout leads with the client bank; its first leaf's leading
    # axis is the population / client count
    n = int(leaf0[0])
    treedef = meta.get("treedef")
    candidates = _candidate_templates(cfg, n, codec, codec_bits, topk_frac)
    errors = []
    # first pass: exact treedef match (distinguishes e.g. plain from gossip
    # only by leaf shapes, so several candidates may match — the loader's
    # shape validation picks the right one); second pass: leaf-count match,
    # so a structurally different arch still surfaces the loader's
    # leaf-path ValueError (PR 4 convention) instead of a generic miss
    passes = ([(name, t) for name, t in candidates
               if treedef is None or str(jax.tree.structure(t)) == treedef],
              [(name, t) for name, t in candidates
               if len(jax.tree.leaves(t)) == meta.get("n_leaves")])
    for cands in passes:
        for name, tmpl in cands:
            try:
                state, step = load_checkpoint(path, tmpl)
            except ValueError as e:
                errors.append((name, e))
                continue
            bank = state[0] if isinstance(state, tuple) else state
            avg = _tree_mean_axis0(bank)
            params = {"x": avg["x"], "y": avg["y"]}
            return params, {"layout": name, "clients": n, "step": step}
        if errors:
            # a candidate's structure fit but a leaf didn't — surface the
            # loader's leaf-path ValueError (arch mismatch)
            raise errors[0][1]
    raise ValueError(
        f"checkpoint {path}: structure matches no known launch/train.py "
        f"layout (tried {', '.join(name for name, _ in candidates)}); "
        f"async-engine checkpoints are not servable — rerun training with "
        f"a sync engine or pass the matching --codec for EF-bank layouts")
