"""Continuous-batching serve engine (docs/serving.md).

A fixed pool of B slots shares ONE jitted ``decode_step`` per tick with a
per-slot position vector. New requests prefill at batch 1, their cache row
scatters into the pool, and retired slots (EOS / token budget / cache
capacity) refill on the next tick — no head-of-line blocking on the longest
sequence. The scheduler changes throughput, never results: every cache leaf
carries the batch axis at position 1 and the decode path is bitwise
row-independent, so a request's tokens are identical whether it shared the
pool or ran alone (pinned in tests/test_serve_engine.py).

``kv_quant=True`` switches the pool to the int8 cache layout: prefill stays
full-precision (a direct int8 cast would be garbage), the row is quantized
per (token, head) on the way into the pool, and decode attends through
either the XLA reference dequant or the fused Pallas kernel
(``kv_kernel="pallas"``; ``"interpret"`` runs the same kernel on CPU).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.fed.serve import build_serve_fns
from repro.obs.telemetry import NULL

QUANT_FAMILIES = ("dense", "vlm", "moe", "encdec")
KV_KERNELS = ("auto", "xla", "pallas", "interpret")


@dataclasses.dataclass
class Request:
    """One generation request. ``tokens``: [plen] int32 prompt.

    ``enc_embeds`` ([enc_len, d], encdec archs — enc_len must equal the
    engine's ``max_len``) and ``prefix_embeds`` ([n_prefix, d], VLM archs)
    ride along when the architecture needs them. ``arrival_s`` is the
    open-loop arrival offset stamped by the load generator."""
    rid: int
    tokens: np.ndarray
    max_new_tokens: int = 32
    arrival_s: float = 0.0
    enc_embeds: Optional[np.ndarray] = None
    prefix_embeds: Optional[np.ndarray] = None


@dataclasses.dataclass
class Completion:
    """A drained request: generated ``tokens`` (prompt excluded; EOS, when
    hit, included) plus scheduling timestamps in engine-clock seconds."""
    rid: int
    prompt_len: int
    tokens: List[int]
    finish_reason: str            # eos | length | capacity
    arrival_s: float
    admitted_s: float
    finished_s: float
    decode_ticks: int

    @property
    def latency_s(self) -> float:
        return self.finished_s - self.arrival_s


class Engine:
    """Continuous-batching greedy-decode engine over ``build_serve_fns``.

    ``submit()`` queues requests; ``step()`` runs one scheduler tick
    (admissions + one shared decode) and returns the requests that finished;
    ``run()`` drains the queue. Decoding is greedy argmax — the scheduler
    must be bit-reproducible, so sampling lives with the caller.
    """

    def __init__(self, cfg: ArchConfig, params, *, slots: int = 8,
                 max_len: int = 256, kv_quant: bool = False,
                 kv_kernel: str = "auto", mesh=None,
                 eos_id: Optional[int] = None, telemetry=NULL):
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        if kv_kernel not in KV_KERNELS:
            raise ValueError(f"kv_kernel must be one of {KV_KERNELS}, got "
                             f"{kv_kernel!r}")
        if kv_quant and cfg.family not in QUANT_FAMILIES:
            raise ValueError(
                f"kv_quant=True needs an attention KV cache; family "
                f"{cfg.family!r} keeps {'SSM state' if cfg.family == 'ssm' else 'hybrid state'} "
                f"(supported: {', '.join(QUANT_FAMILIES)})")
        if kv_kernel == "auto":
            kv_kernel = "pallas" if jax.default_backend() == "tpu" else "xla"
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.kv_quant = kv_quant
        self.kv_kernel = kv_kernel
        self.eos_id = eos_id
        self.tele = telemetry

        dec_shape = ShapeConfig("serve_decode", max_len, slots, "decode")
        pre_shape = ShapeConfig("serve_prefill", max_len, 1, "prefill")
        self._dec = build_serve_fns(cfg, dec_shape, mesh, kv_quant=kv_quant,
                                    kv_kernel=kv_kernel)
        self._pre = build_serve_fns(cfg, pre_shape, mesh)
        self._decode = self._dec["decode"]
        self._prefill = self._pre["prefill"]
        self._pool = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                  self._dec["cache_abs"])
        if mesh is not None and "cache_shardings" in self._dec:
            self._pool = jax.device_put(self._pool,
                                        self._dec["cache_shardings"])
        self._zero_row = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                      self._pre["cache_abs"])
        self._argmax = jax.jit(
            lambda lg: jnp.argmax(lg[:, 0, :], axis=-1).astype(jnp.int32))
        self._scatter = jax.jit(self._scatter_row)
        self._quantize = jax.jit(self._quantize_row) if kv_quant else None

        # host-side slot state
        self._queue: Deque[Request] = deque()
        self._occupant: List[Optional[Request]] = [None] * slots
        self._free: List[int] = list(range(slots))[::-1]   # pop() -> slot 0 first
        self._pos = np.zeros(slots, np.int32)
        self._last_tok = np.zeros(slots, np.int32)
        self._budget = np.zeros(slots, np.int32)
        self._out: Dict[int, List[int]] = {}
        self._admitted_s: Dict[int, float] = {}
        self._admit_tick: Dict[int, int] = {}
        self._ticks = 0
        self._t0 = time.perf_counter()

    # ------------------------------------------------------------ clock

    def start_clock(self) -> None:
        """Reset the engine clock (latencies are measured from here)."""
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    # ------------------------------------------------------------ pool ops

    @staticmethod
    def _scatter_row(pool, row, slot):
        """Write a prefilled B=1 cache row into pool slot ``slot`` — every
        leaf carries batch at axis 1, so one tree_map covers all families."""
        return jax.tree.map(
            lambda p, r: jax.lax.dynamic_update_slice_in_dim(
                p, r.astype(p.dtype), slot, axis=1), pool, row)

    def _quantize_row(self, row):
        """Full-precision prefill cache -> int8 pool layout (k/v quantized
        per (token, head); encdec cross ck/cv stay dense)."""
        from repro.kernels.quant_decode import quantize_kv
        out = dict(row)
        out["k"], out["k_scale"] = quantize_kv(row["k"])
        out["v"], out["v_scale"] = quantize_kv(row["v"])
        return out

    # ------------------------------------------------------------ intake

    def submit(self, req: Request) -> None:
        plen = int(np.shape(req.tokens)[-1])
        if plen < 1:
            raise ValueError(f"request {req.rid}: empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.rid}: max_new_tokens must be "
                             f">= 1, got {req.max_new_tokens}")
        if plen >= self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt_len {plen} must be < the cache "
                f"capacity max_len={self.max_len} (the generation budget is "
                f"truncated at capacity, the prompt is not)")
        self._queue.append(req)

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def active(self) -> int:
        return self.slots - len(self._free)

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or self.active > 0

    # ------------------------------------------------------------ scheduler

    def _admit(self, req: Request, slot: int,
               completed: List[Completion]) -> None:
        batch = {"tokens": jnp.asarray(np.asarray(req.tokens, np.int32)[None])}
        if "prefix_embeds" in self._pre["batch_specs"]:
            pe = req.prefix_embeds
            if pe is None:
                pe = np.zeros((self.cfg.n_prefix_embeds, self.cfg.d_model),
                              np.float32)
            batch["prefix_embeds"] = jnp.asarray(pe[None]).astype(
                self._pre["batch_specs"]["prefix_embeds"].dtype)
        if "enc_embeds" in self._pre["batch_specs"]:
            if req.enc_embeds is None:
                raise ValueError(f"request {req.rid}: encoder-decoder arch "
                                 f"needs enc_embeds [{self.max_len}, d]")
            batch["enc_embeds"] = jnp.asarray(req.enc_embeds[None]).astype(
                self._pre["batch_specs"]["enc_embeds"].dtype)
        with self.tele.span("serve.prefill"):
            logits, row = self._prefill(self.params, batch, self._zero_row)
        if self._quantize is not None:
            row = self._quantize(row)
        self._pool = self._scatter(self._pool, row, jnp.int32(slot))
        first = int(jax.device_get(self._argmax(logits))[0])
        plen = int(np.shape(req.tokens)[-1])
        now = self.now()
        self._occupant[slot] = req
        self._pos[slot] = plen
        self._last_tok[slot] = first
        self._budget[slot] = req.max_new_tokens - 1
        self._out[req.rid] = [first]
        self._admitted_s[req.rid] = now
        self._admit_tick[req.rid] = self._ticks
        if (self.eos_id is not None and first == self.eos_id):
            self._retire(slot, "eos", completed)
        elif req.max_new_tokens == 1:
            self._retire(slot, "length", completed)

    def _retire(self, slot: int, reason: str,
                completed: List[Completion]) -> None:
        req = self._occupant[slot]
        now = self.now()
        comp = Completion(
            rid=req.rid, prompt_len=int(np.shape(req.tokens)[-1]),
            tokens=self._out.pop(req.rid), finish_reason=reason,
            arrival_s=req.arrival_s,
            admitted_s=self._admitted_s.pop(req.rid), finished_s=now,
            decode_ticks=self._ticks - self._admit_tick.pop(req.rid))
        completed.append(comp)
        self.tele.request(
            rid=comp.rid, prompt_len=comp.prompt_len,
            new_tokens=len(comp.tokens), finish_reason=reason,
            latency_s=round(comp.latency_s, 6),
            queue_s=round(comp.admitted_s - comp.arrival_s, 6),
            decode_ticks=comp.decode_ticks)
        self._occupant[slot] = None
        self._free.append(slot)

    def step(self) -> List[Completion]:
        """One scheduler tick: admit into free slots, then ONE shared decode
        over every active slot. Returns the requests that completed."""
        completed: List[Completion] = []
        admitted = 0
        while self._queue and self._free:
            self._admit(self._queue.popleft(), self._free.pop(), completed)
            admitted += 1
        active = [s for s in range(self.slots)
                  if self._occupant[s] is not None]
        if active:
            with self.tele.span("serve.decode"):
                logits, self._pool = self._decode(
                    self.params, self._pool,
                    jnp.asarray(self._last_tok[:, None]),
                    jnp.asarray(np.maximum(self._pos, 1)))
                nxt = np.asarray(jax.device_get(self._argmax(logits)))
            for s in active:
                tok = int(nxt[s])
                self._out[self._occupant[s].rid].append(tok)
                self._pos[s] += 1
                self._last_tok[s] = tok
                self._budget[s] -= 1
                if self.eos_id is not None and tok == self.eos_id:
                    self._retire(s, "eos", completed)
                elif self._budget[s] <= 0:
                    self._retire(s, "length", completed)
                elif self._pos[s] >= self.max_len:
                    self._retire(s, "capacity", completed)
        self._ticks += 1
        self.tele.tick(self._ticks - 1, active=len(active), admitted=admitted,
                       completed=len(completed), queue_depth=len(self._queue))
        return completed

    def run(self, requests=None) -> List[Completion]:
        """Drain: submit ``requests`` (if given) and tick until idle."""
        for r in requests or ():
            self.submit(r)
        done: List[Completion] = []
        while self.has_work:
            done.extend(self.step())
        return done
