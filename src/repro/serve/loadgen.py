"""Synthetic open-loop load generation for the serve engine
(docs/serving.md).

Requests arrive on a Poisson process (exponential inter-arrival gaps at
``rate`` req/s), prompts draw from a discrete length-bucket distribution
(discrete so the per-length prefill programs compile once per bucket, not
per request), and generation budgets draw from a clipped geometric.
``replay`` drives an engine open-loop against the wall clock: a request
enters the queue at its arrival time whether or not the engine has kept
up, so overload shows up as queue growth and latency blow-up — the
property closed-loop replay hides. ``rate=0`` degenerates to
all-at-once submission (the max-throughput measurement the ``--bench
serve`` sweep uses).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.engine import Completion, Engine, Request


@dataclasses.dataclass(frozen=True)
class LoadSpec:
    """Open-loop workload: ``n_requests`` at ``rate`` req/s (0 = all at
    t=0), prompt lengths drawn from ``prompt_lens`` with optional
    ``prompt_weights``, output budgets ~ min(1 + Geom(1/mean_new_tokens),
    ``max_new_cap``)."""
    n_requests: int = 32
    rate: float = 0.0
    prompt_lens: Tuple[int, ...] = (8, 16, 32)
    prompt_weights: Optional[Tuple[float, ...]] = None
    mean_new_tokens: float = 16.0
    max_new_cap: int = 64
    seed: int = 0


def generate_requests(spec: LoadSpec, vocab: int, *,
                      enc_shape: Optional[Tuple[int, int]] = None,
                      prefix_shape: Optional[Tuple[int, int]] = None,
                      ) -> List[Request]:
    """Materialize the workload: token prompts over ``vocab``, arrival
    offsets, budgets. ``enc_shape``/``prefix_shape`` ([len, d_model]) add
    random encoder/prefix embeddings for encdec/VLM archs."""
    rng = np.random.default_rng(spec.seed)
    gaps = (rng.exponential(1.0 / spec.rate, spec.n_requests)
            if spec.rate > 0 else np.zeros(spec.n_requests))
    arrivals = np.cumsum(gaps)
    weights = spec.prompt_weights
    if weights is not None:
        weights = np.asarray(weights, np.float64)
        weights = weights / weights.sum()
    lens = rng.choice(np.asarray(spec.prompt_lens), size=spec.n_requests,
                      p=weights)
    mean = max(spec.mean_new_tokens, 1.0)
    budgets = np.minimum(1 + rng.geometric(1.0 / mean, spec.n_requests),
                         spec.max_new_cap)
    reqs = []
    for i in range(spec.n_requests):
        extras = {}
        if enc_shape is not None:
            extras["enc_embeds"] = rng.standard_normal(
                enc_shape).astype(np.float32)
        if prefix_shape is not None:
            extras["prefix_embeds"] = rng.standard_normal(
                prefix_shape).astype(np.float32)
        reqs.append(Request(
            rid=i,
            tokens=rng.integers(0, vocab, int(lens[i])).astype(np.int32),
            max_new_tokens=int(budgets[i]),
            arrival_s=float(arrivals[i]),
            **extras))
    return reqs


def replay(engine: Engine, requests: Sequence[Request],
           ) -> List[Completion]:
    """Open-loop replay: submit each request when the engine clock reaches
    its ``arrival_s``, tick whenever there is admitted work, drain fully.
    Returns completions (engine-clock timestamps; latency_s measures
    arrival -> finish)."""
    pending = sorted(requests, key=lambda r: r.arrival_s)
    i = 0
    done: List[Completion] = []
    engine.start_clock()
    while i < len(pending) or engine.has_work:
        now = engine.now()
        while i < len(pending) and pending[i].arrival_s <= now:
            engine.submit(pending[i])
            i += 1
        if engine.has_work:
            done.extend(engine.step())
        elif i < len(pending):
            time.sleep(min(pending[i].arrival_s - engine.now(), 0.01))
    return done
