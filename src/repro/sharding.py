"""Logical-axis -> mesh-axis rules and sharding helpers.

Logical axes used by the models:
  vocab, embed, mlp, heads, kv_heads, head_dim, experts, expert_mlp,
  ssm_inner, ssm_state, conv, layers
Activation axes:
  clients, batch, seq, act_embed, act_heads, cache_seq

Modes:
  train (fed_mode replica|zero), prefill, decode.

Replica-train: each client is one ``data`` row (x16 ``model`` chips); client state
carries a leading ``clients`` axis sharded over ("pod","data"). Zero-train: client =
pod; params additionally FSDP-sharded over ``data`` via the ``embed`` rule.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]
Rules = Dict[str, Axis]


def _mesh_axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _fits(mesh: Mesh, axis: Axis, dim: int) -> bool:
    if axis is None:
        return True
    names = (axis,) if isinstance(axis, str) else axis
    total = 1
    for n in names:
        total *= _mesh_axis_size(mesh, n)
    return dim % total == 0 and dim >= total


def client_axes(mesh: Mesh, fed_mode: str) -> Tuple[str, ...]:
    names = mesh.axis_names
    if fed_mode == "zero":
        return ("pod",) if "pod" in names else ()
    return tuple(n for n in ("pod", "data") if n in names)


def n_clients(mesh: Mesh, fed_mode: str) -> int:
    m = 1
    for a in client_axes(mesh, fed_mode):
        m *= _mesh_axis_size(mesh, a)
    return max(m, 1)


def bank_spec(mesh: Mesh, fed_mode: str, shape: Tuple[int, ...]) -> P:
    """PartitionSpec of one population-bank leaf ([N, ...] state rows, [N]
    bookkeeping vectors): the leading population axis partitions over the
    client mesh axes when N divides their product, else the leaf replicates.
    Only the leading axis is assigned here — trailing model axes come from
    the logical-axis rules (``repro.fed.runtime.FederatedTrainer.
    population_state_shardings``); this bare form serves callers without a
    logical-axes tree (``FedDriver``, the bank-scale bench)."""
    axes = client_axes(mesh, fed_mode)
    if axes and _fits(mesh, axes, shape[0]):
        return P(axes[0] if len(axes) == 1 else axes)
    return P()


def bank_shardings(mesh: Mesh, tree, fed_mode: str = "replica"):
    """NamedSharding pytree partitioning every leaf's leading population
    axis over the client mesh axes (:func:`bank_spec` per leaf). ``tree``
    leaves are arrays or ShapeDtypeStructs with leading axis N."""
    return jax.tree.map(
        lambda a: NamedSharding(mesh, bank_spec(mesh, fed_mode,
                                                tuple(a.shape))), tree)


def _sizes_of(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def train_rules(cfg, mesh: Mesh) -> Rules:
    zero = cfg.fed_mode == "zero"
    r: Rules = {
        "_sizes": _sizes_of(mesh),
        "clients": client_axes(mesh, cfg.fed_mode) or None,
        "vocab": "model",
        "vocab_in": None,
        "embed": "data" if zero else None,
        "mlp": "model",
        "heads": "model",
        "kv_heads": None,
        "head_dim": None,
        "experts": "model",
        "expert_mlp": "data" if zero else None,
        "ssm_inner": "model",
        "ssm_state": None,
        "conv": None,
        "layers": None,
        # activations (per-client view: no client dim here)
        "batch": "data" if zero else None,
        "seq": "model",
        "act_embed": None,
        "cache_seq": None,
    }
    return r


def prefill_rules(cfg, mesh: Mesh) -> Rules:
    zero = cfg.fed_mode == "zero"
    return {
        "_sizes": _sizes_of(mesh),
        "clients": None,
        "vocab": "model",
        "vocab_in": None,
        # huge archs FSDP their weights over `data` for prefill too (per-layer
        # all-gathers overlap with the large per-layer compute)
        "embed": "data" if zero else None,
        "mlp": "model",
        "heads": "model",
        "kv_heads": None,
        "head_dim": None,
        "experts": "model",
        # prefill keeps expert FFN weights 1-D sharded (experts over `model`):
        # 2-D (data) sharding makes the expert dots all-reduce [E,C,d]-sized
        # f32 activation partials over `data` EVERY layer (measured 5.1 GiB
        # wire/layer); gathering the ~1.3 GiB/layer weights is far cheaper.
        "expert_mlp": None,
        "ssm_inner": "model",
        "ssm_state": None,
        "conv": None,
        "layers": None,
        "batch": tuple(n for n in ("pod", "data") if n in mesh.axis_names),
        # 32k-token prompts: flash-score blocks scale with the local Sq, so
        # activations are sequence-sharded over `model` (weights win their own
        # model sharding per-tensor; GSPMD gathers the cheaper operand).
        "seq": "model",
        "act_embed": None,
        "cache_seq": "model",
    }


def decode_rules(cfg, mesh: Mesh) -> Rules:
    zero = cfg.fed_mode == "zero"
    return {
        "_sizes": _sizes_of(mesh),
        "clients": None,
        "vocab": "model",
        "vocab_in": None,
        # huge archs: 2D-shard weights (embed over data) so weights+cache fit;
        # GSPMD inserts activation reductions (cheap at 1 token/step).
        "embed": "data" if zero else None,
        "mlp": "model",
        "heads": "model",
        "kv_heads": None,
        # KV cache shards along head_dim (128 on every assigned arch), NOT
        # along the sequence: the per-token dynamic-update-slice then touches
        # only unsharded dims (a seq-sharded cache makes GSPMD gather the
        # whole cache per written token). Attention contracts head_dim ->
        # small psum over `model` per layer.
        "head_dim": "model",
        "experts": "model",
        "expert_mlp": "data" if zero else None,
        "ssm_inner": "model",
        "ssm_state": None,
        "conv": None,
        "layers": None,
        "batch": tuple(n for n in ("pod", "data") if n in mesh.axis_names),
        "seq": None,
        "cache_seq": None,
    }


def rules_for(cfg, mesh: Mesh, kind: str) -> Rules:
    if kind == "train":
        return train_rules(cfg, mesh)
    if kind == "prefill":
        return prefill_rules(cfg, mesh)
    if kind == "decode":
        return decode_rules(cfg, mesh)
    raise ValueError(kind)


def spec_for_axes(axes: Tuple[Optional[str], ...], rules: Rules,
                  mesh: Optional[Mesh] = None,
                  shape: Optional[Tuple[int, ...]] = None,
                  fallback: Tuple[str, ...] = ()) -> P:
    """PartitionSpec from logical axes; drops assignments that don't divide.

    ``fallback``: mesh axes to place on the largest still-unassigned divisible
    dim when the rule-based pass left them unused (weights whose natural axis
    doesn't divide — e.g. 40 heads on a 16-way model axis — get row/column
    parallelism instead of replication). Requires ``shape`` and axis sizes
    (either a real ``mesh`` or a ``_sizes`` entry in ``rules``).
    """
    sizes = dict(rules.get("_sizes", {}))
    if mesh is not None:
        sizes.update(dict(zip(mesh.axis_names, mesh.devices.shape)))

    def fits(names, dim):
        total = 1
        for n in names:
            total *= sizes.get(n, 1)
        return dim % total == 0 and dim >= total

    out = []
    used = set()
    for i, a in enumerate(axes):
        m = rules.get(a) if a is not None else None
        if m is not None:
            names = (m,) if isinstance(m, str) else tuple(m)
            if any(n in used for n in names):
                m = None
            elif shape is not None and sizes and not fits(names, shape[i]):
                m = None
            else:
                used.update(names)
                out.append(names[0] if len(names) == 1 else names)
                continue
        out.append(None)
    if shape is not None and sizes:
        big_enough = 1
        for d in shape:
            big_enough *= d
        if big_enough >= (1 << 20):
            for fb in fallback:
                if fb in used or sizes.get(fb, 1) <= 1:
                    continue
                # vocab_in is deliberately unsharded (embedding gathers must
                # stay collective-free) — never a fallback target.
                cands = [i for i in range(len(axes))
                         if out[i] is None and axes[i] != "vocab_in"
                         and fits((fb,), shape[i])]
                if cands:
                    i = max(cands, key=lambda j: shape[j])
                    out[i] = fb
                    used.add(fb)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_shardings(axes_pytree, rules: Rules, mesh: Mesh, shapes_pytree=None,
                   fallback: Tuple[str, ...] = ()):
    """NamedSharding pytree from a logical-axes pytree (+ optional shapes)."""
    def is_axes(x):
        return isinstance(x, tuple) and all(y is None or isinstance(y, str) for y in x)
    if shapes_pytree is None:
        return jax.tree.map(
            lambda ax: NamedSharding(mesh, spec_for_axes(ax, rules, mesh)),
            axes_pytree, is_leaf=is_axes)
    return jax.tree.map(
        lambda ax, sh: NamedSharding(
            mesh, spec_for_axes(ax, rules, mesh, tuple(sh.shape), fallback)),
        axes_pytree, shapes_pytree, is_leaf=is_axes)


def shard_act(x: jax.Array, axes: Tuple[Optional[str], ...], rules: Optional[Rules],
              fallback: Tuple[str, ...] = ()) -> jax.Array:
    """with_sharding_constraint via logical axes (bare PartitionSpec, so it is
    vmap(spmd_axis_name)-safe); no-op when rules is None."""
    if rules is None:
        return x
    spec = spec_for_axes(axes, rules, None, tuple(x.shape), fallback)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x
