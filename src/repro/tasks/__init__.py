from repro.tasks.driver import FedDriver, RunResult
from repro.tasks.hyperclean import build_hyperclean
from repro.tasks.hyperrep import build_hyperrep

__all__ = ["FedDriver", "RunResult", "build_hyperclean", "build_hyperrep"]
