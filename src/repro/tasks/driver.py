"""Small-scale federated experiment driver: the host-side loop that owns
run orchestration for the paper's experiments.

What this module owns: the ``FedDriver`` run loop (batch building, round
scheduling, metric/wall-clock recording in ``RunResult``) for M simulated
clients on a single host, algorithm-agnostic via the ``Algorithm`` contract
(``repro.core.baselines``), so AdaFBiO (Algorithm 1) and every Table-1
baseline run identically. How it composes with its neighbours: per-step math
comes from ``repro.core`` (``alg.local_step`` implements lines 10-20 /
Eq. 14, ``alg.sync_update`` lines 4-9 of Algorithm 1); fused round programs
come from ``repro.fed.round`` (scan engine) and ``repro.fed.population``
(cohort rounds, async rounds); cohort policies from ``repro.fed.sampling``.
The mesh-sharded LM counterpart of this driver is
``repro.fed.runtime.FederatedTrainer`` — same round shapes, sharded states.

Three participation regimes:

  * masked (seed behaviour, ``participation`` < 1): ALL M clients compute
    every step, inactive ones are masked — O(M) compute regardless of the
    participation fraction, M capped by what one vmap/jit fits;
  * population (``population=PopulationConfig(n, cohort)``): N client states
    persist in a bank (repro.fed.population), a CohortSampler picks C ids
    per round, and only those C are computed (gather → fused scan round →
    scatter) — O(C) compute at any population scale;
  * async population (``population.max_staleness != 0``): overlapping
    cohorts with delayed arrivals, server-side bounded-staleness gating and
    delay-adaptive eta_t (docs/async.md); per-round arrival statistics land
    in ``FedDriver.staleness_log`` / ``staleness_hist``.

Tracks the paper's cost metrics exactly: #samples consumed (q(K+2) at init,
K+2 per local step; async scales each round's increment by the fraction of
cohort slots that actually dispatched — masked in-flight slots discard
their compute and must not count), #communication rounds (1 per sync;
async counts the rounds in which an aggregation actually happened), and —
new with the compression subsystem (``repro.fed.compress``) — the BYTES on
the wire: ``bytes_up`` accrues one codec-priced message per transmitting
client at each aggregation (async: per arrival, dropped arrivals included
— they were shipped before the gate rejected them), ``bytes_down`` one
full-precision client state per receiver of each server push (broadcast:
everyone; participants: the cohort; async: the ``synced`` rows). The
per-codec formulas are ``Codec.message_bytes`` / ``state_bytes``
(docs/compression.md). The pricing itself lives behind the sync layer's
``Aggregator.wire_round`` (``repro.fed.topology``): the four star engines
share the tx-uplinks + rx-downlinks convention above, while the fifth,
decentralized ``engine='gossip'`` prices per directed graph edge — peer
exchanges are codec-priced in BOTH directions with no full-precision
broadcast (docs/topology.md)."""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig, PopulationConfig
from repro.core.baselines import Algorithm, make_algorithm
from repro.core.bilevel import BilevelProblem
from repro.core.tree_util import (tree_bcast_axis0, tree_mean_axis0,
                                  tree_stack)


@dataclasses.dataclass
class RunResult:
    name: str
    steps: List[int]
    samples: List[int]
    comms: List[int]
    metric: List[float]            # task metric (val loss / grad norm)
    grad_norm: List[float]
    seconds: float
    final_avg_state: Any = None    # averaged client state at the last step
    # wall-clock of the first, compile-including round; steady-state rounds
    # land in FedDriver.round_seconds so eager-vs-scan comparisons aren't
    # skewed by compile time
    compile_seconds: float = 0.0
    # cumulative wire bytes at each recorded step (repro.fed.compress):
    # uplink = codec-priced client→server messages, downlink = full-
    # precision server→client pushes
    bytes_up: List[int] = dataclasses.field(default_factory=list)
    bytes_down: List[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class FedDriver:
    problem: BilevelProblem
    fed: FedConfig
    n_clients: int
    batch_fn: Callable[[int, int], Dict[str, Any]]   # (client, step) -> batches
    init_xy: Callable[[jax.Array], Any]              # key -> (xp, yp)
    metric_fn: Optional[Callable[..., float]] = None  # (x̄, ȳ) -> scalar
    grad_norm_fn: Optional[Callable[..., float]] = None
    algorithm: str = "adafbio"
    # partial participation, masked path (thin alias for a uniform sampler):
    # fraction of clients active per round; inactive clients hold state and
    # are excluded from the average — but still COMPUTE (and are masked).
    # Prefer `population=` for anything beyond vmap scale.
    participation: float = 1.0
    # population mode: persistent bank of population.n client states, only
    # population.cohort of them computed per round (repro.fed.population).
    population: Optional[PopulationConfig] = None
    # cohort-selection policy; None derives population.sampler (or a uniform
    # sampler for the masked path) from the run key at run() time.
    sampler: Optional[Any] = None
    track_consensus: bool = False
    # "eager": one jitted call per local step (seed behaviour).
    # "scan":  the fused round engine — q local steps + sync compiled as ONE
    #          program per communication round (repro.fed.round).
    # "gossip": the decentralized engine — no server; the sync is a mixing-
    #          matrix step over population.topology's graph and every node
    #          keeps its own server state (repro.fed.topology). Requires
    #          population= with cohort == n (full participation).
    engine: str = "eager"
    # optional device mesh for the population/async engines: the bank, EF
    # residuals, pending buffer and [N] bookkeeping vectors partition their
    # leading population axis over the mesh's client axes (pod/data), so
    # per-device bank bytes scale as N/devices (docs/sharding.md). The
    # masked eager/scan engines ignore it (they are vmap-scale by design).
    mesh: Optional[Any] = None
    # mega-scan tier (docs/megascan.md): compile R full rounds into ONE
    # donated-carry program and loop over ⌈rounds/R⌉ chunks, draining
    # stats/telemetry once per chunk. R=1 keeps the per-round loops; the
    # eager engine ignores it (it is the per-step reference by design).
    rounds_per_scan: int = 1
    # optional repro.obs.Telemetry bus: per-round records, on-device stat
    # accumulation (drained every telemetry.metrics_every rounds), phase
    # spans. Strictly observational — attaching it never changes the round
    # programs, so trajectories stay bit-identical (tests/test_obs.py).
    telemetry: Optional[Any] = None

    def __post_init__(self):
        from repro.fed.round import ENGINES
        if self.engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}, "
                             f"got {self.engine!r}")
        if self.rounds_per_scan < 1:
            raise ValueError(f"rounds_per_scan must be >= 1, "
                             f"got {self.rounds_per_scan}")
        self.alg: Algorithm = make_algorithm(self.algorithm, self.fed,
                                             self.problem)
        self.consensus_log = []
        # steady-state per-round wall-clock; the first (compile-including)
        # round is reported separately as RunResult.compile_seconds
        self.round_seconds: List[float] = []

    @property
    def codec(self):
        """The update codec the run's FedConfig describes — derived from
        ``alg.fed`` on demand (benchmarks reassign fed/alg after
        construction, so a cached copy could go stale)."""
        from repro.fed.compress import codec_from_config
        return codec_from_config(self.alg.fed)

    def _batches(self, step: int):
        per_client = [self.batch_fn(m, step) for m in range(self.n_clients)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per_client)

    # -------------------------------------------------- shared pieces

    def _init_run(self, key):
        m = self.n_clients
        fed = self.alg.fed
        xp, yp = self.init_xy(key)
        batches0 = self._batches(0)

        def init_one(k, b):
            return self.alg.init_client_state(xp, yp, b, k)
        states = jax.vmap(init_one)(jax.random.split(key, m), batches0)
        server = self.alg.init_server_state(xp)
        if fed.adaptive != "none":
            from repro.core.adafbio import warm_adaptive
            server = warm_adaptive(server, tree_mean_axis0(states), fed)
        return states, server

    def _local_body(self, states, server, batches, key, active):
        m = self.n_clients
        t = server["t"]
        def one(st, b, i):
            kk = jax.random.fold_in(jax.random.fold_in(key, i), t)
            return self.alg.local_step(st, server["adaptive"], b, kk, t, m)
        new = jax.vmap(one)(states, batches, jnp.arange(m))
        # partial participation: inactive clients hold their state
        new = jax.tree.map(
            lambda a, b_: jnp.where(
                active.reshape((m,) + (1,) * (a.ndim - 1)), a, b_),
            new, states)
        srv = dict(server)
        srv["t"] = t + 1
        return new, srv

    def _star_aggregator(self):
        """The star sync as an ``Aggregator`` (``repro.fed.topology``):
        every engine except gossip aggregates, codecs and prices its wire
        traffic through it. ``n_clients`` equals the population size in
        population mode (validated in ``_run_population``), so one helper
        serves all the star engines."""
        from repro.fed.topology import StarAggregator
        m = self.n_clients
        return StarAggregator(
            sync_update=lambda srv, avg: self.alg.sync_update(srv, avg, m),
            codec=self.codec)

    def _sync_body(self, states, server, active):
        m = self.n_clients
        w = active.astype(jnp.float32)
        w = w / jnp.maximum(w.sum(), 1.0)
        new_client, new_server = self._star_aggregator().reduce(
            server, states, weights=w)
        return tree_bcast_axis0(new_client, m), new_server

    def _sync_body_codec(self, states, server, active, ref, ef, key,
                         round_id):
        """The codec-aware sync of the eager/scan engines: client→server
        messages are priced against ``ref`` (the last broadcast — the
        server-known dispatch state, shared by every client), EF residuals
        hold for non-transmitting (inactive) clients, and the aggregation
        runs over the server-side reconstructions. Returns ``(states,
        server, ref, ef)`` with the fresh broadcast as the next ``ref``."""
        from repro.fed.compress import mask_rows
        recon, ef_new = self._star_aggregator().messages(
            key, round_id, jnp.arange(self.n_clients), ref, states, ef)
        if ef is not None:
            ef_new = mask_rows(active, ef_new, ef)
        new_states, new_server = self._sync_body(recon, server, active)
        return new_states, new_server, new_states, ef_new

    def _setup_sampler(self, key):
        """Resolve the run's CohortSampler from the run key (so different
        seeds draw different cohorts — the seed behaviour used a constant
        PRNGKey(23) for every run)."""
        from repro.fed.sampling import make_sampler
        if self.sampler is not None:
            self._run_sampler = self.sampler
            return
        skey = jax.random.fold_in(key, 23)
        m = self.n_clients
        if self.population is not None:
            p = self.population
            self._run_sampler = make_sampler(p.sampler, p.n, p.cohort, skey,
                                             period=p.trace_period,
                                             duty=p.trace_duty,
                                             trace_file=p.trace_file)
        elif self.participation < 1.0:
            c = max(int(self.participation * m), 1)
            self._run_sampler = make_sampler("uniform", m, c, skey)
        else:
            self._run_sampler = None

    def _active_mask(self, round_id):
        if getattr(self, "_run_sampler", None) is None:
            return jnp.ones((self.n_clients,), bool)
        return self._run_sampler.mask(round_id)

    def _record(self, res: RunResult, states, step, samples, comms,
                bytes_up: int = 0, bytes_down: int = 0):
        avg = tree_mean_axis0(states)
        res.steps.append(step)
        res.samples.append(samples)
        res.comms.append(comms)
        res.bytes_up.append(int(bytes_up))
        res.bytes_down.append(int(bytes_down))
        res.metric.append(float(self.metric_fn(avg["x"], avg["y"]))
                          if self.metric_fn else float("nan"))
        res.grad_norm.append(float(self.grad_norm_fn(avg["x"], avg["y"]))
                             if self.grad_norm_fn else float("nan"))

    def _wire_costs(self, states):
        """(per-message uplink bytes, per-receiver downlink bytes) for one
        client's state shape — ``states`` carries a leading client axis."""
        from repro.fed.compress import wire_costs
        return wire_costs(self.codec, states)

    # -------------------------------------------------- bank sharding

    def _bank_shardings(self, tree):
        """NamedSharding pytree partitioning each leaf's leading population
        axis over the mesh's client axes (``repro.sharding.bank_spec``);
        None without a mesh. Applies to the bank / pending / EF stacks and
        the [N] bookkeeping vectors alike."""
        if self.mesh is None:
            return None
        from jax.sharding import NamedSharding
        from repro import sharding as shlib
        return jax.tree.map(
            lambda a: NamedSharding(self.mesh, shlib.bank_spec(
                self.mesh, "replica", tuple(a.shape))), tree)

    def _replicated(self):
        from jax.sharding import NamedSharding, PartitionSpec
        return NamedSharding(self.mesh, PartitionSpec())

    def _async_state_shardings(self, state):
        """Shardings of the async-state dict: bank-shaped entries partition
        over the client axes, the anchor/server replicate."""
        if self.mesh is None:
            return None
        rep = self._replicated()
        sh = {}
        for k, v in state.items():
            if k in ("bank", "pending", "ef", "last_sync", "in_flight",
                     "dispatch_round", "return_round"):
                sh[k] = self._bank_shardings(v)
            else:
                sh[k] = jax.tree.map(lambda _: rep, v)
        return sh

    # -------------------------------------------------- observability

    def _tele(self):
        """The attached telemetry bus, or the shared no-op one."""
        from repro.obs import NULL
        return self.telemetry if self.telemetry is not None else NULL

    def _obs_begin(self, states):
        """Create the on-device stat ring (repro.obs.devstats) when a
        telemetry bus with at least one sink is attached; the stats are
        computed by a separate jitted program on each round's OUTPUT states,
        so the round programs themselves are untouched."""
        tele = self._tele()
        if not tele.sinks:
            return None
        from repro.obs import StatAccum
        return StatAccum.create(states, tele.metrics_every, tele.consensus)

    def _obs_round(self, acc, states, round_id: int, dt: float, step: int,
                   samples, comms: int, bytes_up: int = 0,
                   bytes_down: int = 0, **extra):
        """Per-round telemetry: one buffered record + one on-device stat
        append; the accumulator drains (the single host transfer) every
        ``metrics_every`` rounds."""
        tele = self._tele()
        tele.round(round_id, step=step, round_seconds=dt, samples=samples,
                   comms=comms, bytes_up=bytes_up, bytes_down=bytes_down,
                   **extra)
        if acc is not None:
            acc.update(states)
            if acc.ready:
                tele.stats(**acc.drain())

    def _obs_end(self, acc):
        """Drain the partial tail window and flush the sinks."""
        tele = self._tele()
        if acc is not None and acc.pending:
            tele.stats(**acc.drain())
        tele.flush()

    def _mega_obs(self, tele):
        """Mega-mode stat plumbing (docs/megascan.md): the fused programs
        emit one ``repro.obs.stat_row`` per round as a scan output — the
        rows are unconditionally part of the program, so it compiles
        byte-identically with telemetry on or off — and this returns the
        emitter that converts an ``[L, 2]`` device row block into ONE
        telemetry ``stats`` record, the once-per-chunk drain. The opt-in
        consensus column is O(N) work per round and stays out of the fused
        program by policy, so it is rejected up front."""
        if tele.sinks and getattr(tele, "consensus", False):
            raise ValueError(
                "rounds_per_scan > 1 cannot fold the O(N) consensus stat "
                "into the mega-scan program; run with rounds_per_scan=1 "
                "or telemetry consensus=False")
        state = {"round0": 0}

        def emit(rows):
            k = int(rows.shape[0])
            if tele.sinks and k:
                arr = np.asarray(rows, np.float32)   # the chunk's transfer
                tele.stats(round_start=state["round0"],
                           global_norm=[float(v) for v in arr[:, 0]],
                           update_norm=[float(v) for v in arr[:, 1]])
            state["round0"] += k

        return emit

    def _log_chunk(self, res: RunResult, dt: float, length: int,
                   fresh: bool):
        """Chunk wall-clock accounting: a fresh-length chunk carries its
        compile (kept out of the steady-state log, mirroring _log_round's
        first-round convention); steady chunks amortize their wall-clock
        over the rounds they contain."""
        if fresh:
            res.compile_seconds += dt
        else:
            self.round_seconds.extend([dt / length] * length)

    # -------------------------------------------------- run loops

    def _log_round(self, res: RunResult, dt: float):
        """First completed round carries the compile; keep it out of the
        steady-state per-round log."""
        if res.compile_seconds == 0.0:
            res.compile_seconds = dt
        else:
            self.round_seconds.append(dt)

    def run(self, total_steps: int, key=None, eval_every: int = 10) -> RunResult:
        key = key if key is not None else jax.random.PRNGKey(0)
        self._setup_sampler(key)
        if self.engine == "gossip":
            return self._run_gossip(total_steps, key, eval_every)
        if self.population is not None:
            return self._run_population(total_steps, key, eval_every)
        if self.engine == "scan":
            return self._run_scan(total_steps, key, eval_every)
        fed = self.alg.fed
        states, server = self._init_run(key)
        samples = fed.q * (fed.neumann_k + 2)
        comms = 0
        agg = self._star_aggregator()
        msg_b, down_b = self._wire_costs(states)
        bytes_up = bytes_down = 0
        lossy = self.codec.lossy

        local = jax.jit(self._local_body)
        sync = jax.jit(self._sync_body)
        if lossy:
            from repro.fed.compress import zeros_ef
            sync_c = jax.jit(self._sync_body_codec)
            ref = states                      # the server-known init
            ef = zeros_ef(self.codec, states)

        acc = self._obs_begin(states)
        res = RunResult(self.alg.name, [], [], [], [], [], 0.0)
        t0 = time.time()
        r0 = time.time()
        for t in range(total_steps):
            rnd = t // fed.q
            active = self._active_mask(rnd)
            if t > 0 and t % fed.q == 0:
                if self.track_consensus:
                    from repro.core.metrics import consensus_error
                    ce = consensus_error(states)
                    self.consensus_log.append(
                        {"step": t, **{k: float(v) for k, v in ce.items()}})
                active_prev = self._active_mask(rnd - 1)
                if lossy:
                    states, server, ref, ef = sync_c(
                        states, server, active_prev, ref, ef, key,
                        jnp.int32(rnd - 1))
                else:
                    states, server = sync(states, server, active_prev)
                comms += 1
                up, down = agg.wire_round(msg_b, down_b,
                                          tx=int(active_prev.sum()),
                                          rx=self.n_clients)
                bytes_up += up
                bytes_down += down
            states, server = local(states, server, self._batches(t), key,
                                   active)
            samples += fed.neumann_k + 2
            if (t + 1) % fed.q == 0:
                # per-round wall-clock, comparable with the scan engine's
                jax.block_until_ready(states)
                dt = time.time() - r0
                self._log_round(res, dt)
                self._obs_round(acc, states, rnd, dt, t, samples, comms,
                                bytes_up, bytes_down)
                r0 = time.time()
            if t % eval_every == 0 or t == total_steps - 1:
                self._record(res, states, t, samples, comms, bytes_up,
                             bytes_down)
        res.seconds = time.time() - t0
        self._obs_end(acc)
        res.final_avg_state = tree_mean_axis0(states)
        return res

    def _run_scan(self, total_steps: int, key, eval_every: int) -> RunResult:
        """Fused round engine: each communication round runs as ONE jitted
        program, shaped exactly like the eager loop — the sync that closes
        the PREVIOUS round, then this round's local steps as a ``lax.scan``.
        Same per-step math, same fold_in(t) RNG keys, same step count (a
        trailing partial round scans the remainder), and every recorded state
        is post-local/pre-sync like the eager loop's — only the eval
        granularity is per-round instead of per-step.
        """
        from repro.fed.round import make_round_step
        if self.track_consensus:
            raise ValueError("track_consensus needs engine='eager' (it reads "
                             "pre-sync client states mid-round)")
        fed = self.alg.fed
        q = fed.q
        states, server = self._init_run(key)
        samples = fed.q * (fed.neumann_k + 2)
        comms = 0
        agg = self._star_aggregator()
        msg_b, down_b = self._wire_costs(states)
        bytes_up = bytes_down = 0
        lossy = self.codec.lossy
        if lossy:
            from repro.fed.compress import zeros_ef
            ref = states
            ef = zeros_ef(self.codec, states)

        def segment_body(states, server, batches_q, kk, active_prev, active,
                         *, n_steps, sync_first):
            if sync_first:
                states, server = self._sync_body(states, server, active_prev)
            local = lambda st, srv, b, k: self._local_body(st, srv, b, k,
                                                           active)
            return make_round_step(local, lambda st, srv: (st, srv),
                                   n_steps)(states, server, batches_q, kk)

        def segment_codec_body(states, server, ref, ef, batches_q, kk,
                               active_prev, active, round_id, *, n_steps,
                               sync_first):
            # the sync closing round r-1 folds round_id - 1 — the same RNG
            # stream the eager engine's codec sync uses, so eager and scan
            # stay parity-comparable under stochastic codecs too
            if sync_first:
                states, server, ref, ef = self._sync_body_codec(
                    states, server, active_prev, ref, ef, kk, round_id - 1)
            local = lambda st, srv, b, k: self._local_body(st, srv, b, k,
                                                           active)
            states, server = make_round_step(
                local, lambda st, srv: (st, srv), n_steps)(states, server,
                                                           batches_q, kk)
            return states, server, ref, ef

        # the plain bodies above also become the mega-scan chunk body; the
        # per-round jits below compile the exact programs the decorated
        # closures used to
        segment = jax.jit(segment_body,
                          static_argnames=("n_steps", "sync_first"))
        segment_codec = jax.jit(segment_codec_body,
                                static_argnames=("n_steps", "sync_first"))

        full, rem = divmod(total_steps, q)
        lengths = [q] * full + ([rem] if rem else [])
        eval_rounds = max(eval_every // q, 1)
        tele = self._tele()
        R = self.rounds_per_scan
        acc = self._obs_begin(states) if R <= 1 else None
        res = RunResult(self.alg.name, [], [], [], [], [], 0.0)
        t0 = time.time()
        t = 0
        if R > 1:
            # mega-scan tier: full sync-first rounds (1 .. full-1) fuse into
            # chunks of up to R rounds, each ONE donated-carry program; round
            # 0 (no preceding sync) and the trailing partial round peel off
            # as single-round programs (docs/megascan.md)
            from repro.fed.round import make_multi_round
            from repro.obs.devstats import stat_row
            emit_rows = self._mega_obs(tele)
            row_fn = jax.jit(stat_row)
            prev_avg = jax.jit(tree_mean_axis0)(states)

            if lossy:
                def chunk_round(carry, masks, batches_q, kk, round_id):
                    states, server, ref, ef, prev = carry
                    states, server, ref, ef = segment_codec_body(
                        states, server, ref, ef, batches_q, kk, masks[0],
                        masks[1], round_id, n_steps=q, sync_first=True)
                    row, prev = stat_row(states, prev)
                    return (states, server, ref, ef, prev), row
            else:
                def chunk_round(carry, masks, batches_q, kk, round_id):
                    states, server, prev = carry
                    states, server = segment_body(
                        states, server, batches_q, kk, masks[0], masks[1],
                        n_steps=q, sync_first=True)
                    row, prev = stat_row(states, prev)
                    return (states, server, prev), row

            mega = jax.jit(make_multi_round(chunk_round),
                           donate_argnums=(0,))
            mega_compiled = set()
            # peeled single-round programs ((n_steps, sync_first) keys) also
            # compile fresh the first time — e.g. the trailing partial round
            # — and must stay out of the steady-state round log
            seg_used = set()
            n_rounds = len(lengths)
            r = 0
            while r < n_rounds:
                n_steps = lengths[r]
                L = min(R, full - r) if (r > 0 and n_steps == q) else 1
                if L <= 1:
                    with tele.span("batch_build"):
                        batches_q = tree_stack([self._batches(t + j)
                                                for j in range(n_steps)])
                    active = self._active_mask(r)
                    active_prev = (self._active_mask(r - 1) if r > 0
                                   else active)
                    seg_fresh = (n_steps, r > 0) not in seg_used
                    seg_used.add((n_steps, r > 0))
                    r0 = time.time()
                    with tele.span("round_program"):
                        if lossy:
                            states, server, ref, ef = segment_codec(
                                states, server, ref, ef, batches_q, key,
                                active_prev, active, jnp.int32(r),
                                n_steps=n_steps, sync_first=r > 0)
                        else:
                            states, server = segment(
                                states, server, batches_q, key, active_prev,
                                active, n_steps=n_steps, sync_first=r > 0)
                        jax.block_until_ready(states)
                    dt = time.time() - r0
                    self._log_chunk(res, dt, 1, seg_fresh)
                    row, prev_avg = row_fn(states, prev_avg)
                    t += n_steps
                    samples += n_steps * (fed.neumann_k + 2)
                    if r > 0:
                        comms += 1
                        up, down = agg.wire_round(
                            msg_b, down_b, tx=int(active_prev.sum()),
                            rx=self.n_clients)
                        bytes_up += up
                        bytes_down += down
                    tele.round(r, step=t - 1, round_seconds=dt,
                               samples=samples, comms=comms,
                               bytes_up=bytes_up, bytes_down=bytes_down)
                    emit_rows(row[None])
                    if r % eval_rounds == 0 or r == n_rounds - 1:
                        self._record(res, states, t - 1, samples, comms,
                                     bytes_up, bytes_down)
                    r += 1
                    continue
                masks_prev = [self._active_mask(rr - 1)
                              for rr in range(r, r + L)]
                masks_cur = [self._active_mask(rr)
                             for rr in range(r, r + L)]
                prev_np = [np.asarray(m) for m in masks_prev]
                with tele.span("batch_build"):
                    batches_R = tree_stack(
                        [tree_stack([self._batches(t + j * q + jj)
                                     for jj in range(q)])
                         for j in range(L)])
                fresh = L not in mega_compiled
                mega_compiled.add(L)
                r0 = time.time()
                with tele.span("round_program"):
                    if lossy:
                        carry = (states, server, ref, ef, prev_avg)
                    else:
                        carry = (states, server, prev_avg)
                    carry, rows = mega(
                        carry, (jnp.stack(masks_prev), jnp.stack(masks_cur)),
                        batches_R, key, jnp.int32(r))
                    if lossy:
                        states, server, ref, ef, prev_avg = carry
                    else:
                        states, server, prev_avg = carry
                    jax.block_until_ready(states)
                dt = time.time() - r0
                self._log_chunk(res, dt, L, fresh)
                for j in range(L):
                    t += q
                    samples += q * (fed.neumann_k + 2)
                    comms += 1
                    up, down = agg.wire_round(msg_b, down_b,
                                              tx=int(prev_np[j].sum()),
                                              rx=self.n_clients)
                    bytes_up += up
                    bytes_down += down
                    tele.round(r + j, step=t - 1, round_seconds=dt / L,
                               samples=samples, comms=comms,
                               bytes_up=bytes_up, bytes_down=bytes_down)
                emit_rows(rows)
                if (any((r + j) % eval_rounds == 0 for j in range(L))
                        or r + L == n_rounds):
                    self._record(res, states, t - 1, samples, comms,
                                 bytes_up, bytes_down)
                r += L
        else:
            for r, n_steps in enumerate(lengths):
                with tele.span("batch_build"):
                    batches_q = tree_stack([self._batches(t + j)
                                            for j in range(n_steps)])
                active = self._active_mask(r)
                # round 0 has no preceding sync (sync_first=False): reuse
                # the current mask instead of an unused _active_mask(-1)
                active_prev = self._active_mask(r - 1) if r > 0 else active
                r0 = time.time()
                with tele.span("round_program"):
                    if lossy:
                        states, server, ref, ef = segment_codec(
                            states, server, ref, ef, batches_q, key,
                            active_prev, active, jnp.int32(r),
                            n_steps=n_steps, sync_first=r > 0)
                    else:
                        states, server = segment(
                            states, server, batches_q, key, active_prev,
                            active, n_steps=n_steps, sync_first=r > 0)
                    jax.block_until_ready(states)
                dt = time.time() - r0
                self._log_round(res, dt)
                t += n_steps
                samples += n_steps * (fed.neumann_k + 2)
                if r > 0:
                    comms += 1
                    up, down = agg.wire_round(msg_b, down_b,
                                              tx=int(active_prev.sum()),
                                              rx=self.n_clients)
                    bytes_up += up
                    bytes_down += down
                self._obs_round(acc, states, r, dt, t - 1, samples, comms,
                                bytes_up, bytes_down)
                if r % eval_rounds == 0 or r == len(lengths) - 1:
                    self._record(res, states, t - 1, samples, comms,
                                 bytes_up, bytes_down)
        res.seconds = time.time() - t0
        self._obs_end(acc)
        res.final_avg_state = tree_mean_axis0(states)
        return res

    # -------------------------------------------------- population mode

    def _cohort_batches(self, ids, step: int):
        per = [self.batch_fn(int(g), step) for g in ids]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per)

    def _cohort_local_step(self, n: int):
        """One cohort-wide local step: per-client RNG folds the GLOBAL id
        (so a cohort step reproduces the same client's full-population
        step) and the eta_t schedule sees the population size ``n``. The
        single implementation both the sync and async population round
        programs scan — the degenerate-async ≡ sync parity guarantee
        (tests/test_async.py) rides on them sharing it."""
        def step(states, srv, batch, kk, ids):
            t = srv["t"]

            def one(st1, b, gid):
                k2 = jax.random.fold_in(jax.random.fold_in(kk, gid), t)
                return self.alg.local_step(st1, srv["adaptive"], b, k2, t, n)
            states = jax.vmap(one)(states, batch, ids)
            srv = dict(srv)
            srv["t"] = t + 1
            return states, srv
        return step

    def _init_population(self, key):
        """Bank of N client states — same per-client init as the masked
        path's ``_init_run`` (shared (x0, y0), per-client estimator keys and
        step-0 batches), so N == M runs start identically."""
        from repro.fed.population import ClientPopulation
        n = self.population.n
        fed = self.alg.fed
        xp, yp = self.init_xy(key)
        batches0 = self._cohort_batches(range(n), 0)
        pop = ClientPopulation.create(
            lambda k, b: self.alg.init_client_state(xp, yp, b, k),
            key, batches0, n)
        server = self.alg.init_server_state(xp)
        if fed.adaptive != "none":
            from repro.core.adafbio import warm_adaptive
            server = warm_adaptive(server, tree_mean_axis0(pop.states), fed)
        return pop, server

    def _run_population(self, total_steps: int, key, eval_every) -> RunResult:
        """Cohort-sampled rounds over a persistent N-client bank.

        Same round shape as ``_run_scan`` — the sync that closes the
        PREVIOUS round, then this round's local steps as one ``lax.scan`` —
        but gather/compute/scatter touch only the C sampled clients, so the
        program jits once for cohort shape [C, ...] and per-round compute is
        O(C) regardless of N. With ``sync_mode='broadcast'`` and the same
        cohort schedule this reproduces the masked-participation trajectory
        exactly (tests/test_population.py).
        """
        from repro.fed.population import (broadcast, gather, scatter,
                                          staleness_weights)
        if self.track_consensus:
            raise ValueError("track_consensus needs the masked eager engine "
                             "(it reads pre-sync client states mid-round)")
        pcfg = self.population
        # checked here, not __post_init__: `population` is routinely assigned
        # after construction, and batch_fn/init indices run over 0..n-1
        if pcfg.n != self.n_clients:
            raise ValueError(
                f"population.n ({pcfg.n}) must equal n_clients "
                f"({self.n_clients}) — batch_fn/init indices run over the "
                f"population")
        if pcfg.asynchronous:
            return self._run_population_async(total_steps, key, eval_every)
        n = pcfg.n
        fed = self.alg.fed
        q = fed.q
        agg = self._star_aggregator()
        pop, server = self._init_population(key)
        bank, last_sync = pop.states, pop.last_sync
        samples = fed.q * (fed.neumann_k + 2)
        comms = 0
        msg_b, down_b = self._wire_costs(bank)
        bytes_up = bytes_down = 0
        lossy = self.codec.lossy
        from repro.fed.compress import zeros_ef
        ef = zeros_ef(self.codec, bank)
        bank_sh = self._bank_shardings(bank)
        vec_sh = self._bank_shardings(last_sync)
        ef_sh = self._bank_shardings(ef) if ef is not None else None
        if self.mesh is not None:
            # commit the bank layout up front: each device holds N/devices
            # rows of the bank (and EF stack), the round program keeps it
            bank = jax.device_put(bank, bank_sh)
            last_sync = jax.device_put(last_sync, vec_sh)
            if ef is not None:
                ef = jax.device_put(ef, ef_sh)

        def segment_fn(bank, last_sync, ef, server, prev_ids, ids, batches_q,
                       kk, round_id, *, n_steps, sync_first):
            if sync_first:
                # the sync at the START of round r closes round r-1; a client
                # stamped at the previous sync (last_sync == r-1) is fully
                # fresh — same staleness origin as make_population_round's
                # end-of-round convention (which stamps round_id + 1)
                with jax.named_scope("round/aggregate"):
                    w = staleness_weights(last_sync, prev_ids, round_id - 1,
                                          pcfg.staleness_decay)
                    new_client, server = agg.reduce(
                        server, gather(bank, prev_ids), weights=w)
                if pcfg.sync_mode == "broadcast":
                    with jax.named_scope("round/broadcast"):
                        bank = broadcast(bank, new_client)
                        last_sync = jnp.full_like(last_sync, round_id)
                else:
                    with jax.named_scope("round/scatter_sync"):
                        c = prev_ids.shape[0]
                        bank = scatter(bank, prev_ids, jax.tree.map(
                            lambda v: jnp.broadcast_to(v[None],
                                                       (c,) + v.shape),
                            new_client))
                        last_sync = last_sync.at[prev_ids].set(round_id)
            with jax.named_scope("round/gather"):
                cur = gather(bank, ids)
            ref = cur                 # server-known dispatch states
            local = self._cohort_local_step(n)

            def body(carry, batch):
                st, srv = carry
                st, srv = local(st, srv, batch, kk, ids)
                return (st, srv), None

            with jax.named_scope("round/local_scan"):
                (cur, server), _ = jax.lax.scan(body, (cur, server),
                                                batches_q, length=n_steps)
            if lossy:
                # the cohort ships its update through the codec when the
                # round ends; the bank row becomes the server-side
                # reconstruction, which the NEXT round's sync aggregates
                with jax.named_scope("round/codec"):
                    ef_c = gather(ef, ids) if ef is not None else None
                    cur, ef_c = agg.messages(kk, round_id, ids, ref, cur,
                                             ef_c)
                    if ef is not None:
                        ef = scatter(ef, ids, ef_c)
            with jax.named_scope("round/scatter"):
                bank = scatter(bank, ids, cur)
            return bank, last_sync, ef, server

        if self.mesh is None:
            segment = jax.jit(segment_fn,
                              static_argnames=("n_steps", "sync_first"))
        else:
            # pjit rejects kwargs alongside in_shardings: close over the
            # static pair and cache one jitted program per combination
            # (at most {(q, False), (q, True), (rem, True)})
            rep = self._replicated()
            seg_cache = {}

            def segment(*a, n_steps, sync_first):
                k = (n_steps, sync_first)
                if k not in seg_cache:
                    seg_cache[k] = jax.jit(
                        functools.partial(segment_fn, n_steps=n_steps,
                                          sync_first=sync_first),
                        in_shardings=(bank_sh, vec_sh, ef_sh, rep, rep,
                                      rep, rep, rep, rep),
                        out_shardings=(bank_sh, vec_sh, ef_sh, rep))
                return seg_cache[k](*a)

        full, rem = divmod(total_steps, q)
        lengths = [q] * full + ([rem] if rem else [])
        eval_rounds = max(eval_every // q, 1)
        tele = self._tele()
        R = self.rounds_per_scan
        acc = self._obs_begin(bank) if R <= 1 else None
        res = RunResult(self.alg.name, [], [], [], [], [], 0.0)
        t0 = time.time()
        t = 0
        if R > 1:
            # mega-scan tier: full sync-first rounds chunk into ONE donated-
            # carry program each; round 0 and the trailing partial round
            # peel off as single-round programs. The carry threads (bank,
            # last_sync, ef, server, prev_ids, prev_avg) — prev_ids feeds
            # each in-scan opening sync, prev_avg the per-round stat rows.
            from repro.fed.round import make_multi_round
            from repro.fed.sampling import in_scan_cohort_fn
            from repro.obs.devstats import stat_row
            emit_rows = self._mega_obs(tele)
            row_fn = jax.jit(stat_row)
            prev_avg = jax.jit(tree_mean_axis0)(bank)
            cohort_fn = in_scan_cohort_fn(self._run_sampler)

            def chunk_round(carry, ids, batches_q, kk, round_id):
                bank, last_sync, ef, server, prev_ids, prev = carry
                bank, last_sync, ef, server = segment_fn(
                    bank, last_sync, ef, server, prev_ids, ids, batches_q,
                    kk, round_id, n_steps=q, sync_first=True)
                row, prev = stat_row(bank, prev)
                return (bank, last_sync, ef, server, ids, prev), row

            mega_fn = make_multi_round(chunk_round, cohort_fn=cohort_fn)
            if self.mesh is None:
                mega = jax.jit(mega_fn, donate_argnums=(0,))
            else:
                rep = self._replicated()
                carry_sh = (bank_sh, vec_sh, ef_sh, rep, rep, rep)
                ids_sh = None if cohort_fn is not None else rep
                mega = jax.jit(mega_fn,
                               in_shardings=(carry_sh, ids_sh, rep, rep,
                                             rep),
                               out_shardings=(carry_sh, rep),
                               donate_argnums=(0,))
            mega_compiled = set()
            # peeled single-round programs ((n_steps, sync_first) keys) also
            # compile fresh the first time — e.g. the trailing partial round
            # — and must stay out of the steady-state round log
            seg_used = set()
            n_rounds = len(lengths)
            prev_ids_np = None
            r = 0
            while r < n_rounds:
                n_steps = lengths[r]
                L = min(R, full - r) if (r > 0 and n_steps == q) else 1
                # host ALWAYS draws the ids — batch gather and unique-
                # transmitter billing need them even when cohort_fn re-draws
                # them in-scan (the draws match bit-for-bit:
                # tests/test_property.py)
                ids_np = [np.asarray(self._run_sampler.cohort(rr)).astype(
                    np.int32) for rr in range(r, r + L)]
                if L <= 1:
                    ids = jnp.asarray(ids_np[0])
                    sync_np = (prev_ids_np if prev_ids_np is not None
                               else ids_np[0])
                    with tele.span("batch_build"):
                        batches_q = tree_stack(
                            [self._cohort_batches(ids_np[0], t + j)
                             for j in range(n_steps)])
                    seg_fresh = (n_steps, r > 0) not in seg_used
                    seg_used.add((n_steps, r > 0))
                    r0 = time.time()
                    with tele.span("round_program"):
                        bank, last_sync, ef, server = segment(
                            bank, last_sync, ef, server,
                            jnp.asarray(sync_np), ids, batches_q, key,
                            jnp.int32(r), n_steps=n_steps,
                            sync_first=r > 0)
                        jax.block_until_ready(bank)
                    dt = time.time() - r0
                    self._log_chunk(res, dt, 1, seg_fresh)
                    row, prev_avg = row_fn(bank, prev_avg)
                    t += n_steps
                    samples += n_steps * (fed.neumann_k + 2)
                    if r > 0:
                        comms += 1
                        tx = int(np.unique(sync_np).size)
                        up, down = agg.wire_round(
                            msg_b, down_b, tx=tx,
                            rx=(n if pcfg.sync_mode == "broadcast" else tx))
                        bytes_up += up
                        bytes_down += down
                    tele.round(r, step=t - 1, round_seconds=dt,
                               samples=samples, comms=comms,
                               bytes_up=bytes_up, bytes_down=bytes_down)
                    emit_rows(row[None])
                    if r % eval_rounds == 0 or r == n_rounds - 1:
                        self._record(res, bank, t - 1, samples, comms,
                                     bytes_up, bytes_down)
                    prev_ids_np = ids_np[0]
                    r += 1
                    continue
                with tele.span("batch_build"):
                    batches_R = tree_stack(
                        [tree_stack([self._cohort_batches(ids_np[j],
                                                          t + j * q + jj)
                                     for jj in range(q)])
                         for j in range(L)])
                ids_R = (None if cohort_fn is not None
                         else jnp.asarray(np.stack(ids_np)))
                fresh = L not in mega_compiled
                mega_compiled.add(L)
                r0 = time.time()
                with tele.span("round_program"):
                    carry = (bank, last_sync, ef, server,
                             jnp.asarray(prev_ids_np), prev_avg)
                    carry, rows = mega(carry, ids_R, batches_R, key,
                                       jnp.int32(r))
                    bank, last_sync, ef, server, _, prev_avg = carry
                    jax.block_until_ready(bank)
                dt = time.time() - r0
                self._log_chunk(res, dt, L, fresh)
                # round rr's opening sync bills round rr-1's cohort
                sync_chain = [prev_ids_np] + ids_np[:-1]
                for j in range(L):
                    t += q
                    samples += q * (fed.neumann_k + 2)
                    comms += 1
                    tx = int(np.unique(sync_chain[j]).size)
                    up, down = agg.wire_round(
                        msg_b, down_b, tx=tx,
                        rx=(n if pcfg.sync_mode == "broadcast" else tx))
                    bytes_up += up
                    bytes_down += down
                    tele.round(r + j, step=t - 1, round_seconds=dt / L,
                               samples=samples, comms=comms,
                               bytes_up=bytes_up, bytes_down=bytes_down)
                emit_rows(rows)
                if (any((r + j) % eval_rounds == 0 for j in range(L))
                        or r + L == n_rounds):
                    self._record(res, bank, t - 1, samples, comms,
                                 bytes_up, bytes_down)
                prev_ids_np = ids_np[-1]
                r += L
        else:
            prev_ids = None
            for r, n_steps in enumerate(lengths):
                ids = jnp.asarray(self._run_sampler.cohort(r), jnp.int32)
                # the sync opening round r aggregates (and bills) the
                # PREVIOUS round's cohort — the clients whose updates are
                # on the wire
                sync_ids = prev_ids if prev_ids is not None else ids
                with tele.span("batch_build"):
                    batches_q = tree_stack([self._cohort_batches(ids, t + j)
                                            for j in range(n_steps)])
                r0 = time.time()
                with tele.span("round_program"):
                    bank, last_sync, ef, server = segment(
                        bank, last_sync, ef, server, sync_ids, ids,
                        batches_q, key, jnp.int32(r), n_steps=n_steps,
                        sync_first=r > 0)
                    jax.block_until_ready(bank)
                dt = time.time() - r0
                self._log_round(res, dt)
                prev_ids = ids
                t += n_steps
                samples += n_steps * (fed.neumann_k + 2)
                if r > 0:
                    comms += 1
                    # wire convention (docs/sharding.md): uplink bills
                    # UNIQUE transmitters — a duplicate cohort id (trace
                    # shortfall cycling) occupies two aggregation slots but
                    # one client computed and shipped one message;
                    # participants-mode downlink likewise reaches each
                    # member once
                    tx = int(np.unique(np.asarray(sync_ids)).size)
                    up, down = agg.wire_round(
                        msg_b, down_b, tx=tx,
                        rx=(n if pcfg.sync_mode == "broadcast" else tx))
                    bytes_up += up
                    bytes_down += down
                self._obs_round(acc, bank, r, dt, t - 1, samples, comms,
                                bytes_up, bytes_down)
                if r % eval_rounds == 0 or r == len(lengths) - 1:
                    self._record(res, bank, t - 1, samples, comms, bytes_up,
                                 bytes_down)
        res.seconds = time.time() - t0
        self._obs_end(acc)
        self.final_bank = bank        # benchmarks inspect per-device bytes
        res.final_avg_state = tree_mean_axis0(bank)
        return res

    # -------------------------------------------------- gossip engine

    def _gossip_local_step(self, n: int):
        """Per-node local step of the decentralized engine: same math and
        per-client RNG fold as ``_cohort_local_step``, but the server state
        is a stacked [n] bank — every node advances against its OWN
        adaptive matrices and step counter (in lockstep the counters stay
        equal, so the fold_in(gid)/fold_in(t) draws match the star
        engines' for the same (gid, t))."""
        def step(states, srv_bank, batch, kk, ids):
            def one(st1, srv, b, gid):
                t = srv["t"]
                k2 = jax.random.fold_in(jax.random.fold_in(kk, gid), t)
                new_st = self.alg.local_step(st1, srv["adaptive"], b, k2,
                                             t, n)
                srv = dict(srv)
                srv["t"] = t + 1
                return new_st, srv
            return jax.vmap(one)(states, srv_bank, batch, ids)
        return step

    def _run_gossip(self, total_steps: int, key, eval_every) -> RunResult:
        """Decentralized rounds: no server — each node keeps its own server
        state, and the sync that opens round r is ONE doubly-stochastic
        mixing step over ``population.topology``'s graph followed by every
        node's own ``sync_update`` (``repro.fed.topology``; semantics in
        docs/topology.md). Same fused round shape as ``_run_population``
        (mix closing round r-1, then q local steps as one scan; round 0 has
        nothing to close), full participation by construction.

        Wire accounting is per DIRECTED EDGE: every sync, each node ships
        one codec-priced message along each out-edge and receives one along
        each in-edge — there is no full-precision broadcast. Time-varying
        graphs are billed exactly by replaying each round's draw on the
        host (``GossipAggregator.host_matrix``).

        On the complete graph the Metropolis matrix is uniform (1/n rows),
        so this engine matches the star population engine's full-cohort
        trajectory to float tolerance (tests/test_topology.py)."""
        from repro.fed.compress import zeros_ef
        from repro.fed.topology import GossipAggregator, make_gossip_round
        if self.track_consensus:
            raise ValueError("track_consensus needs the masked eager engine "
                             "(it reads pre-sync client states mid-round)")
        pcfg = self.population
        if pcfg is None:
            raise ValueError(
                "engine='gossip' needs population=PopulationConfig(...) — "
                "the population size and topology knobs live there")
        if pcfg.n != self.n_clients:
            raise ValueError(
                f"population.n ({pcfg.n}) must equal n_clients "
                f"({self.n_clients}) — batch_fn/init indices run over the "
                f"population")
        if pcfg.cohort != pcfg.n:
            raise ValueError(
                f"the gossip engine is full-participation: every node mixes "
                f"and steps every round, so population.cohort "
                f"({pcfg.cohort}) must equal population.n ({pcfg.n})")
        if pcfg.asynchronous:
            raise ValueError("the gossip engine is synchronous — set "
                             "population.max_staleness = 0")
        n = pcfg.n
        fed = self.alg.fed
        q = fed.q
        agg = GossipAggregator(
            sync_update=lambda srv, avg: self.alg.sync_update(srv, avg, n),
            n=n, topology=pcfg.topology, er_p=pcfg.er_p,
            seed=pcfg.topology_seed, time_varying=pcfg.time_varying,
            codec=self.codec)
        self.gossip_agg = agg        # benches/tests read .gap / .edges()
        pop, server = self._init_population(key)
        bank = pop.states
        # one initial consensus pass: every node starts from the SAME
        # warm-adaptive server state (broadcast to a [n] bank) — the star
        # engines' init, so round-0 trajectories coincide by construction
        srv_bank = tree_bcast_axis0(server, n)
        samples = fed.q * (fed.neumann_k + 2)
        comms = 0
        msg_b, down_b = self._wire_costs(bank)
        bytes_up = bytes_down = 0
        ef = zeros_ef(self.codec, bank)
        bank_sh = self._bank_shardings(bank)
        svb_sh = self._bank_shardings(srv_bank)
        ef_sh = self._bank_shardings(ef) if ef is not None else None
        if self.mesh is not None:
            bank = jax.device_put(bank, bank_sh)
            srv_bank = jax.device_put(srv_bank, svb_sh)
            if ef is not None:
                ef = jax.device_put(ef, ef_sh)

        round_fn = make_gossip_round(self._gossip_local_step(n), agg, q)
        if self.mesh is None:
            segment = jax.jit(round_fn,
                              static_argnames=("n_steps", "sync_first"))
        else:
            # pjit rejects kwargs alongside in_shardings: close over the
            # static pair and cache one jitted program per combination
            rep = self._replicated()
            seg_cache = {}

            def segment(*a, n_steps, sync_first):
                k = (n_steps, sync_first)
                if k not in seg_cache:
                    seg_cache[k] = jax.jit(
                        functools.partial(round_fn, n_steps=n_steps,
                                          sync_first=sync_first),
                        in_shardings=(bank_sh, svb_sh, ef_sh, rep, rep,
                                      rep),
                        out_shardings=(bank_sh, svb_sh, ef_sh))
                return seg_cache[k](*a)

        # static graphs price once; time-varying ones replay per round
        static_edges = None if pcfg.time_varying else agg.edges(0)

        def round_edges(rid):
            return (static_edges if static_edges is not None
                    else agg.edges(rid))

        full, rem = divmod(total_steps, q)
        lengths = [q] * full + ([rem] if rem else [])
        eval_rounds = max(eval_every // q, 1)
        tele = self._tele()
        R = self.rounds_per_scan
        acc = self._obs_begin(bank) if R <= 1 else None
        res = RunResult(self.alg.name, [], [], [], [], [], 0.0)
        t0 = time.time()
        t = 0
        if R > 1:
            # mega-scan tier: full mix-first rounds chunk into ONE donated-
            # carry program each; round 0 and the trailing partial round
            # peel off as single-round programs (docs/megascan.md). Time-
            # varying graphs re-draw INSIDE the scan from the traced
            # round_id, so the fused rounds mix exactly what per-round
            # execution would.
            from repro.fed.round import make_multi_round
            from repro.obs.devstats import stat_row
            emit_rows = self._mega_obs(tele)
            row_fn = jax.jit(stat_row)
            prev_avg = jax.jit(tree_mean_axis0)(bank)

            def chunk_round(carry, ids, batches_q, kk, round_id):
                del ids
                bank, srv_bank, ef, prev = carry
                bank, srv_bank, ef = round_fn(bank, srv_bank, ef,
                                              batches_q, kk, round_id,
                                              n_steps=q, sync_first=True)
                row, prev = stat_row(bank, prev)
                return (bank, srv_bank, ef, prev), row

            mega_fn = make_multi_round(chunk_round)
            if self.mesh is None:
                mega = jax.jit(mega_fn, donate_argnums=(0,))
            else:
                rep = self._replicated()
                carry_sh = (bank_sh, svb_sh, ef_sh, rep)
                mega = jax.jit(mega_fn,
                               in_shardings=(carry_sh, None, rep, rep,
                                             rep),
                               out_shardings=(carry_sh, rep),
                               donate_argnums=(0,))
            mega_compiled = set()
            seg_used = set()
            n_rounds = len(lengths)
            r = 0
            while r < n_rounds:
                n_steps = lengths[r]
                L = min(R, full - r) if (r > 0 and n_steps == q) else 1
                if L <= 1:
                    with tele.span("batch_build"):
                        batches_q = tree_stack([self._batches(t + j)
                                                for j in range(n_steps)])
                    seg_fresh = (n_steps, r > 0) not in seg_used
                    seg_used.add((n_steps, r > 0))
                    r0 = time.time()
                    with tele.span("round_program"):
                        bank, srv_bank, ef = segment(
                            bank, srv_bank, ef, batches_q, key,
                            jnp.int32(r), n_steps=n_steps,
                            sync_first=r > 0)
                        jax.block_until_ready(bank)
                    dt = time.time() - r0
                    self._log_chunk(res, dt, 1, seg_fresh)
                    row, prev_avg = row_fn(bank, prev_avg)
                    t += n_steps
                    samples += n_steps * (fed.neumann_k + 2)
                    if r > 0:
                        comms += 1
                        up, down = agg.wire_round(
                            msg_b, down_b, edges=round_edges(r - 1))
                        bytes_up += up
                        bytes_down += down
                    tele.round(r, step=t - 1, round_seconds=dt,
                               samples=samples, comms=comms,
                               bytes_up=bytes_up, bytes_down=bytes_down)
                    emit_rows(row[None])
                    if r % eval_rounds == 0 or r == n_rounds - 1:
                        self._record(res, bank, t - 1, samples, comms,
                                     bytes_up, bytes_down)
                    r += 1
                    continue
                with tele.span("batch_build"):
                    batches_R = tree_stack(
                        [tree_stack([self._batches(t + j * q + jj)
                                     for jj in range(q)])
                         for j in range(L)])
                fresh = L not in mega_compiled
                mega_compiled.add(L)
                r0 = time.time()
                with tele.span("round_program"):
                    carry = (bank, srv_bank, ef, prev_avg)
                    carry, rows = mega(carry, None, batches_R, key,
                                       jnp.int32(r))
                    bank, srv_bank, ef, prev_avg = carry
                    jax.block_until_ready(bank)
                dt = time.time() - r0
                self._log_chunk(res, dt, L, fresh)
                for j in range(L):
                    t += q
                    samples += q * (fed.neumann_k + 2)
                    comms += 1
                    up, down = agg.wire_round(
                        msg_b, down_b, edges=round_edges(r + j - 1))
                    bytes_up += up
                    bytes_down += down
                    tele.round(r + j, step=t - 1, round_seconds=dt / L,
                               samples=samples, comms=comms,
                               bytes_up=bytes_up, bytes_down=bytes_down)
                emit_rows(rows)
                if (any((r + j) % eval_rounds == 0 for j in range(L))
                        or r + L == n_rounds):
                    self._record(res, bank, t - 1, samples, comms,
                                 bytes_up, bytes_down)
                r += L
        else:
            for r, n_steps in enumerate(lengths):
                with tele.span("batch_build"):
                    batches_q = tree_stack([self._batches(t + j)
                                            for j in range(n_steps)])
                r0 = time.time()
                with tele.span("round_program"):
                    bank, srv_bank, ef = segment(
                        bank, srv_bank, ef, batches_q, key, jnp.int32(r),
                        n_steps=n_steps, sync_first=r > 0)
                    jax.block_until_ready(bank)
                dt = time.time() - r0
                self._log_round(res, dt)
                t += n_steps
                samples += n_steps * (fed.neumann_k + 2)
                if r > 0:
                    comms += 1
                    up, down = agg.wire_round(msg_b, down_b,
                                              edges=round_edges(r - 1))
                    bytes_up += up
                    bytes_down += down
                self._obs_round(acc, bank, r, dt, t - 1, samples, comms,
                                bytes_up, bytes_down)
                if r % eval_rounds == 0 or r == len(lengths) - 1:
                    self._record(res, bank, t - 1, samples, comms,
                                 bytes_up, bytes_down)
        res.seconds = time.time() - t0
        self._obs_end(acc)
        self.final_bank = bank
        res.final_avg_state = tree_mean_axis0(bank)
        return res

    # -------------------------------------------------- async population

    def _run_population_async(self, total_steps: int, key,
                              eval_every) -> RunResult:
        """Asynchronous rounds over the bank: arrivals → bounded-staleness
        gate → (delay-adaptively scaled) server step → overlapping-cohort
        dispatch, all inside ONE jitted program per round
        (``repro.fed.population.make_async_round``; semantics in
        docs/async.md). Per-round arrival stats land in
        ``self.staleness_log`` and the accepted-staleness histogram in
        ``self.staleness_hist`` (index = staleness in rounds); with the
        ``tiers`` delay model, ``self.staleness_hist_by_tier`` splits the
        same histogram by the client's permanent speed tier.

        Sample accounting: a cohort slot whose client is still in flight is
        masked out and its compute discarded, so the per-round sample
        increment scales by ``dispatched / cohort`` — the fraction of
        UNIQUE cohort clients that actually started work (docs/async.md).
        """
        from repro.fed.population import (accum_staleness_hist,
                                          accum_tier_hists,
                                          delay_model_from_config,
                                          init_async_state, make_async_round)
        if self.track_consensus:
            raise ValueError("track_consensus needs the masked eager engine "
                             "(it reads pre-sync client states mid-round)")
        pcfg = self.population
        n = pcfg.n
        c = pcfg.cohort
        fed = self.alg.fed
        q = fed.q
        agg = self._star_aggregator()
        # resolve() bakes the permanent per-client delay quantities into
        # the round program as constants (same key every round below)
        dm = delay_model_from_config(pcfg).resolve(key, n)
        pop, server = self._init_population(key)
        state = init_async_state(pop.states, server, n, codec=self.codec)
        samples = float(fed.q * (fed.neumann_k + 2))
        comms = 0
        msg_b, down_b = self._wire_costs(pop.states)
        bytes_up = bytes_down = 0
        self.staleness_log: List[Dict[str, float]] = []
        self.staleness_hist = np.zeros(0, np.int64)
        self.staleness_hist_by_tier: Dict[int, Any] = {}
        tier_of = (np.asarray(dm.tiers(key, n))
                   if pcfg.delay_model == "tiers" else None)

        round_fn = make_async_round(
            self._cohort_local_step(n), agg,
            q, sync_mode=pcfg.sync_mode,
            staleness_decay=pcfg.staleness_decay,
            max_staleness=pcfg.max_staleness, max_delay=pcfg.max_delay,
            delay_eta=pcfg.delay_eta, delay=dm, codec=self.codec)
        if self.mesh is None:
            segment = jax.jit(round_fn)
        else:
            # bank + pending buffer + EF + [N] bookkeeping all partition
            # their population axis over the mesh; stats come back
            # replicated (the host reads them every round anyway)
            st_sh = self._async_state_shardings(state)
            rep = self._replicated()
            stats_sh = {k: rep for k in ("arrived", "accepted", "dropped",
                                         "mean_staleness", "eta_scale",
                                         "dispatched", "synced",
                                         "staleness")}
            state = jax.device_put(state, st_sh)
            segment = jax.jit(round_fn, in_shardings=(st_sh, rep, rep, rep,
                                                      rep),
                              out_shardings=(st_sh, stats_sh))

        full, rem = divmod(total_steps, q)
        lengths = [q] * full + ([rem] if rem else [])
        eval_rounds = max(eval_every // q, 1)
        tele = self._tele()
        R = self.rounds_per_scan
        statacc = self._obs_begin(state["bank"]) if R <= 1 else None
        res = RunResult(self.alg.name, [], [], [], [], [], 0.0)

        def note_round(r, stats_np, idx=None):
            """Host-side bookkeeping for one async round's stats (idx picks
            a row out of a chunk's stacked stats). Returns the round's
            staleness-log row; the counter updates happen at the call site
            so chunked and per-round paths share one implementation."""
            pick = ((lambda v: v[idx]) if idx is not None else (lambda v: v))
            stale = np.asarray(pick(stats_np["staleness"]))
            acc_ = stale[stale >= 0]
            if acc_.size:
                self.staleness_hist = accum_staleness_hist(
                    self.staleness_hist, acc_)
            if tier_of is not None:
                accum_tier_hists(self.staleness_hist_by_tier, stale,
                                 tier_of, len(pcfg.tier_fracs))
            self.staleness_log.append({
                "round": r,
                "arrived": int(pick(stats_np["arrived"])),
                "accepted": int(pick(stats_np["accepted"])),
                "dropped": int(pick(stats_np["dropped"])),
                "dispatched": int(pick(stats_np["dispatched"])),
                "synced": int(pick(stats_np["synced"])),
                "mean_staleness": float(pick(stats_np["mean_staleness"])),
                "eta_scale": float(pick(stats_np["eta_scale"])),
            })
            return self.staleness_log[-1]

        t0 = time.time()
        t = 0
        if R > 1:
            # mega-scan tier: the async round is uniform in round_id (round
            # 0 is not special), so chunks start at round 0; only the
            # trailing partial round peels off. Per-round stats come back
            # stacked as scan outputs and the host drains them per chunk.
            from repro.fed.population import make_multi_async_round
            from repro.fed.sampling import in_scan_cohort_fn
            from repro.obs.devstats import stat_row
            emit_rows = self._mega_obs(tele)
            row_fn = jax.jit(stat_row)
            prev_avg = jax.jit(tree_mean_axis0)(state["bank"])
            cohort_fn = in_scan_cohort_fn(self._run_sampler)

            def chunk_round(carry, ids, batches_q, kk, round_id):
                st, prev = carry
                st, stats = round_fn(st, ids, batches_q, kk, round_id)
                row, prev = stat_row(st["bank"], prev)
                return (st, prev), (stats, row)

            mega_fn = make_multi_async_round(chunk_round,
                                             cohort_fn=cohort_fn)
            if self.mesh is None:
                mega = jax.jit(mega_fn, donate_argnums=(0,))
            else:
                ids_sh = None if cohort_fn is not None else rep
                mega = jax.jit(mega_fn,
                               in_shardings=((st_sh, rep), ids_sh, rep,
                                             rep, rep),
                               out_shardings=((st_sh, rep),
                                              (stats_sh, rep)),
                               donate_argnums=(0,))
            mega_compiled = set()
            # peeled single-round programs (keyed by batch-stack length) also
            # compile fresh the first time — e.g. the trailing partial round
            # — and must stay out of the steady-state round log
            seg_used = set()
            n_rounds = len(lengths)
            r = 0
            while r < n_rounds:
                n_steps = lengths[r]
                L = min(R, full - r) if n_steps == q else 1
                ids_np = [np.asarray(self._run_sampler.cohort(rr)).astype(
                    np.int32) for rr in range(r, r + L)]
                if L <= 1:
                    ids = jnp.asarray(ids_np[0])
                    with tele.span("batch_build"):
                        batches_q = tree_stack(
                            [self._cohort_batches(ids_np[0], t + j)
                             for j in range(n_steps)])
                    seg_fresh = n_steps not in seg_used
                    seg_used.add(n_steps)
                    r0 = time.time()
                    with tele.span("round_program"):
                        state, stats = segment(state, ids, batches_q, key,
                                               jnp.int32(r))
                        jax.block_until_ready(state)
                    dt = time.time() - r0
                    self._log_chunk(res, dt, 1, seg_fresh)
                    row_dev, prev_avg = row_fn(state["bank"], prev_avg)
                    stats_np = {k2: np.asarray(v)
                                for k2, v in stats.items()}
                    row = note_round(r, stats_np)
                    comms += int(row["accepted"] > 0)
                    up, down = agg.wire_round(msg_b, down_b,
                                              tx=row["arrived"],
                                              rx=row["synced"])
                    bytes_up += up
                    bytes_down += down
                    t += n_steps
                    samples += (n_steps * (fed.neumann_k + 2)
                                * row["dispatched"] / c)
                    tele.round(r, step=t - 1, round_seconds=dt,
                               samples=int(round(samples)), comms=comms,
                               bytes_up=bytes_up, bytes_down=bytes_down,
                               **{k2: row[k2] for k2 in
                                  ("arrived", "accepted", "dropped",
                                   "dispatched", "synced",
                                   "mean_staleness", "eta_scale")})
                    emit_rows(row_dev[None])
                    if r % eval_rounds == 0 or r == n_rounds - 1:
                        self._record(res, state["bank"], t - 1,
                                     int(round(samples)), comms, bytes_up,
                                     bytes_down)
                    r += 1
                    continue
                with tele.span("batch_build"):
                    batches_R = tree_stack(
                        [tree_stack([self._cohort_batches(ids_np[j],
                                                          t + j * q + jj)
                                     for jj in range(q)])
                         for j in range(L)])
                ids_R = (None if cohort_fn is not None
                         else jnp.asarray(np.stack(ids_np)))
                fresh = L not in mega_compiled
                mega_compiled.add(L)
                r0 = time.time()
                with tele.span("round_program"):
                    (state, prev_avg), (stats_R, rows) = mega(
                        (state, prev_avg), ids_R, batches_R, key,
                        jnp.int32(r))
                    jax.block_until_ready(state)
                dt = time.time() - r0
                self._log_chunk(res, dt, L, fresh)
                stats_np = {k2: np.asarray(v) for k2, v in stats_R.items()}
                for j in range(L):
                    row = note_round(r + j, stats_np, idx=j)
                    comms += int(row["accepted"] > 0)
                    up, down = agg.wire_round(msg_b, down_b,
                                              tx=row["arrived"],
                                              rx=row["synced"])
                    bytes_up += up
                    bytes_down += down
                    t += q
                    samples += (q * (fed.neumann_k + 2)
                                * row["dispatched"] / c)
                    tele.round(r + j, step=t - 1, round_seconds=dt / L,
                               samples=int(round(samples)), comms=comms,
                               bytes_up=bytes_up, bytes_down=bytes_down,
                               **{k2: row[k2] for k2 in
                                  ("arrived", "accepted", "dropped",
                                   "dispatched", "synced",
                                   "mean_staleness", "eta_scale")})
                emit_rows(rows)
                if (any((r + j) % eval_rounds == 0 for j in range(L))
                        or r + L == n_rounds):
                    self._record(res, state["bank"], t - 1,
                                 int(round(samples)), comms, bytes_up,
                                 bytes_down)
                r += L
        else:
            for r, n_steps in enumerate(lengths):
                ids = jnp.asarray(self._run_sampler.cohort(r), jnp.int32)
                with tele.span("batch_build"):
                    batches_q = tree_stack([self._cohort_batches(ids, t + j)
                                            for j in range(n_steps)])
                r0 = time.time()
                with tele.span("round_program"):
                    state, stats = segment(state, ids, batches_q, key,
                                           jnp.int32(r))
                    # fence: the dispatch is async — round wall-clock must
                    # measure completion, not dispatch (pinned by
                    # tests/test_obs.py's forced-sleep lower bound)
                    jax.block_until_ready(state)
                dt = time.time() - r0
                self._log_round(res, dt)
                stats_np = {k2: np.asarray(v) for k2, v in stats.items()}
                row = note_round(r, stats_np)
                comms += int(row["accepted"] > 0)
                # uplink: every arrival shipped one codec message (dropped
                # ones too — the gate rejects them AFTER transmission);
                # downlink: the rows that received the new global model
                up, down = agg.wire_round(msg_b, down_b,
                                          tx=row["arrived"],
                                          rx=row["synced"])
                bytes_up += up
                bytes_down += down
                t += n_steps
                # only the dispatched fraction of the cohort computed this
                # round (in-flight slots are masked out and discarded) — the
                # paper's sample-complexity curves must not count them
                samples += (n_steps * (fed.neumann_k + 2)
                            * row["dispatched"] / c)
                self._obs_round(statacc, state["bank"], r, dt, t - 1,
                                int(round(samples)), comms, bytes_up,
                                bytes_down,
                                arrived=row["arrived"],
                                accepted=row["accepted"],
                                dropped=row["dropped"],
                                dispatched=row["dispatched"],
                                synced=row["synced"],
                                mean_staleness=row["mean_staleness"],
                                eta_scale=row["eta_scale"])
                if r % eval_rounds == 0 or r == len(lengths) - 1:
                    self._record(res, state["bank"], t - 1,
                                 int(round(samples)), comms, bytes_up,
                                 bytes_down)
        res.seconds = time.time() - t0
        tele.note(staleness_hist=[int(k) for k in self.staleness_hist])
        self._obs_end(statacc)
        self.final_bank = state["bank"]   # benchmarks inspect device bytes
        res.final_avg_state = tree_mean_axis0(state["bank"])
        return res
