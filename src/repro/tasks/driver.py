"""Small-scale federated driver for the paper's experiments (M simulated
clients as a leading pytree axis on a single host; algorithm-agnostic via the
``Algorithm`` contract, so AdaFBiO and every baseline run identically).

Tracks the paper's cost metrics exactly: #samples consumed (q(K+2) at init,
K+2 per local step) and #communication rounds (1 per sync)."""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core.baselines import Algorithm, make_algorithm
from repro.core.bilevel import BilevelProblem
from repro.core.tree_util import (tree_bcast_axis0, tree_mean_axis0,
                                  tree_stack)


@dataclasses.dataclass
class RunResult:
    name: str
    steps: List[int]
    samples: List[int]
    comms: List[int]
    metric: List[float]            # task metric (val loss / grad norm)
    grad_norm: List[float]
    seconds: float
    final_avg_state: Any = None    # averaged client state at the last step


@dataclasses.dataclass
class FedDriver:
    problem: BilevelProblem
    fed: FedConfig
    n_clients: int
    batch_fn: Callable[[int, int], Dict[str, Any]]   # (client, step) -> batches
    init_xy: Callable[[jax.Array], Any]              # key -> (xp, yp)
    metric_fn: Optional[Callable[..., float]] = None  # (x̄, ȳ) -> scalar
    grad_norm_fn: Optional[Callable[..., float]] = None
    algorithm: str = "adafbio"
    # partial participation: fraction of clients active per round (between
    # syncs); inactive clients hold state and are excluded from the average.
    participation: float = 1.0
    track_consensus: bool = False
    # "eager": one jitted call per local step (seed behaviour).
    # "scan":  the fused round engine — q local steps + sync compiled as ONE
    #          program per communication round (repro.fed.round).
    engine: str = "eager"

    def __post_init__(self):
        from repro.fed.round import ENGINES
        if self.engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}, "
                             f"got {self.engine!r}")
        self.alg: Algorithm = make_algorithm(self.algorithm, self.fed,
                                             self.problem)
        self.consensus_log = []
        self.round_seconds: List[float] = []   # per-round wall-clock (scan)

    def _batches(self, step: int):
        per_client = [self.batch_fn(m, step) for m in range(self.n_clients)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per_client)

    # -------------------------------------------------- shared pieces

    def _init_run(self, key):
        m = self.n_clients
        fed = self.alg.fed
        xp, yp = self.init_xy(key)
        batches0 = self._batches(0)

        def init_one(k, b):
            return self.alg.init_client_state(xp, yp, b, k)
        states = jax.vmap(init_one)(jax.random.split(key, m), batches0)
        server = self.alg.init_server_state(xp)
        if fed.adaptive != "none":
            from repro.core.adafbio import warm_adaptive
            server = warm_adaptive(server, tree_mean_axis0(states), fed)
        return states, server

    def _local_body(self, states, server, batches, key, active):
        m = self.n_clients
        t = server["t"]
        def one(st, b, i):
            kk = jax.random.fold_in(jax.random.fold_in(key, i), t)
            return self.alg.local_step(st, server["adaptive"], b, kk, t, m)
        new = jax.vmap(one)(states, batches, jnp.arange(m))
        # partial participation: inactive clients hold their state
        new = jax.tree.map(
            lambda a, b_: jnp.where(
                active.reshape((m,) + (1,) * (a.ndim - 1)), a, b_),
            new, states)
        srv = dict(server)
        srv["t"] = t + 1
        return new, srv

    def _sync_body(self, states, server, active):
        m = self.n_clients
        w = active.astype(jnp.float32)
        w = w / jnp.maximum(w.sum(), 1.0)
        avg = jax.tree.map(
            lambda a: jnp.tensordot(w, a.astype(jnp.float32),
                                    axes=1).astype(a.dtype), states)
        new_client, new_server = self.alg.sync_update(server, avg, m)
        return tree_bcast_axis0(new_client, m), new_server

    def _active_mask(self, round_id):
        m = self.n_clients
        if self.participation >= 1.0:
            return jnp.ones((m,), bool)
        k = jax.random.fold_in(jax.random.PRNGKey(23), round_id)
        n_active = max(int(self.participation * m), 1)
        perm = jax.random.permutation(k, m)
        return jnp.zeros((m,), bool).at[perm[:n_active]].set(True)

    def _record(self, res: RunResult, states, step, samples, comms):
        avg = tree_mean_axis0(states)
        res.steps.append(step)
        res.samples.append(samples)
        res.comms.append(comms)
        res.metric.append(float(self.metric_fn(avg["x"], avg["y"]))
                          if self.metric_fn else float("nan"))
        res.grad_norm.append(float(self.grad_norm_fn(avg["x"], avg["y"]))
                             if self.grad_norm_fn else float("nan"))

    # -------------------------------------------------- run loops

    def run(self, total_steps: int, key=None, eval_every: int = 10) -> RunResult:
        key = key if key is not None else jax.random.PRNGKey(0)
        if self.engine == "scan":
            return self._run_scan(total_steps, key, eval_every)
        fed = self.alg.fed
        states, server = self._init_run(key)
        samples = fed.q * (fed.neumann_k + 2)
        comms = 0

        local = jax.jit(self._local_body)
        sync = jax.jit(self._sync_body)

        res = RunResult(self.alg.name, [], [], [], [], [], 0.0)
        t0 = time.time()
        r0 = time.time()
        for t in range(total_steps):
            rnd = t // fed.q
            active = self._active_mask(rnd)
            if t > 0 and t % fed.q == 0:
                if self.track_consensus:
                    from repro.core.metrics import consensus_error
                    ce = consensus_error(states)
                    self.consensus_log.append(
                        {"step": t, **{k: float(v) for k, v in ce.items()}})
                states, server = sync(states, server,
                                      self._active_mask(rnd - 1))
                comms += 1
            states, server = local(states, server, self._batches(t), key,
                                   active)
            samples += fed.neumann_k + 2
            if (t + 1) % fed.q == 0:
                # per-round wall-clock, comparable with the scan engine's
                jax.block_until_ready(states)
                self.round_seconds.append(time.time() - r0)
                r0 = time.time()
            if t % eval_every == 0 or t == total_steps - 1:
                self._record(res, states, t, samples, comms)
        res.seconds = time.time() - t0
        res.final_avg_state = tree_mean_axis0(states)
        return res

    def _run_scan(self, total_steps: int, key, eval_every: int) -> RunResult:
        """Fused round engine: each communication round runs as ONE jitted
        program, shaped exactly like the eager loop — the sync that closes
        the PREVIOUS round, then this round's local steps as a ``lax.scan``.
        Same per-step math, same fold_in(t) RNG keys, same step count (a
        trailing partial round scans the remainder), and every recorded state
        is post-local/pre-sync like the eager loop's — only the eval
        granularity is per-round instead of per-step.
        """
        from repro.fed.round import make_round_step
        if self.track_consensus:
            raise ValueError("track_consensus needs engine='eager' (it reads "
                             "pre-sync client states mid-round)")
        fed = self.alg.fed
        q = fed.q
        states, server = self._init_run(key)
        samples = fed.q * (fed.neumann_k + 2)
        comms = 0

        @functools.partial(jax.jit, static_argnames=("n_steps", "sync_first"))
        def segment(states, server, batches_q, kk, active_prev, active, *,
                    n_steps, sync_first):
            if sync_first:
                states, server = self._sync_body(states, server, active_prev)
            local = lambda st, srv, b, k: self._local_body(st, srv, b, k,
                                                           active)
            return make_round_step(local, lambda st, srv: (st, srv),
                                   n_steps)(states, server, batches_q, kk)

        full, rem = divmod(total_steps, q)
        lengths = [q] * full + ([rem] if rem else [])
        eval_rounds = max(eval_every // q, 1)
        res = RunResult(self.alg.name, [], [], [], [], [], 0.0)
        t0 = time.time()
        t = 0
        for r, n_steps in enumerate(lengths):
            batches_q = tree_stack([self._batches(t + j)
                                    for j in range(n_steps)])
            r0 = time.time()
            states, server = segment(
                states, server, batches_q, key,
                self._active_mask(r - 1), self._active_mask(r),
                n_steps=n_steps, sync_first=r > 0)
            jax.block_until_ready(states)
            self.round_seconds.append(time.time() - r0)
            t += n_steps
            samples += n_steps * (fed.neumann_k + 2)
            if r > 0:
                comms += 1
            if r % eval_rounds == 0 or r == len(lengths) - 1:
                self._record(res, states, t - 1, samples, comms)
        res.seconds = time.time() - t0
        res.final_avg_state = tree_mean_axis0(states)
        return res
