"""Federated data hyper-cleaning (paper Problem (4) / Section 6.2).

UL variable x^m ∈ R^{n_train}: per-sample weights through σ(·) on client m.
LL variable y ∈ R^{(feat+1) x classes}: shared linear classifier + L2 reg.
Closed-form diagnostics: the LL is strongly convex, so y*(x) and the TRUE
hypergradient ∇F(x) are computable by direct solve — we report the paper's
ε-stationarity metric E‖∇F(x̄)‖ exactly.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.paper_tasks import HyperCleanConfig
from repro.core.bilevel import BilevelProblem, softmax_xent
from repro.data.hyperclean import HyperCleanData


def _logits(y, a):
    w, b = y["w"], y["b"]
    return a @ w + b


def _ce(logits, labels):
    return softmax_xent(logits, labels)


def build_hyperclean(cfg: HyperCleanConfig):
    data = HyperCleanData(cfg.n_clients, cfg.n_train_per_client,
                          cfg.n_val_per_client, cfg.feat_dim, cfg.n_classes,
                          cfg.corrupt_frac)
    ds = data.all_clients()        # stacked [M, ...]

    def g(xp, yp, batch):
        """Weighted train loss + strongly convex reg.

        xp: the GLOBAL weight table [M, n_train] (problem (4)'s x is the
        concatenation over clients; client m's loss touches block m only)."""
        m = batch["client"]
        idx = batch["idx"]
        a = ds["a_tr"][m][idx]
        b = ds["b_tr"][m][idx]
        wgt = jax.nn.sigmoid(xp[m][idx])
        logits = _logits(yp, a)
        lf = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(lf, axis=-1)
        iota = jnp.arange(lf.shape[-1])
        ll = jnp.sum(jnp.where(iota == b[:, None], lf, 0.0), axis=-1)
        per = lse - ll
        reg = cfg.nu * (jnp.sum(yp["w"] ** 2) + jnp.sum(yp["b"] ** 2))
        return jnp.mean(wgt * per) + reg

    def f(xp, yp, batch):
        m = batch["client"]
        idx = batch["vidx"]
        return _ce(_logits(yp, ds["a_val"][m][idx]), ds["b_val"][m][idx])

    problem = BilevelProblem(f=f, g=g)

    def init_xy(key):
        xp = jnp.zeros((cfg.n_clients, cfg.n_train_per_client), jnp.float32)
        k1, k2 = jax.random.split(key)
        yp = {"w": 0.01 * jax.random.normal(k1, (cfg.feat_dim, cfg.n_classes)),
              "b": jnp.zeros((cfg.n_classes,), jnp.float32)}
        return xp, yp

    def batch_fn(client: int, step: int) -> Dict:
        key = jax.random.fold_in(jax.random.fold_in(
            jax.random.PRNGKey(17), client), step)
        ks = jax.random.split(key, 3 + cfg.fed.neumann_k)
        bs = cfg.batch
        idx = jax.random.randint(ks[0], (bs,), 0, cfg.n_train_per_client)
        vidx = jax.random.randint(ks[1], (bs,), 0, cfg.n_val_per_client)
        i0 = jax.random.randint(ks[2], (bs,), 0, cfg.n_train_per_client)
        gi = jnp.stack([jax.random.randint(k, (bs,), 0, cfg.n_train_per_client)
                        for k in ks[3:]])
        cid = jnp.int32(client)
        mk = lambda i: {"client": cid, "idx": i, "vidx": vidx}
        return {"g": mk(idx), "g0": mk(i0), "f": mk(idx),
                "gi": {"client": jnp.full((cfg.fed.neumann_k,), client, jnp.int32),
                       "idx": gi,
                       "vidx": jnp.tile(vidx, (cfg.fed.neumann_k, 1))}}

    # ---------------- exact diagnostics (full-batch, all clients) -----------

    def _flat_y(yp):
        return jnp.concatenate([yp["w"].reshape(-1), yp["b"].reshape(-1)])

    def _unflat_y(vec):
        nw = cfg.feat_dim * cfg.n_classes
        return {"w": vec[:nw].reshape(cfg.feat_dim, cfg.n_classes),
                "b": vec[nw:]}

    def g_full(x_all, y_vec):
        """Global LL objective (mean over clients, full batches).
        x_all: [M, n_train]."""
        yp = _unflat_y(y_vec)
        total = 0.0
        for m in range(cfg.n_clients):
            wgt = jax.nn.sigmoid(x_all[m])
            logits = _logits(yp, ds["a_tr"][m])
            lf = logits.astype(jnp.float32)
            lse = jax.scipy.special.logsumexp(lf, axis=-1)
            iota = jnp.arange(lf.shape[-1])
            ll = jnp.sum(jnp.where(iota == ds["b_tr"][m][:, None], lf, 0.0), -1)
            total = total + jnp.mean(wgt * (lse - ll))
            total = total + cfg.nu * (jnp.sum(yp["w"] ** 2) + jnp.sum(yp["b"] ** 2))
        return total / cfg.n_clients

    def f_full(y_vec):
        yp = _unflat_y(y_vec)
        losses = [
            _ce(_logits(yp, ds["a_val"][m]), ds["b_val"][m])
            for m in range(cfg.n_clients)]
        return jnp.mean(jnp.stack(losses))

    @jax.jit
    def solve_y_star(x_all, y0_vec):
        """Newton on the strongly convex LL."""
        def newton(y, _):
            grad = jax.grad(g_full, argnums=1)(x_all, y)
            hess = jax.hessian(g_full, argnums=1)(x_all, y)
            return y - jnp.linalg.solve(hess, grad), None
        y, _ = jax.lax.scan(newton, y0_vec, None, length=12)
        return y

    @jax.jit
    def true_grad_norm(x_all, yp):
        y0 = _flat_y(yp)
        ys = solve_y_star(x_all, y0)
        gy_f = jax.grad(f_full)(ys)
        hess = jax.hessian(g_full, argnums=1)(x_all, ys)
        lam = jnp.linalg.solve(hess, gy_f)
        # dF/dx = - (d²g/dx dy) λ (∇x f = 0 here)
        def gy_of_x(x_all_):
            return jax.grad(g_full, argnums=1)(x_all_, ys)
        _, vjp = jax.vjp(gy_of_x, x_all)
        mixed = vjp(lam)[0]
        return jnp.linalg.norm(-mixed)

    @jax.jit
    def val_loss(x_all, yp):
        y0 = _flat_y(yp)
        ys = solve_y_star(x_all, y0)
        return f_full(ys)

    return dict(problem=problem, init_xy=init_xy, batch_fn=batch_fn,
                data=ds, cfg=cfg, true_grad_norm=true_grad_norm,
                val_loss=val_loss)
