"""Federated hyper-representation learning (paper Problem (3) / Section 6.1).

x: shared representation MLP (in -> hidden -> rep); y: per-client linear
heads, stacked [M, rep, classes] (the paper's y = (y^1;...;y^M), each g^m
touching only block m + the strongly convex regularizer)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.paper_tasks import HyperRepConfig
from repro.core.bilevel import BilevelProblem, softmax_xent


def build_hyperrep(cfg: HyperRepConfig):
    proto_key = jax.random.PRNGKey(42)
    protos = jax.random.normal(proto_key, (cfg.n_classes, cfg.in_dim))

    def client_sample(client, step, split, n):
        """Non-iid synthetic classification sample (client-specific rotation)."""
        kc = jax.random.fold_in(jax.random.PRNGKey(5), client)
        rot = jnp.eye(cfg.in_dim) + 0.2 * jax.random.normal(
            kc, (cfg.in_dim, cfg.in_dim)) / jnp.sqrt(cfg.in_dim)
        key = jax.random.fold_in(jax.random.fold_in(kc, step), split)
        ka, kb = jax.random.split(key)
        labels = jax.random.randint(ka, (n,), 0, cfg.n_classes)
        feats = protos[labels] @ rot + 0.3 * jax.random.normal(
            kb, (n, cfg.in_dim))
        return feats.astype(jnp.float32), labels

    def rep(xp, a):
        h = jnp.tanh(a @ xp["w1"] + xp["b1"])
        return jnp.tanh(h @ xp["w2"] + xp["b2"])

    def _loss(xp, yp, batch):
        m = batch["client"]
        r = rep(xp, batch["a"])
        logits = r @ yp["heads"][m]
        return softmax_xent(logits, batch["b"])

    def g(xp, yp, batch):
        from repro.core.tree_util import tree_sqnorm
        return _loss(xp, yp, batch) + 0.5 * cfg.fed.nu * tree_sqnorm(yp)

    def f(xp, yp, batch):
        return _loss(xp, yp, batch)

    problem = BilevelProblem(f=f, g=g)

    def init_xy(key):
        k1, k2 = jax.random.split(key)
        s1 = 1.0 / jnp.sqrt(cfg.in_dim)
        s2 = 1.0 / jnp.sqrt(cfg.hidden)
        xp = {"w1": s1 * jax.random.normal(k1, (cfg.in_dim, cfg.hidden)),
              "b1": jnp.zeros((cfg.hidden,)),
              "w2": s2 * jax.random.normal(k2, (cfg.hidden, cfg.rep_dim)),
              "b2": jnp.zeros((cfg.rep_dim,))}
        yp = {"heads": jnp.zeros((cfg.n_clients, cfg.rep_dim, cfg.n_classes))}
        return xp, yp

    def batch_fn(client: int, step: int) -> Dict:
        cid = jnp.int32(client)
        K = cfg.fed.neumann_k

        def mk(split, n):
            a, b = client_sample(client, step, split, n)
            return {"client": cid, "a": a, "b": b}

        gi_batches = [mk(10 + i, cfg.batch) for i in range(K)]
        gi = jax.tree.map(lambda *xs: jnp.stack(xs), *gi_batches)
        return {"g": mk(0, cfg.batch), "g0": mk(1, cfg.batch),
                "f": mk(2, cfg.batch), "gi": gi}

    def val_loss(xp, yp):
        losses = []
        for m in range(cfg.n_clients):
            a, b = client_sample(m, 999_999, 3, 256)
            r = rep(xp, a)
            losses.append(softmax_xent(r @ yp["heads"][m], b))
        return jnp.mean(jnp.stack(losses))

    return dict(problem=problem, init_xy=init_xy, batch_fn=batch_fn,
                val_loss=jax.jit(val_loss), cfg=cfg)
