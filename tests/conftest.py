import os

# Tests see the real single CPU device (the 512-device override lives ONLY in
# repro.launch.dryrun). Force deterministic, quiet JAX.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
