import os

# Tests see the real single CPU device (the 512-device override lives ONLY in
# repro.launch.dryrun). Force deterministic, quiet JAX.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# ... except that the host platform is split into TWO devices so the
# mesh-sharded paths (tests/test_mesh_async.py) run on a real multi-device
# mesh. Single-device tests are unaffected: default placement stays device 0.
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
