"""Algorithm 1 mechanics: adaptive matrices, schedules, STORM, sync."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FedConfig
from repro.core import adafbio, adaptive as ada
from repro.core.bilevel import quadratic_bilevel_problem
from repro.core.tree_util import (tree_bcast_axis0, tree_mean_axis0, tree_sub,
                                  tree_vdot)


def _rand_tree(key, shapes):
    ks = jax.random.split(key, len(shapes))
    return {f"p{i}": jax.random.normal(k, s)
            for i, (k, s) in enumerate(zip(ks, shapes))}


def test_adaptive_matrices_assumption6():
    """A_t >= rho I and rho <= b_t <= b_max by construction."""
    key = jax.random.PRNGKey(0)
    x = _rand_tree(key, [(8, 4), (16,)])
    for kind in ("adam", "adabelief"):
        st = ada.init_adaptive_state(x, kind)
        for i in range(5):
            w = _rand_tree(jax.random.fold_in(key, i), [(8, 4), (16,)])
            v = _rand_tree(jax.random.fold_in(key, 100 + i), [(3,)])
            st = ada.update_adaptive(st, w, v, kind=kind, varrho=0.9)
        for a in jax.tree.leaves(st["a"]):
            assert (a >= 0).all()
        assert 0 <= float(st["b"]) <= 1e3
        # preconditioning never amplifies by more than 1/rho
        rho = 0.1
        out = ada.precondition_x(st, w, kind=kind, rho=rho)
        for o, wi in zip(jax.tree.leaves(out), jax.tree.leaves(w)):
            assert (jnp.abs(o) <= jnp.abs(wi) / rho + 1e-5).all()


def test_nonadaptive_is_identity():
    key = jax.random.PRNGKey(0)
    w = _rand_tree(key, [(4, 4)])
    st = ada.init_adaptive_state(w, "none")
    out = ada.precondition_x(st, w, kind="none", rho=1.0)
    np.testing.assert_allclose(np.asarray(out["p0"]), np.asarray(w["p0"]))


def test_eta_alpha_beta_schedules():
    fed = FedConfig(eta_k=1.0, eta_n=64.0, alpha_c1=4.0, beta_c2=4.0)
    for t in (0, 10, 1000):
        eta = adafbio.eta_t(fed, jnp.int32(t), m=8)
        a, b = adafbio.alpha_beta(fed, eta)
        assert 0 < float(eta) <= 1.0
        assert 0 < float(a) <= 1.0 and 0 < float(b) <= 1.0
    # eta decreasing in t
    e1 = adafbio.eta_t(fed, jnp.int32(1), 8)
    e2 = adafbio.eta_t(fed, jnp.int32(100), 8)
    assert float(e2) < float(e1)


def test_param_update_eq14():
    """Interpolated two-stage update (Eqs. 12-13) == direct Eq. 14."""
    fed = FedConfig(adaptive="none", lr_x=0.1, lr_y=0.2)
    key = jax.random.PRNGKey(1)
    x = _rand_tree(key, [(5, 3)])
    y = _rand_tree(jax.random.fold_in(key, 1), [(4,)])
    w = _rand_tree(jax.random.fold_in(key, 2), [(5, 3)])
    v = _rand_tree(jax.random.fold_in(key, 3), [(4,)])
    st = ada.init_adaptive_state(x, "none")
    eta = 0.37
    x2, y2 = adafbio.param_update(fed, st, x, y, v, w, eta)
    # two-stage: x_hat = x - lr*w ; x' = x + eta (x_hat - x)
    x_ref = jax.tree.map(lambda p, d: p - eta * fed.lr_x * d, x, w)
    y_ref = jax.tree.map(lambda p, d: p - eta * fed.lr_y * d, y, v)
    np.testing.assert_allclose(np.asarray(x2["p0"]), np.asarray(x_ref["p0"]),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(y2["p0"]), np.asarray(y_ref["p0"]),
                               rtol=1e-5)


def test_storm_alpha1_is_sgd():
    """alpha = beta = 1 -> the estimator equals the fresh gradient (no VR)."""
    d, p = 4, 3
    key = jax.random.PRNGKey(0)
    H = jnp.eye(p) * 2.0
    Bm = jax.random.normal(key, (p, d)) * 0.3
    prob = quadratic_bilevel_problem(H, Bm, jnp.ones(p), jnp.eye(d))
    fed = FedConfig(adaptive="none", neumann_k=4, theta=0.5)
    x = jnp.ones(d)
    y = jnp.ones(p)
    state = {"x": x, "y": y, "v": 100 * jnp.ones(p), "w": 100 * jnp.ones(d)}
    batches = {"f": 0, "g": 0, "g0": 0, "gi": jnp.zeros((4,))}
    v_new, w_new = adafbio.storm_refresh(prob, fed, state, x, y, batches,
                                         jax.random.PRNGKey(1), alpha=1.0,
                                         beta=1.0)
    g_fresh = jax.grad(prob.g, argnums=1)(x, y, 0)
    np.testing.assert_allclose(np.asarray(v_new), np.asarray(g_fresh),
                               rtol=1e-5)
    assert float(jnp.abs(w_new).max()) < 50  # old '100' estimate fully dropped


def test_sync_broadcast_consistency():
    """After a sync step all clients hold identical state == server update of
    the client mean."""
    fed = FedConfig(adaptive="adam", lr_x=0.1, lr_y=0.1)
    key = jax.random.PRNGKey(0)
    m = 4
    one = {"x": _rand_tree(key, [(6,)]), "y": _rand_tree(key, [(3,)]),
           "v": _rand_tree(jax.random.fold_in(key, 1), [(3,)]),
           "w": _rand_tree(jax.random.fold_in(key, 2), [(6,)])}
    states = jax.tree.map(
        lambda a: a[None] + 0.1 * jax.random.normal(key, (m,) + a.shape), one)
    server = adafbio.init_server_state(one["x"], fed)
    avg = tree_mean_axis0(states)
    new_client, new_server = adafbio.sync_update(fed, server, avg, m)
    bcast = tree_bcast_axis0(new_client, m)
    for leaf in jax.tree.leaves(bcast):
        for i in range(1, m):
            np.testing.assert_allclose(np.asarray(leaf[0]),
                                       np.asarray(leaf[i]))
    assert int(new_server["t"]) == int(server["t"]) + 1
    # estimators pass through the average untouched (analysis base case)
    np.testing.assert_allclose(np.asarray(new_client["v"]["p0"]),
                               np.asarray(avg["v"]["p0"]), rtol=1e-6)
