"""Per-assigned-architecture smoke tests: REDUCED variant of the same family
(2 layers, d_model<=256, <=4 experts) — forward + one AdaFBiO train step on
CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FedConfig, get_arch, list_arch_ids, reduced
from repro.configs.base import ShapeConfig
from repro.fed.runtime import FederatedTrainer, client_batch_specs
from repro.models import ModelCtx, forward, init_params, model_specs

B, S = 2, 32


def _batch_for(cfg, key):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.n_prefix_embeds:
        batch["prefix_embeds"] = jnp.zeros(
            (B, cfg.n_prefix_embeds, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        batch["enc_embeds"] = (jax.random.normal(key, (B, S, cfg.d_model))
                               .astype(jnp.bfloat16))
    return batch


@pytest.mark.parametrize("arch_id", list_arch_ids())
def test_reduced_forward(arch_id):
    cfg = reduced(get_arch(arch_id))
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0), cfg.dtype)
    logits = forward(cfg, params, _batch_for(cfg, jax.random.PRNGKey(1)),
                     ModelCtx(kind="train"))
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()


@pytest.mark.slow
@pytest.mark.parametrize("arch_id", list_arch_ids())
def test_reduced_train_step(arch_id):
    cfg = reduced(get_arch(arch_id))
    fed = FedConfig(q=2, neumann_k=2, lr_x=1e-2, lr_y=1e-1)
    shape = ShapeConfig("t", S, B, "train")
    tr = FederatedTrainer(cfg, fed, shape, mesh=None)
    specs, _ = client_batch_specs(cfg, shape, tr.m, fed)
    key = jax.random.PRNGKey(0)
    batch = {k: (jax.random.randint(key, v.shape, 0, cfg.vocab)
                 if v.dtype == jnp.int32 else jnp.zeros(v.shape, v.dtype))
             for k, v in specs.items()}
    states, server = tr.init_states(key, batch)
    states, server = jax.jit(tr.local_step_fn())(states, server, batch, key)
    states, server = jax.jit(tr.sync_step_fn())(states, server)
    for path, leaf in jax.tree_util.tree_leaves_with_path(states):
        arr = np.asarray(leaf, dtype=np.float32)
        assert np.isfinite(arr).all(), (arch_id, path)
    assert int(server["t"]) == 2
