"""Asynchronous execution layer (repro.fed.population.make_async_round):
degenerate async must reproduce the synchronous population path, bounded
staleness must gate, cohorts must genuinely overlap, and delay-adaptive
eta_t must scale the server step — all as one jitted program per round."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import PopulationConfig
from repro.fed.population import (NEVER, init_async_state, make_async_round,
                                  scatter_where)
from repro.fed.sampling import UniformSampler
from tests.test_system import _quad_driver


INF = float("inf")


def _toy_round(**kw):
    """Toy algorithm: local step adds 1, sync returns the plain aggregate."""
    def local(states, server, batch, key, ids):
        return jax.tree.map(lambda a: a + 1.0, states), server

    def sync(server, avg):
        return avg, server
    return make_async_round(local, sync, q=2, **kw)


def _toy_state(n=5):
    return init_async_state({"x": jnp.zeros((n,))}, {}, n)


# --------------------------------------------------- strict-superset parity

def test_degenerate_async_matches_sync_population():
    """max_delay=1, max_staleness=inf, delay_eta=0: every dispatch returns
    next round with staleness 1 — the async program must reproduce the
    synchronous population trajectory (async is a strict superset)."""
    runs = {}
    for name, pcfg in [
        ("sync", PopulationConfig(n=4, cohort=2)),
        ("async", PopulationConfig(n=4, cohort=2, max_staleness=INF)),
    ]:
        d = _quad_driver("adafbio")
        d.sampler = UniformSampler(4, 2, jax.random.PRNGKey(9))
        d.population = pcfg
        runs[name] = d.run(16, eval_every=4)
    for a, b in zip(jax.tree.leaves(runs["sync"].final_avg_state),
                    jax.tree.leaves(runs["async"].final_avg_state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, rtol=1e-6)
    np.testing.assert_allclose(runs["sync"].grad_norm, runs["async"].grad_norm,
                               atol=1e-5, rtol=1e-5)
    assert runs["sync"].comms[-1] == runs["async"].comms[-1]
    assert runs["sync"].samples[-1] == runs["async"].samples[-1]


def test_max_staleness_zero_routes_to_sync_path():
    """The OFF switch: max_staleness=0 never enters the async program (no
    staleness_log is produced) and matches the plain population run."""
    d = _quad_driver("adafbio")
    d.population = PopulationConfig(n=4, cohort=2, max_staleness=0.0)
    d.run(8, eval_every=8)
    assert not hasattr(d, "staleness_log")


# --------------------------------------------------- toy-round mechanics

def test_async_round_pending_buffer_and_delayed_arrival():
    """A dispatched update sits in `pending` until its return round, then
    aggregates and broadcasts; the bank mirrors the local state meanwhile."""
    round_fn = jax.jit(_toy_round(max_staleness=INF, max_delay=1))
    state = _toy_state(n=5)
    ids = jnp.asarray([3, 0], jnp.int32)
    kk = jax.random.PRNGKey(0)

    state, stats = round_fn(state, ids, jnp.zeros((2,)), kk, jnp.int32(0))
    # round 0: nothing arrives, both dispatch; update parked in pending
    assert int(stats["arrived"]) == 0 and int(stats["dispatched"]) == 2
    np.testing.assert_array_equal(np.asarray(state["in_flight"]),
                                  [True, False, False, True, False])
    np.testing.assert_array_equal(np.asarray(state["pending"]["x"]),
                                  [2.0, 0.0, 0.0, 2.0, 0.0])
    np.testing.assert_array_equal(np.asarray(state["bank"]["x"]),
                                  [2.0, 0.0, 0.0, 2.0, 0.0])
    np.testing.assert_array_equal(np.asarray(state["return_round"]),
                                  [1, NEVER, NEVER, 1, NEVER])
    # server untouched: no arrivals yet
    np.testing.assert_array_equal(np.asarray(state["last_sync"]), 0)

    state, stats = round_fn(state, ids, jnp.zeros((2,)), kk, jnp.int32(1))
    # round 1: both arrive with staleness 1, aggregate (2.0) broadcasts,
    # then the same cohort redispatches from the fresh model
    assert int(stats["arrived"]) == 2 and int(stats["accepted"]) == 2
    np.testing.assert_allclose(float(stats["mean_staleness"]), 1.0)
    np.testing.assert_array_equal(np.asarray(state["bank"]["x"]),
                                  [4.0, 2.0, 2.0, 4.0, 2.0])
    np.testing.assert_array_equal(np.asarray(state["anchor"]["x"]), 2.0)
    np.testing.assert_array_equal(np.asarray(state["last_sync"]), 1)


def test_async_round_overlapping_cohort_skips_in_flight():
    """With max_delay large, a client sampled while in flight is ineligible:
    its pending update is NOT recomputed and its flight bookkeeping holds."""
    round_fn = jax.jit(_toy_round(max_staleness=INF, max_delay=4))
    state = _toy_state(n=5)
    # pin delays: dispatch at round 0 with delay in [1,4] — run until arrival
    ids = jnp.asarray([3, 0], jnp.int32)
    kk = jax.random.PRNGKey(1)
    state, s0 = round_fn(state, ids, jnp.zeros((2,)), kk, jnp.int32(0))
    assert int(s0["dispatched"]) == 2
    disp0 = np.asarray(state["dispatch_round"]).copy()
    pend0 = np.asarray(state["pending"]["x"]).copy()
    ret0 = np.asarray(state["return_round"]).copy()
    if (ret0[[0, 3]] > 1).any():
        # at least one of them is still flying at round 1: resampling it
        # must not restart the flight
        state, s1 = round_fn(state, ids, jnp.zeros((2,)), kk, jnp.int32(1))
        still = [i for i in (0, 3) if ret0[i] > 1]
        assert int(s1["dispatched"]) == 2 - len(still)
        np.testing.assert_array_equal(
            np.asarray(state["dispatch_round"])[still], disp0[still])
        np.testing.assert_array_equal(
            np.asarray(state["pending"]["x"])[still], pend0[still])


def test_async_round_bounded_staleness_drops_and_resyncs():
    """An arrival with tau > max_staleness is dropped (its compute never
    reaches the aggregate) but the client still re-syncs to the current
    global model."""
    round_fn = jax.jit(_toy_round(max_staleness=1, max_delay=1,
                                  sync_mode="participants"))
    state = _toy_state(n=4)
    kk = jax.random.PRNGKey(0)
    # manufacture a stale in-flight update for client 2: dispatched at round
    # -5 (tau = 5 at round 0), returning now, with a poisoned value that
    # must never be aggregated
    state["in_flight"] = state["in_flight"].at[2].set(True)
    state["dispatch_round"] = state["dispatch_round"].at[2].set(-5)
    state["return_round"] = state["return_round"].at[2].set(0)
    state["pending"] = {"x": state["pending"]["x"].at[2].set(1e6)}
    # and a fresh one for client 1 (tau = 1), value 10
    state["in_flight"] = state["in_flight"].at[1].set(True)
    state["dispatch_round"] = state["dispatch_round"].at[1].set(-1)
    state["return_round"] = state["return_round"].at[1].set(0)
    state["pending"] = {"x": state["pending"]["x"].at[1].set(10.0)}

    ids = jnp.asarray([0, 3], jnp.int32)
    state, stats = round_fn(state, ids, jnp.zeros((2,)), kk, jnp.int32(0))
    assert int(stats["arrived"]) == 2
    assert int(stats["accepted"]) == 1 and int(stats["dropped"]) == 1
    # aggregate = the fresh update only; both returners re-sync to it
    np.testing.assert_array_equal(np.asarray(state["anchor"]["x"]), 10.0)
    np.testing.assert_array_equal(np.asarray(state["bank"]["x"])[[1, 2]],
                                  [10.0, 10.0])
    # accepted-staleness vector marks only the accepted arrival
    np.testing.assert_array_equal(np.asarray(stats["staleness"]),
                                  [-1, 1, -1, -1])
    assert not np.asarray(state["in_flight"])[[1, 2]].any()


def test_async_round_no_arrivals_leaves_server_alone():
    """A round with zero arrivals must not move the server, the anchor, or
    anyone's last_sync (the where-gated sync_update is fully discarded)."""
    def sync(server, avg):
        return avg, {"calls": server["calls"] + 1}
    def local(states, server, batch, key, ids):
        return jax.tree.map(lambda a: a + 1.0, states), server
    round_fn = jax.jit(make_async_round(local, sync, q=1, max_staleness=INF,
                                        max_delay=3))
    state = init_async_state({"x": jnp.arange(4.0)}, {"calls": jnp.int32(0)},
                             4)
    state, stats = round_fn(state, jnp.asarray([1], jnp.int32),
                            jnp.zeros((1,)), jax.random.PRNGKey(0),
                            jnp.int32(0))
    assert int(stats["arrived"]) == 0
    assert int(state["server"]["calls"]) == 0
    np.testing.assert_allclose(float(state["anchor"]["x"]), 1.5)
    np.testing.assert_array_equal(np.asarray(state["last_sync"]), 0)


def test_delay_adaptive_eta_scales_server_movement():
    """delay_eta > 0: the model movement shrinks by
    1/(1 + delay_eta*(mean_tau - 1)); tau = 1 arrivals are unscaled."""
    for tau, want_scale in [(1, 1.0), (3, 0.5)]:
        round_fn = jax.jit(_toy_round(max_staleness=INF, max_delay=1,
                                      delay_eta=0.5))
        state = _toy_state(n=3)
        state["in_flight"] = state["in_flight"].at[0].set(True)
        state["dispatch_round"] = state["dispatch_round"].at[0].set(-tau)
        state["return_round"] = state["return_round"].at[0].set(0)
        state["pending"] = {"x": state["pending"]["x"].at[0].set(8.0)}
        state, stats = round_fn(state, jnp.asarray([1, 2], jnp.int32),
                                jnp.zeros((2,)), jax.random.PRNGKey(0),
                                jnp.int32(0))
        np.testing.assert_allclose(float(stats["eta_scale"]), want_scale)
        # anchor starts at 0 (bank mean of zeros): movement toward 8.0
        np.testing.assert_allclose(float(state["anchor"]["x"]),
                                   8.0 * want_scale)


def test_delay_eta_changes_trajectory_on_quadratic():
    """End-to-end: with real delays, delay-adaptive stepping produces a
    different (finite) trajectory than the unscaled async run."""
    outs = {}
    for eta in (0.0, 2.0):
        d = _quad_driver("adafbio", m=8)
        d.sampler = UniformSampler(8, 3, jax.random.PRNGKey(3))
        d.population = PopulationConfig(n=8, cohort=3, max_staleness=INF,
                                        max_delay=3, delay_eta=eta)
        outs[eta] = d.run(24, eval_every=24)
        assert np.isfinite(outs[eta].grad_norm).all()
    a = np.concatenate([np.asarray(l).ravel() for l in
                        jax.tree.leaves(outs[0.0].final_avg_state)])
    b = np.concatenate([np.asarray(l).ravel() for l in
                        jax.tree.leaves(outs[2.0].final_avg_state)])
    assert not np.allclose(a, b, atol=1e-6)


# --------------------------------------------------- driver-level behaviour

def test_async_driver_gates_and_reports_staleness():
    """FedDriver async run: staleness histogram only holds accepted taus
    <= max_staleness, the log accounts every arrival, and overlap shows up
    as rounds with fewer dispatches than cohort slots."""
    d = _quad_driver("adafbio", m=8)
    d.population = PopulationConfig(n=8, cohort=3, max_staleness=2,
                                    max_delay=3)
    r = d.run(48, eval_every=12)
    assert np.isfinite(r.grad_norm).all()
    log = d.staleness_log
    assert len(log) == 12
    assert sum(s["dropped"] for s in log) > 0          # tau=3 arrivals exist
    assert any(s["dispatched"] < 3 for s in log)       # overlapping cohorts
    # histogram: accepted arrivals only, staleness within the bound
    assert d.staleness_hist.sum() == sum(s["accepted"] for s in log)
    assert d.staleness_hist.size <= 3                  # taus 1..2 only
    assert d.staleness_hist[0] == 0                    # tau >= 1 always
    # arrivals are conserved: accepted + dropped == arrived
    assert all(s["accepted"] + s["dropped"] == s["arrived"] for s in log)


def test_async_config_validation():
    with pytest.raises(ValueError):
        PopulationConfig(n=8, cohort=2, max_delay=3)       # async knob, off
    with pytest.raises(ValueError):
        PopulationConfig(n=8, cohort=2, delay_eta=0.5)     # async knob, off
    with pytest.raises(ValueError):
        PopulationConfig(n=8, cohort=2, max_staleness=-1.0)
    with pytest.raises(ValueError):
        PopulationConfig(n=8, cohort=2, max_staleness=1, max_delay=0)
    with pytest.raises(ValueError):
        PopulationConfig(n=8, cohort=2, sampler="trace-file")  # needs path
    with pytest.raises(ValueError):
        make_async_round(lambda *a: a, lambda *a: a, q=1, max_staleness=0)
    assert PopulationConfig(n=8, cohort=2,
                            max_staleness=INF).asynchronous
    assert not PopulationConfig(n=8, cohort=2).asynchronous


def test_dispatched_counts_unique_cohort_ids():
    """Regression: a duplicate cohort id (trace-sampler shortfall cycling)
    occupies two slots but dispatches ONE client — `dispatched` must count
    unique clients, matching the single in_flight.at[ids].set(True) mark."""
    round_fn = jax.jit(_toy_round(max_staleness=INF, max_delay=1))
    state = _toy_state(n=5)
    ids = jnp.asarray([2, 2, 0], jnp.int32)
    state, stats = round_fn(state, ids, jnp.zeros((2,)),
                            jax.random.PRNGKey(0), jnp.int32(0))
    assert int(stats["dispatched"]) == 2
    np.testing.assert_array_equal(np.asarray(state["in_flight"]),
                                  [True, False, True, False, False])


def test_sample_counter_parity_sync_async_at_max_delay_one():
    """Regression: the async sample counter scales by dispatched/C; at
    max_delay=1 every cohort slot dispatches every round, so the counter
    must equal the synchronous population run's exactly."""
    runs = {}
    for name, pcfg in [
        ("sync", PopulationConfig(n=6, cohort=3)),
        ("async", PopulationConfig(n=6, cohort=3, max_staleness=INF)),
    ]:
        d = _quad_driver("adafbio", m=6)
        d.sampler = UniformSampler(6, 3, jax.random.PRNGKey(5))
        d.population = pcfg
        runs[name] = d.run(24, eval_every=4)
    assert runs["sync"].samples == runs["async"].samples


def test_async_sample_counter_scales_by_dispatched():
    """Regression: with real overlap (max_delay > 1) some cohort slots are
    masked out and discarded — the recorded samples must follow
    q(K+2) + sum_r n_steps (K+2) dispatched_r / C, strictly fewer than the
    synchronous count whenever any round under-dispatches."""
    d = _quad_driver("adafbio", m=8)
    d.population = PopulationConfig(n=8, cohort=3, max_staleness=INF,
                                    max_delay=3)
    r = d.run(48, eval_every=48)
    fed = d.fed
    k2 = fed.neumann_k + 2
    expect = float(fed.q * k2)
    for s in d.staleness_log:
        expect += fed.q * k2 * s["dispatched"] / 3
    assert abs(r.samples[-1] - expect) <= 1
    assert any(s["dispatched"] < 3 for s in d.staleness_log)
    naive = fed.q * k2 * (len(d.staleness_log) + 1)
    assert r.samples[-1] < naive


def test_scatter_where_masks_rows():
    bank = {"x": jnp.zeros((4, 2))}
    ids = jnp.asarray([2, 0], jnp.int32)
    vals = {"x": jnp.ones((2, 2)) * 7.0}
    out = scatter_where(bank, ids, vals, jnp.asarray([True, False]))
    np.testing.assert_array_equal(np.asarray(out["x"][2]), 7.0)
    np.testing.assert_array_equal(np.asarray(out["x"][0]), 0.0)
