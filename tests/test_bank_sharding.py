"""Sharded client banks (docs/sharding.md): deterministic last-wins
scatter semantics, bank partitioning over the mesh's client axes through
the FedDriver population/async engines, and the host-spill tier
(``repro.fed.spill``) replaying the dense trajectory."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import PopulationConfig
from repro.core.baselines import make_algorithm
from repro.fed.compress import make_codec, zeros_ef
from repro.fed.population import (make_cohort_round, make_population_round,
                                  resolve_last_wins, scatter, scatter_where)
from repro.fed.spill import HostSpillBank, _last_wins_mask
from tests.test_system import _quad_driver

INF = float("inf")


# ------------------------------------------------------- last-wins scatter

def _bank(n=5, d=3):
    return {"x": jnp.arange(n * d, dtype=jnp.float32).reshape(n, d)}


def test_scatter_duplicates_last_wins():
    """The documented contract: with DIFFERENT values on duplicate slots,
    the last slot's value lands — explicitly resolved, not left to XLA's
    unspecified duplicate-index ordering."""
    bank = _bank()
    ids = jnp.asarray([1, 1, 2], jnp.int32)
    vals = {"x": jnp.stack([jnp.full((3,), 10.0), jnp.full((3,), 20.0),
                            jnp.full((3,), 30.0)])}
    out = scatter(bank, ids, vals)
    np.testing.assert_array_equal(out["x"][1], np.full(3, 20.0))
    np.testing.assert_array_equal(out["x"][2], np.full(3, 30.0))
    np.testing.assert_array_equal(out["x"][0], np.asarray(bank["x"][0]))


def test_scatter_where_last_KEPT_duplicate_wins():
    """scatter_where: the winner among duplicates is the last slot whose
    keep flag is True; rows with no kept slot stay untouched."""
    bank = _bank()
    ids = jnp.asarray([1, 1, 2], jnp.int32)
    vals = {"x": jnp.stack([jnp.full((3,), 10.0), jnp.full((3,), 20.0),
                            jnp.full((3,), 30.0)])}
    out = scatter_where(bank, ids, vals,
                        jnp.asarray([True, False, False]))
    np.testing.assert_array_equal(out["x"][1], np.full(3, 10.0))
    np.testing.assert_array_equal(out["x"][2], np.asarray(bank["x"][2]))


def test_resolve_last_wins_jit_deterministic():
    """resolve_last_wins makes every duplicate slot carry the winning
    value, so any .at[ids].set ordering produces the same bank."""
    ids = jnp.asarray([0, 3, 0, 3, 3], jnp.int32)
    vals = {"x": jnp.arange(5.0)[:, None] * jnp.ones((1, 2))}
    res, wins = jax.jit(resolve_last_wins)(ids, vals)
    np.testing.assert_array_equal(np.asarray(wins), np.ones(5, bool))
    # every slot of id 0 carries slot 2's value; of id 3, slot 4's
    np.testing.assert_array_equal(np.asarray(res["x"][:, 0]),
                                  [2.0, 4.0, 2.0, 4.0, 4.0])


# ------------------------------------------------------- driver mesh parity

def _pop_driver(codec, max_staleness, mesh, m=8, steps=12):
    d = _quad_driver("adafbio", m=m)
    if codec != "none":
        d.fed = dataclasses.replace(d.alg.fed, codec=codec, topk_frac=0.5)
        d.alg = make_algorithm("adafbio", d.fed, d.problem)
    d.population = PopulationConfig(
        n=m, cohort=2, max_staleness=max_staleness,
        max_delay=2 if max_staleness else 1)
    d.mesh = mesh
    r = d.run(steps, key=jax.random.PRNGKey(1), eval_every=4)
    return d, r


@pytest.fixture(scope="module")
def two_devices():
    if len(jax.devices()) < 2:
        pytest.skip("needs the 2-way forced host platform (conftest.py)")
    return jax.make_mesh((2, 1), ("data", "model"))


@pytest.mark.parametrize("codec,ms", [
    ("none", 0.0), ("none", INF),
    pytest.param("topk", 0.0, marks=pytest.mark.slow),
    pytest.param("topk", INF, marks=pytest.mark.slow)])
def test_driver_population_mesh_parity(two_devices, codec, ms):
    """FedDriver population/async engines on a 2-device client mesh: same
    trajectory and wire accounting as mesh=None, and the final bank rows
    genuinely partition across the devices (N/2 rows, half the bytes
    each)."""
    d0, r0 = _pop_driver(codec, ms, None)
    d1, r1 = _pop_driver(codec, ms, two_devices)
    np.testing.assert_allclose(np.asarray(r0.grad_norm),
                               np.asarray(r1.grad_norm),
                               rtol=1e-6, atol=1e-7)
    assert r0.bytes_up == r1.bytes_up
    assert r0.bytes_down == r1.bytes_down
    assert r0.comms == r1.comms
    for a, b in zip(jax.tree.leaves(d0.final_bank),
                    jax.tree.leaves(d1.final_bank)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
    n = 8
    for leaf in jax.tree.leaves(d1.final_bank):
        shards = leaf.addressable_shards
        assert len(shards) == 2
        assert sorted(s.data.shape[0] for s in shards) == [n // 2] * 2
        assert sum(s.data.nbytes for s in shards) == leaf.nbytes


def test_driver_population_duplicate_ids_unique_billing():
    """Wire convention: a duplicate cohort id fills two aggregation slots
    but bills ONE uplink message (docs/sharding.md)."""
    d = _quad_driver("adafbio", m=4)
    d.population = PopulationConfig(n=4, cohort=2)

    class Dup:
        def cohort(self, r):
            return jnp.asarray([1, 1], jnp.int32)
    d.sampler = Dup()
    r = d.run(16, eval_every=16)
    from repro.fed.compress import make_codec as _mk
    state = {"x": jnp.zeros((8,)), "y": jnp.zeros((6,)),
             "v": jnp.zeros((6,)), "w": jnp.zeros((8,))}
    msg_b = _mk("none").message_bytes(state)
    comms = r.comms[-1]
    assert comms > 0
    assert r.bytes_up[-1] == comms * 1 * msg_b   # 1 unique transmitter


# ------------------------------------------------------------- host spill

def test_last_wins_mask():
    mask = _last_wins_mask(np.asarray([3, 1, 3, 2, 1]))
    np.testing.assert_array_equal(mask, [False, False, True, True, True])


def _np_bank(n=6, d=2):
    return {"x": np.arange(n * d, dtype=np.float32).reshape(n, d)}


def test_spill_scatter_gather_duplicates():
    b = HostSpillBank(rows=_np_bank(), n=6)
    b.scatter(np.asarray([4, 4]),
              {"x": np.stack([np.full(2, 7.0), np.full(2, 9.0)])})
    out = b.gather(np.asarray([4, 0]))
    np.testing.assert_array_equal(np.asarray(out["x"][0]), np.full(2, 9.0))
    np.testing.assert_array_equal(np.asarray(out["x"][1]), [0.0, 1.0])


def test_spill_broadcast_is_lazy_and_materialize_is_dense():
    b = HostSpillBank(rows=_np_bank(), n=6)
    before = b.rows["x"].copy()
    b.broadcast({"x": np.full(2, 5.0)})
    # lazy: the row storage is untouched, only base/fresh changed
    np.testing.assert_array_equal(b.rows["x"], before)
    assert not b.fresh.any()
    out = b.gather(np.asarray([0, 3]))
    np.testing.assert_array_equal(np.asarray(out["x"]), np.full((2, 2), 5.0))
    # a scatter after the broadcast re-freshens exactly its rows
    b.scatter(np.asarray([2]), {"x": np.full((1, 2), 8.0)})
    dense = b.materialize()
    np.testing.assert_array_equal(dense["x"][2], np.full(2, 8.0))
    np.testing.assert_array_equal(dense["x"][0], np.full(2, 5.0))


def test_spill_prefetch_consumed_and_invalidated():
    b = HostSpillBank(rows=_np_bank(), n=6)
    b.prefetch(np.asarray([1, 2]))
    out = b.gather(np.asarray([1, 2]))       # consumes the prefetch
    np.testing.assert_array_equal(np.asarray(out["x"]),
                                  b.rows["x"][[1, 2]])
    b.prefetch(np.asarray([1, 2]))
    b.scatter(np.asarray([1]), {"x": np.full((1, 2), -1.0)})  # drops it
    out = b.gather(np.asarray([1, 2]))
    np.testing.assert_array_equal(np.asarray(out["x"][0]), np.full(2, -1.0))
    b.prefetch(np.asarray([0, 1]))
    out = b.gather(np.asarray([3, 4]))       # mismatched ids: fresh gather
    np.testing.assert_array_equal(np.asarray(out["x"]),
                                  b.rows["x"][[3, 4]])


# ------------------------------------------- spill vs dense round parity

def _toy_round_pieces(lossy=False):
    """A tiny population round program: the local step moves each cohort
    state by a deterministic function of (global id, batch); the sync
    averages and halves."""
    def local(states, server, batch, key, ids):
        upd = {"x": states["x"] + batch[:, None] * (ids[:, None] + 1.0)}
        return upd, server

    def sync_update(server, avg):
        new_client = {"x": avg["x"] * 0.5 + server["s"]}
        return new_client, {"s": server["s"] + 1.0}

    codec = make_codec("topk", topk_frac=0.5) if lossy else None
    return local, sync_update, codec


@pytest.mark.parametrize("lossy", [False, True])
def test_cohort_round_matches_dense_population_round(lossy):
    """A spilled run (HostSpillBank + make_cohort_round, broadcast
    write-back on host) replays the dense make_population_round trajectory
    bit-for-bit — including duplicate-heavy cohorts and the lossy EF
    path."""
    n, c, q, rounds = 6, 3, 2, 4
    local, sync_update, codec = _toy_round_pieces(lossy)
    dense_round = make_population_round(local, sync_update, q, codec=codec)
    cohort_round = make_cohort_round(local, sync_update, q, codec=codec)
    key = jax.random.PRNGKey(0)

    bank0 = {"x": jnp.arange(n * 2, dtype=jnp.float32).reshape(n, 2)}
    server0 = {"s": jnp.zeros(())}
    ef0 = zeros_ef(codec, bank0) if lossy else None

    # duplicate-heavy cohorts (trace shortfall cycling)
    cohorts = [jnp.asarray(v, jnp.int32) for v in
               ([0, 0, 1], [2, 5, 2], [4, 4, 4], [1, 3, 1])]
    batches = [jnp.arange(q * c, dtype=jnp.float32).reshape(q, c) + r
               for r in range(rounds)]

    bank, last_sync, server = bank0, jnp.zeros(n, jnp.int32), server0
    ef = ef0
    for r in range(rounds):
        if lossy:
            bank, last_sync, ef, server = dense_round(
                bank, last_sync, ef, server, cohorts[r], batches[r], key,
                jnp.int32(r))
        else:
            bank, last_sync, server = dense_round(
                bank, last_sync, server, cohorts[r], batches[r], key,
                jnp.int32(r))

    spill = HostSpillBank.from_device(bank0)
    ef_spill = HostSpillBank.from_device(ef0) if lossy else None
    ls = np.zeros(n, np.int32)
    server_s = server0
    for r in range(rounds):
        ids = np.asarray(cohorts[r])
        cur = spill.gather(ids)
        if lossy:
            ef_c = ef_spill.gather(ids)
            new_client, ef_c, server_s = cohort_round(
                cur, jnp.asarray(ls[ids]), ef_c, server_s, cohorts[r],
                batches[r], key, jnp.int32(r))
            ef_spill.scatter(ids, ef_c)
        else:
            new_client, server_s = cohort_round(
                cur, jnp.asarray(ls[ids]), server_s, cohorts[r],
                batches[r], key, jnp.int32(r))
        spill.broadcast(new_client)
        ls[:] = r + 1
        if r + 1 < rounds:
            spill.prefetch(np.asarray(cohorts[r + 1]))

    np.testing.assert_array_equal(np.asarray(bank["x"]),
                                  spill.materialize()["x"])
    np.testing.assert_array_equal(np.asarray(last_sync), ls)
    np.testing.assert_array_equal(np.asarray(server["s"]),
                                  np.asarray(server_s["s"]))
    if lossy:
        np.testing.assert_array_equal(np.asarray(ef["x"]),
                                      ef_spill.materialize()["x"])
