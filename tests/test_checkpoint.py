import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint


def test_roundtrip(tmp_path):
    key = jax.random.PRNGKey(0)
    tree = {"a": jax.random.normal(key, (4, 3)).astype(jnp.bfloat16),
            "b": {"c": jnp.arange(5), "d": jnp.float32(3.5)}}
    save_checkpoint(tmp_path / "ck", tree, step=17)
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, step = load_checkpoint(tmp_path / "ck", like)
    assert step == 17
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_load_rejects_leaf_count_mismatch(tmp_path):
    """Regression: validation must raise ValueError (bare assert vanishes
    under python -O)."""
    save_checkpoint(tmp_path / "ck", {"a": jnp.zeros((2,))}, step=1)
    with pytest.raises(ValueError, match="leaves"):
        load_checkpoint(tmp_path / "ck",
                        {"a": jnp.zeros((2,)), "b": jnp.zeros((2,))})


def test_load_rejects_shape_mismatch_naming_leaf_path(tmp_path):
    save_checkpoint(tmp_path / "ck",
                    {"a": jnp.zeros((2, 3)), "b": {"c": jnp.zeros((4,))}},
                    step=1)
    with pytest.raises(ValueError, match=r"\['b'\]\['c'\]"):
        load_checkpoint(tmp_path / "ck",
                        {"a": jnp.zeros((2, 3)), "b": {"c": jnp.zeros((5,))}})


def test_load_rejects_tampered_dtype_metadata(tmp_path):
    """The recorded dtype metadata is verified on load: a mismatching
    .npz/.json pair must not restore silently."""
    save_checkpoint(tmp_path / "ck", {"a": jnp.zeros((2,), jnp.float32)},
                    step=1)
    meta_path = Path(str(tmp_path / "ck") + ".json")
    meta = json.loads(meta_path.read_text())
    meta["dtypes"]["leaf_0"] = "int32"
    meta_path.write_text(json.dumps(meta))
    with pytest.raises(ValueError, match="dtype"):
        load_checkpoint(tmp_path / "ck", {"a": jnp.zeros((2,), jnp.float32)})
