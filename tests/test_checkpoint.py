import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint


def test_roundtrip(tmp_path):
    key = jax.random.PRNGKey(0)
    tree = {"a": jax.random.normal(key, (4, 3)).astype(jnp.bfloat16),
            "b": {"c": jnp.arange(5), "d": jnp.float32(3.5)}}
    save_checkpoint(tmp_path / "ck", tree, step=17)
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, step = load_checkpoint(tmp_path / "ck", like)
    assert step == 17
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype
