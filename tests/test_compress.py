"""Communication compression subsystem (repro.fed.compress): codec
semantics, error-feedback telescoping, four-engine parity (codec="none"
bit-identical, topk at 100% density ≡ none), and bytes accounting against
the documented per-codec formulas."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FedConfig, PopulationConfig
from repro.core.baselines import make_algorithm
from repro.fed.compress import (Codec, client_messages, codec_from_config,
                                make_codec, mask_rows, state_bytes,
                                zeros_ef)
from repro.fed.population import init_async_state, make_async_round
from repro.fed.sampling import UniformSampler
from tests.test_system import _quad_driver

INF = float("inf")


def _tree(key, dtype=jnp.float32, c=3):
    """Batched [c, ...] pytree with odd leaf sizes."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {"x": jax.random.normal(k1, (c, 13), jnp.float32).astype(dtype),
            "y": {"w": jax.random.normal(k2, (c, 5, 7), jnp.float32)
                  .astype(dtype),
                  "b": jax.random.normal(k3, (c, 3), jnp.float32)
                  .astype(dtype)}}


# ------------------------------------------------------------ construction

def test_codec_validation():
    with pytest.raises(ValueError):
        make_codec("gzip")
    with pytest.raises(ValueError):
        make_codec("int8", bits=1)
    with pytest.raises(ValueError):
        make_codec("int8", bits=9)
    with pytest.raises(ValueError):
        make_codec("topk", topk_frac=0.0)
    with pytest.raises(ValueError):
        make_codec("topk", topk_frac=1.5)
    with pytest.raises(ValueError):
        FedConfig(codec="lz4")
    with pytest.raises(ValueError):
        FedConfig(codec="topk", topk_frac=-0.1)
    assert not make_codec("none").lossy
    assert make_codec("topk").stateful
    assert not make_codec("int8", error_feedback=False).stateful
    assert codec_from_config(FedConfig(codec="int8", codec_bits=4)).qmax == 7


# ------------------------------------------------------------ roundtrips

@pytest.mark.parametrize("bits", [8, 4, 2])
def test_int8_roundtrip_error_bound(bits):
    """|decode(encode(x)) - x| <= scale = max|x| / (2^(b-1) - 1), per leaf
    per client."""
    cod = make_codec("int8", bits=bits)
    tree = _tree(jax.random.PRNGKey(0))
    one = jax.tree.map(lambda a: a[0], tree)
    rt = cod.roundtrip(jax.random.PRNGKey(1), one)
    for got, x in zip(jax.tree.leaves(rt), jax.tree.leaves(one)):
        scale = float(jnp.max(jnp.abs(x))) / cod.qmax
        assert np.max(np.abs(np.asarray(got) - np.asarray(x))) <= scale + 1e-6


def test_int8_roundtrip_unbiased():
    """Stochastic rounding is unbiased: the mean over many independent noise
    draws converges to x (tolerance ~ scale / sqrt(reps))."""
    cod = make_codec("int8")
    x = {"x": jax.random.normal(jax.random.PRNGKey(2), (257,))}
    reps = 512
    rts = jax.vmap(lambda k: cod.roundtrip(k, x)["x"])(
        jax.random.split(jax.random.PRNGKey(3), reps))
    scale = float(jnp.max(jnp.abs(x["x"]))) / 127
    err = np.abs(np.asarray(rts.mean(0)) - np.asarray(x["x"]))
    assert err.max() < 5 * scale / np.sqrt(reps)


def test_topk_keeps_largest_and_full_density_is_identity():
    cod = make_codec("topk", topk_frac=0.25)
    x = {"x": jnp.asarray([0.1, -3.0, 0.2, 2.0, -0.05, 0.4, 1.0, -0.3])}
    rt = cod.roundtrip(jax.random.PRNGKey(0), x)["x"]
    np.testing.assert_array_equal(np.asarray(rt),
                                  [0, -3.0, 0, 2.0, 0, 0, 0, 0])
    full = make_codec("topk", topk_frac=1.0)
    y = _tree(jax.random.PRNGKey(1))
    rt = full.roundtrip(jax.random.PRNGKey(0), y)
    for a, b in zip(jax.tree.leaves(rt), jax.tree.leaves(y)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name,kw", [("int8", {}), ("int8", {"bits": 4}),
                                     ("topk", {"topk_frac": 0.3})])
def test_error_feedback_telescopes(name, kw):
    """sent + residual ≡ the true (EF-augmented) update: what the codec
    dropped this round is exactly what the residual carries forward."""
    cod = make_codec(name, **kw)
    key = jax.random.PRNGKey(4)
    ref = _tree(key)
    cur = jax.tree.map(
        lambda a: a + 0.3 * jax.random.normal(jax.random.fold_in(key, 1),
                                              a.shape), ref)
    ef = jax.tree.map(
        lambda a: 0.05 * jax.random.normal(jax.random.fold_in(key, 2),
                                           a.shape), ref)
    ids = jnp.arange(3)
    recon, ef_new = client_messages(cod, key, 7, ids, ref, cur, ef)
    delta = jax.tree.map(jnp.subtract, cur, ref)
    sent = jax.tree.map(jnp.subtract, recon, ref)
    for s, e, d, e0 in zip(jax.tree.leaves(sent), jax.tree.leaves(ef_new),
                           jax.tree.leaves(delta), jax.tree.leaves(ef)):
        np.testing.assert_allclose(np.asarray(s + e), np.asarray(d + e0),
                                   atol=1e-6, rtol=1e-6)


def test_client_messages_none_is_passthrough():
    tree = _tree(jax.random.PRNGKey(5))
    cur = jax.tree.map(lambda a: a + 1.0, tree)
    recon, ef = client_messages(make_codec("none"), jax.random.PRNGKey(0),
                                0, jnp.arange(3), tree, cur, None)
    assert recon is cur and ef is None


def test_client_messages_folds_global_ids():
    """Per-client stochastic streams fold the GLOBAL id: the same client in
    a different cohort slot draws the same noise (cohort ≡ population
    reproducibility, as for the local-step RNG)."""
    cod = make_codec("int8")
    key = jax.random.PRNGKey(6)
    ref, cur = _tree(key, c=2), _tree(jax.random.fold_in(key, 1), c=2)
    a, _ = client_messages(cod, key, 3, jnp.asarray([4, 9]), ref, cur)
    swap = lambda t: jax.tree.map(lambda l: l[::-1], t)
    b, _ = client_messages(cod, key, 3, jnp.asarray([9, 4]), swap(ref),
                           swap(cur))
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(swap(b))):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_mask_rows_and_zeros_ef():
    tree = _tree(jax.random.PRNGKey(7))
    zeros = jax.tree.map(jnp.zeros_like, tree)
    out = mask_rows(jnp.asarray([True, False, True]), tree, zeros)
    assert float(jnp.abs(out["x"][1]).max()) == 0.0
    assert float(jnp.abs(out["x"][0] - tree["x"][0]).max()) == 0.0
    assert zeros_ef(make_codec("none"), tree) is None
    assert zeros_ef(make_codec("int8", error_feedback=False), tree) is None
    ef = zeros_ef(make_codec("topk"), tree)
    assert all(l.dtype == jnp.float32 and float(jnp.abs(l).max()) == 0
               for l in jax.tree.leaves(ef))


# ------------------------------------------------------------ bytes formulas

def test_message_bytes_formulas():
    t = {"a": jax.ShapeDtypeStruct((10, 3), jnp.float32),
         "b": jax.ShapeDtypeStruct((7,), jnp.bfloat16)}
    assert state_bytes(t) == 30 * 4 + 7 * 2
    assert make_codec("none").message_bytes(t) == state_bytes(t)
    # int8: ceil(size * bits / 8) packed levels + one f32 scale per leaf
    assert make_codec("int8", bits=8).message_bytes(t) == (30 + 4) + (7 + 4)
    assert make_codec("int8", bits=4).message_bytes(t) == (15 + 4) + (4 + 4)
    # topk: (int32 index + f32 value) per kept entry, k = round(frac * size)
    assert make_codec("topk", topk_frac=0.3).message_bytes(t) == 9 * 8 + 2 * 8
    # downlink is always the uncompressed state
    assert make_codec("topk").down_bytes(t) == state_bytes(t)


# ------------------------------------------------------------ engine parity

def _run(mode, steps=16, m=4, **fed_kw):
    d = _quad_driver("adafbio", m=m)
    if fed_kw:
        d.fed = dataclasses.replace(d.alg.fed, **fed_kw)
        d.alg = make_algorithm("adafbio", d.fed, d.problem)
    d.sampler = UniformSampler(m, 2, jax.random.PRNGKey(9))
    if mode == "population":
        d.population = PopulationConfig(n=m, cohort=2)
    elif mode == "async":
        d.population = PopulationConfig(n=m, cohort=2, max_staleness=INF)
    else:
        d.participation = 0.5
        d.engine = mode
    return d.run(steps, eval_every=steps), d


ENGINES4 = ("eager", "scan", "population", "async")


@pytest.mark.parametrize("mode", ENGINES4)
def test_codec_none_bit_identical(mode):
    """The acceptance property: codec="none" (the default) is bit-identical
    to a run that never mentions codecs, on every engine."""
    base, _ = _run(mode)
    none, _ = _run(mode, codec="none")
    for a, b in zip(jax.tree.leaves(base.final_avg_state),
                    jax.tree.leaves(none.final_avg_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert base.grad_norm == none.grad_norm
    assert base.bytes_up == none.bytes_up


@pytest.mark.parametrize("mode", ENGINES4)
def test_topk_full_density_matches_none(mode):
    """topk at k = 100% transmits everything: the trajectory matches the
    uncompressed run to 1e-6 on every engine (only float re-association of
    ref + (cur - ref) separates them)."""
    base, _ = _run(mode)
    full, _ = _run(mode, codec="topk", topk_frac=1.0)
    for a, b in zip(jax.tree.leaves(base.final_avg_state),
                    jax.tree.leaves(full.final_avg_state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, rtol=1e-6)
    np.testing.assert_allclose(base.grad_norm, full.grad_norm,
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("codec_kw", [dict(codec="int8"),
                                      dict(codec="int8", codec_bits=4),
                                      dict(codec="topk", topk_frac=0.25),
                                      dict(codec="topk", topk_frac=0.25,
                                           error_feedback=False)])
@pytest.mark.parametrize("mode", ENGINES4)
def test_lossy_codecs_stay_finite(mode, codec_kw):
    r, _ = _run(mode, steps=24, **codec_kw)
    assert np.isfinite(r.grad_norm).all()


def test_eager_scan_share_stochastic_streams():
    """The eager and scan engines fold the same codec RNG stream, so even
    the STOCHASTIC int8 codec produces identical trajectories."""
    a, _ = _run("eager", codec="int8")
    b, _ = _run("scan", codec="int8")
    for x, y in zip(jax.tree.leaves(a.final_avg_state),
                    jax.tree.leaves(b.final_avg_state)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=1e-6, rtol=1e-6)


def test_error_feedback_changes_trajectory():
    """EF on vs off is a real difference under aggressive sparsification
    (without it, dropped coordinates would never be transmitted)."""
    on, _ = _run("population", steps=32, codec="topk", topk_frac=0.1)
    off, _ = _run("population", steps=32, codec="topk", topk_frac=0.1,
                  error_feedback=False)
    a = np.concatenate([np.asarray(l).ravel()
                        for l in jax.tree.leaves(on.final_avg_state)])
    b = np.concatenate([np.asarray(l).ravel()
                        for l in jax.tree.leaves(off.final_avg_state)])
    assert not np.allclose(a, b, atol=1e-6)


# ------------------------------------------------------------ driver bytes

def _one_client_bytes(d, codec):
    state = {"x": jnp.zeros((8,)), "y": jnp.zeros((6,)),
             "v": jnp.zeros((6,)), "w": jnp.zeros((8,))}
    return codec.message_bytes(state), codec.down_bytes(state)


@pytest.mark.parametrize("codec_kw", [dict(), dict(codec="int8"),
                                      dict(codec="topk", topk_frac=0.5)])
def test_driver_bytes_follow_formulas_sync_engines(codec_kw):
    """eager / scan / population all record bytes_up = comms x transmitters
    x message_bytes and bytes_down = comms x receivers x state_bytes."""
    for mode, tx, rx in (("eager", 2, 4), ("scan", 2, 4),
                         ("population", 2, 4)):
        r, d = _run(mode, steps=16, **codec_kw)
        msg_b, down_b = _one_client_bytes(d, d.codec)
        comms = r.comms[-1]
        assert comms > 0
        assert r.bytes_up[-1] == comms * tx * msg_b, mode
        assert r.bytes_down[-1] == comms * rx * down_b, mode


@pytest.mark.parametrize("codec_kw", [dict(),
                                      dict(codec="topk", topk_frac=0.5)])
def test_driver_bytes_duplicate_cohort_bills_unique_transmitters(codec_kw):
    """Wire convention (docs/sharding.md): a duplicate cohort id — trace
    shortfall cycling — fills two aggregation slots but the client computed
    and shipped ONE message, so bytes_up prices unique transmitters."""
    class Dup:
        def cohort(self, r):
            return jnp.asarray([2, 2], jnp.int32)
    d = _quad_driver("adafbio", m=4)
    if codec_kw:
        d.fed = dataclasses.replace(d.alg.fed, **codec_kw)
        d.alg = make_algorithm("adafbio", d.fed, d.problem)
    d.population = PopulationConfig(n=4, cohort=2)
    d.sampler = Dup()
    r = d.run(16, eval_every=16)
    msg_b, down_b = _one_client_bytes(d, d.codec)
    comms = r.comms[-1]
    assert comms > 0
    assert r.bytes_up[-1] == comms * 1 * msg_b      # 1 unique transmitter
    assert r.bytes_down[-1] == comms * 4 * down_b   # broadcast: all N rows


def test_driver_bytes_follow_formulas_async():
    """Async: bytes_up counts every ARRIVAL (dropped ones shipped before
    the gate), bytes_down the per-round synced rows."""
    d = _quad_driver("adafbio", m=8)
    d.population = PopulationConfig(n=8, cohort=3, max_staleness=2,
                                    max_delay=3)
    r = d.run(48, eval_every=48)
    msg_b, down_b = _one_client_bytes(d, d.codec)
    arrived = sum(s["arrived"] for s in d.staleness_log)
    synced = sum(s["synced"] for s in d.staleness_log)
    assert arrived > 0 and synced > 0
    assert r.bytes_up[-1] == arrived * msg_b
    assert r.bytes_down[-1] == synced * down_b
    assert sum(s["dropped"] for s in d.staleness_log) > 0   # gate active


# ------------------------------------------------------------ async EF bank

def _toy_async(codec, **kw):
    def local(states, server, batch, key, ids):
        return jax.tree.map(lambda a: a + 1.0, states), server

    def sync(server, avg):
        return avg, server
    return make_async_round(local, sync, q=2, codec=codec, **kw)


def test_async_ef_rides_in_state_and_masks_in_flight():
    """EF residuals persist in state["ef"]; a cohort slot whose client is
    still in flight is a no-op on the residual as well as the pending
    update."""
    cod = make_codec("topk", topk_frac=0.5)
    round_fn = jax.jit(_toy_async(cod, max_staleness=INF, max_delay=4))
    state = init_async_state({"x": jnp.zeros((5, 4))}, {}, 5, codec=cod)
    assert "ef" in state
    # client 3 is mid-flight with a marked residual; resampling it must
    # leave both its pending update and its residual untouched
    state["in_flight"] = state["in_flight"].at[3].set(True)
    state["dispatch_round"] = state["dispatch_round"].at[3].set(-1)
    state["return_round"] = state["return_round"].at[3].set(9)
    state["ef"] = {"x": state["ef"]["x"].at[3].set(42.0)}
    pend3 = np.asarray(state["pending"]["x"][3]).copy()
    ids = jnp.asarray([3, 0], jnp.int32)
    state, stats = round_fn(state, ids, jnp.zeros((2,)),
                            jax.random.PRNGKey(0), jnp.int32(0))
    assert int(stats["dispatched"]) == 1            # only client 0 started
    np.testing.assert_array_equal(np.asarray(state["ef"]["x"][3]), 42.0)
    np.testing.assert_array_equal(np.asarray(state["pending"]["x"][3]),
                                  pend3)
    # the dispatched client's pending row holds the codec reconstruction:
    # topk at 50% of a uniform +2 update keeps half the entries
    sent = np.asarray(state["pending"]["x"][0])
    assert (sent == 2.0).sum() == 2 and (sent == 0.0).sum() == 2
    # and its residual carries exactly what was dropped
    np.testing.assert_allclose(np.asarray(state["ef"]["x"][0]) + sent,
                               2.0, atol=1e-6)


def test_async_codec_none_state_has_no_ef():
    state = init_async_state({"x": jnp.zeros((4, 2))}, {}, 4,
                             codec=make_codec("none"))
    assert "ef" not in state
    state = init_async_state({"x": jnp.zeros((4, 2))}, {}, 4)
    assert "ef" not in state
