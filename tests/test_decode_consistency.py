"""Serving correctness: prefill+decode must reproduce the full forward pass
(per family, f32 reduced configs). MoE runs with drop-free capacity: with
finite capacity, token dropping legitimately depends on how many tokens share
a dispatch (train batch vs 1-token decode)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import (ModelCtx, decode_step, forward, init_cache,
                          init_params, model_specs, prefill)

FAMS = ["qwen1.5-4b",        # dense (MHA, qkv bias)
        "granite-20b",       # dense (MQA)
        "falcon-mamba-7b",   # ssm
        "zamba2-1.2b",       # hybrid
        "qwen3-moe-30b-a3b", # moe
        "whisper-tiny"]      # encdec


def _cfg(arch_id):
    cfg = reduced(get_arch(arch_id), dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    return cfg


@pytest.mark.parametrize("arch_id", FAMS)
def test_prefill_matches_forward(arch_id):
    cfg = _cfg(arch_id)
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0), "float32")
    B, S = 1, 24
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(key, (B, S, cfg.d_model))
    if cfg.n_prefix_embeds:
        batch["prefix_embeds"] = 0.1 * jax.random.normal(
            key, (B, cfg.n_prefix_embeds, cfg.d_model))
    full = forward(cfg, params, batch, ModelCtx(kind="train"))
    cache = init_cache(cfg, B, S + 4,
                       enc_len=S if cfg.family == "encdec" else 0,
                       dtype=jnp.float32)
    lg, cache = prefill(cfg, params, batch, cache, ModelCtx(kind="prefill"))
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch_id", FAMS)
def test_decode_matches_forward(arch_id):
    """forward(tokens[:S]) position S-1 logits == prefill(tokens[:S-1]) then
    decode(token[S-1])."""
    cfg = _cfg(arch_id)
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0), "float32")
    B, S = 1, 16
    key = jax.random.PRNGKey(2)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    enc = jax.random.normal(key, (B, S, cfg.d_model)) \
        if cfg.family == "encdec" else None

    def mk(t):
        b = {"tokens": t}
        if enc is not None:
            b["enc_embeds"] = enc
        return b

    full = forward(cfg, params, mk(tokens), ModelCtx(kind="train"))
    cache = init_cache(cfg, B, S + 4,
                       enc_len=S if cfg.family == "encdec" else 0,
                       dtype=jnp.float32)
    _, cache = prefill(cfg, params, mk(tokens[:, :S - 1]), cache,
                       ModelCtx(kind="prefill"))
    lg, cache = decode_step(cfg, params, cache, tokens[:, S - 1:],
                            jnp.int32(S - 1), ModelCtx(kind="decode"))
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, -1]),
                               rtol=5e-3, atol=5e-3)
