"""Heterogeneous per-client delay models (repro.fed.population.DelayModel):
uniform must stay bit-identical to the plain async path, tiers must be
permanent/deterministic and degenerate to sync, lognormal must quantize a
permanent latency, and the trace model must replay the JSONL per-client
delay field."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import PopulationConfig
from repro.fed.population import (delay_schedule, init_async_state,
                                  make_async_round, make_delay_model,
                                  parse_tier_spec, tier_assignment,
                                  _tier_sizes)
from repro.fed.sampling import load_delay_trace, load_trace, save_trace
from repro.fed.sampling import UniformSampler
from tests.test_system import _quad_driver

INF = float("inf")


def _toy_round(**kw):
    def local(states, server, batch, key, ids):
        return jax.tree.map(lambda a: a + 1.0, states), server

    def sync(server, avg):
        return avg, server
    return make_async_round(local, sync, q=2, **kw)


# ------------------------------------------------------------- construction

def test_parse_tier_spec():
    assert parse_tier_spec("0.2:1:1,0.6:2:4,0.2:4:8") == (
        (0.2, 0.6, 0.2), ((1, 1), (2, 4), (4, 8)))
    with pytest.raises(ValueError):
        parse_tier_spec("0.2:1")


def test_make_delay_model_validation():
    with pytest.raises(ValueError):
        make_delay_model("warp", 3)
    with pytest.raises(ValueError):
        make_delay_model("uniform", 0)
    with pytest.raises(ValueError):                      # fracs don't sum
        make_delay_model("tiers", 1, tier_fracs=(0.5, 0.2),
                         tier_delays=((1, 1), (2, 3)))
    with pytest.raises(ValueError):                      # lo > hi
        make_delay_model("tiers", 1, tier_fracs=(0.5, 0.5),
                         tier_delays=((1, 1), (5, 3)))
    with pytest.raises(ValueError):                      # length mismatch
        make_delay_model("tiers", 1, tier_fracs=(0.5, 0.5),
                         tier_delays=((1, 1),))
    with pytest.raises(ValueError):
        make_delay_model("lognormal", 4, sigma=-1.0)
    with pytest.raises(ValueError):                      # inert: clips to 1
        make_delay_model("lognormal", 1)
    with pytest.raises(ValueError):                      # trace needs table
        make_delay_model("trace", 1)
    with pytest.raises(ValueError):                      # delays < 1
        make_delay_model("trace", 1, table=np.zeros((2, 3), np.int32))
    # a table narrower than the population must error, not silently clip
    dm = make_delay_model("trace", 1, table=np.full((4, 5), 2, np.int32))
    with pytest.raises(ValueError, match="population"):
        dm.schedule(jax.random.PRNGKey(0), 0, 8)


def test_population_config_delay_validation():
    with pytest.raises(ValueError):                      # async knob, off
        PopulationConfig(n=8, cohort=2, delay_model="tiers")
    with pytest.raises(ValueError):                      # unknown model
        PopulationConfig(n=8, cohort=2, max_staleness=INF,
                         delay_model="warp")
    with pytest.raises(ValueError):                      # trace needs file
        PopulationConfig(n=8, cohort=2, max_staleness=INF,
                         delay_model="trace")
    with pytest.raises(ValueError):                      # bad tier range
        PopulationConfig(n=8, cohort=2, max_staleness=INF,
                         delay_model="tiers", tier_fracs=(1.0,),
                         tier_delays=((3, 2),))
    assert PopulationConfig(n=8, cohort=2, max_staleness=INF,
                            delay_model="tiers").asynchronous


# ------------------------------------------------------------- uniform model

def test_uniform_model_bit_identical_to_delay_schedule():
    key = jax.random.PRNGKey(11)
    dm = make_delay_model("uniform", 6)
    for r in range(4):
        np.testing.assert_array_equal(
            np.asarray(dm.schedule(key, r, 32)),
            np.asarray(delay_schedule(key, r, 32, 6)))


def test_uniform_model_round_fn_bit_identical_to_default():
    """make_async_round(delay=uniform DelayModel) must reproduce the
    delay=None path bit-for-bit across fresh jit instances (the PR 3
    trajectories)."""
    key = jax.random.PRNGKey(2)
    ids = jnp.asarray([1, 3], jnp.int32)
    outs = []
    for delay in (None, make_delay_model("uniform", 4)):
        round_fn = jax.jit(_toy_round(max_staleness=INF, max_delay=4,
                                      delay=delay))
        state = init_async_state({"x": jnp.arange(5.0)}, {}, 5)
        for r in range(4):
            state, _ = round_fn(state, ids, jnp.zeros((2,)), key,
                                jnp.int32(r))
        outs.append(state)
    for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------- tiers model

def test_tier_sizes_largest_remainder():
    assert _tier_sizes(10, (0.2, 0.6, 0.2)) == (2, 6, 2)
    for n in (1, 3, 7, 17):
        assert sum(_tier_sizes(n, (0.2, 0.6, 0.2))) == n


def test_tier_assignment_permanent_and_sized():
    key = jax.random.PRNGKey(4)
    a = np.asarray(tier_assignment(key, 20, (0.2, 0.6, 0.2)))
    b = np.asarray(tier_assignment(key, 20, (0.2, 0.6, 0.2)))
    np.testing.assert_array_equal(a, b)                  # permanent
    np.testing.assert_array_equal(np.bincount(a), [4, 12, 4])
    c = np.asarray(tier_assignment(jax.random.PRNGKey(5), 20,
                                   (0.2, 0.6, 0.2)))
    assert (a != c).any()                                # key-seeded


def test_tiers_schedule_within_ranges_and_deterministic():
    key = jax.random.PRNGKey(7)
    dm = make_delay_model("tiers", 1, tier_fracs=(0.25, 0.5, 0.25),
                          tier_delays=((1, 1), (2, 4), (5, 9)))
    tier = np.asarray(dm.tiers(key, 16))
    lo = np.asarray([1, 2, 5])[tier]
    hi = np.asarray([1, 4, 9])[tier]
    for r in range(6):
        d = np.asarray(dm.schedule(key, r, 16))
        assert (d >= lo).all() and (d <= hi).all()
        np.testing.assert_array_equal(d, np.asarray(dm.schedule(key, r, 16)))
    assert dm.bound == 9


def test_tiers_model_determinism_end_to_end():
    """Two identical tiers-model runs produce identical trajectories,
    histograms, and per-tier histograms."""
    outs = []
    for _ in range(2):
        d = _quad_driver("adafbio", m=8)
        d.population = PopulationConfig(
            n=8, cohort=3, max_staleness=INF, delay_model="tiers",
            tier_fracs=(0.25, 0.5, 0.25),
            tier_delays=((1, 1), (2, 3), (4, 6)))
        r = d.run(64, eval_every=16)
        outs.append((r, d.staleness_hist.copy(),
                     {k: v.copy() for k, v in
                      d.staleness_hist_by_tier.items()}))
    (r0, h0, t0), (r1, h1, t1) = outs
    np.testing.assert_array_equal(r0.grad_norm, r1.grad_norm)
    np.testing.assert_array_equal(h0, h1)
    assert t0.keys() == t1.keys()
    for k in t0:
        np.testing.assert_array_equal(t0[k], t1[k])
    # fast tier arrives fresher than the straggler tier (monotone shift)
    mean_tau = {k: (np.arange(v.size) * v).sum() / v.sum()
                for k, v in t0.items() if v.sum()}
    assert mean_tau[0] < mean_tau[2]


def test_tiers_all_unit_delays_degenerate_to_sync():
    """Sync degeneracy: tiers whose every range is (1, 1) make each
    dispatch return next round — the trajectory must match the synchronous
    population path (same guarantee as the uniform max_delay=1 case)."""
    runs = {}
    for name, pcfg in [
        ("sync", PopulationConfig(n=4, cohort=2)),
        ("tiers", PopulationConfig(n=4, cohort=2, max_staleness=INF,
                                   delay_model="tiers",
                                   tier_fracs=(0.5, 0.5),
                                   tier_delays=((1, 1), (1, 1)))),
    ]:
        d = _quad_driver("adafbio")
        d.sampler = UniformSampler(4, 2, jax.random.PRNGKey(9))
        d.population = pcfg
        runs[name] = d.run(16, eval_every=4)
    for a, b in zip(jax.tree.leaves(runs["sync"].final_avg_state),
                    jax.tree.leaves(runs["tiers"].final_avg_state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, rtol=1e-6)
    assert runs["sync"].samples == runs["tiers"].samples


def test_resolve_precomputed_schedules_match_unresolved():
    """resolve(key, n) only caches the permanent per-client quantities —
    the emitted delays must stay bitwise-identical to the unresolved
    model's."""
    key = jax.random.PRNGKey(13)
    for dm in (make_delay_model("tiers", 1, tier_fracs=(0.5, 0.5),
                                tier_delays=((1, 2), (3, 7))),
               make_delay_model("lognormal", 6, mu=0.5, sigma=0.7),
               make_delay_model("uniform", 4)):
        res = dm.resolve(key, 12)
        for r in range(5):
            np.testing.assert_array_equal(
                np.asarray(dm.schedule(key, r, 12)),
                np.asarray(res.schedule(key, r, 12)))


# ------------------------------------------------------------- lognormal

def test_lognormal_permanent_quantized_clipped():
    key = jax.random.PRNGKey(3)
    dm = make_delay_model("lognormal", 6, mu=0.7, sigma=0.8)
    d0 = np.asarray(dm.schedule(key, 0, 64))
    d9 = np.asarray(dm.schedule(key, 9, 64))
    np.testing.assert_array_equal(d0, d9)        # permanent per client
    assert d0.min() >= 1 and d0.max() <= 6
    assert len(np.unique(d0)) > 1                # heterogeneous
    assert dm.bound == 6


# ------------------------------------------------------------- trace model

def test_trace_delay_model_replays_table():
    """A client whose trace says delay 3 must return exactly 3 rounds after
    dispatch."""
    tab = np.asarray([[3, 1]], np.int32)         # client 0 slow, 1 fast
    round_fn = jax.jit(_toy_round(
        max_staleness=INF, delay=make_delay_model("trace", 1, table=tab)))
    state = init_async_state({"x": jnp.zeros((2,))}, {}, 2)
    ids = jnp.asarray([0, 1], jnp.int32)
    key = jax.random.PRNGKey(0)
    state, _ = round_fn(state, ids, jnp.zeros((2,)), key, jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(state["return_round"]), [3, 1])


def test_trace_delay_driver_run(tmp_path):
    """End-to-end: delay_model='trace' loads the per-client delay field
    from PopulationConfig.trace_file; the staleness histogram is bounded by
    the table's delays."""
    path = tmp_path / "trace.jsonl"
    with open(path, "w") as f:
        f.write(json.dumps({"horizon": 2}) + "\n")
        for i in range(4):
            f.write(json.dumps({"client": i, "up": [[0, 2]],
                                "delay": 2 if i < 2 else 1}) + "\n")
    d = _quad_driver("adafbio", m=4)
    d.population = PopulationConfig(n=4, cohort=2, max_staleness=INF,
                                    delay_model="trace",
                                    trace_file=str(path))
    r = d.run(24, eval_every=8)
    assert np.isfinite(r.grad_norm).all()
    assert d.staleness_hist.size <= 3            # taus in {1, 2} only
    assert d.staleness_hist.sum() > 0


def test_save_trace_roundtrips_delays(tmp_path):
    path = str(tmp_path / "t.jsonl")
    up = np.ones((4, 3), bool)
    delays = np.asarray([[2, 1, 5], [2, 1, 5], [2, 3, 5], [2, 3, 5]])
    save_trace(path, up, delays)
    np.testing.assert_array_equal(load_delay_trace(path, 3), delays)
    # scalar form: [n] vector
    save_trace(path, up, np.asarray([4, 1, 2]))
    np.testing.assert_array_equal(load_delay_trace(path, 3),
                                  np.tile([4, 1, 2], (4, 1)))


def test_load_delay_trace_defaults_and_validation(tmp_path):
    path = tmp_path / "d.jsonl"
    with open(path, "w") as f:
        f.write(json.dumps({"client": 0, "delay": [4, 2, 7]}) + "\n")
    tab = load_delay_trace(str(path), 2)
    assert tab.shape == (3, 2)                   # horizon = longest list
    np.testing.assert_array_equal(tab[:, 0], [4, 2, 7])
    np.testing.assert_array_equal(tab[:, 1], 1)  # absent client: delay 1
    with open(path, "a") as f:
        f.write(json.dumps({"client": 1, "delay": 0}) + "\n")
    with pytest.raises(ValueError):
        load_delay_trace(str(path), 2)
    # a delay list longer than an explicit horizon must error, not
    # silently truncate the recorded delays
    with open(path, "w") as f:
        f.write(json.dumps({"horizon": 2}) + "\n")
        f.write(json.dumps({"client": 0, "delay": [1, 1, 9]}) + "\n")
    with pytest.raises(ValueError, match="horizon"):
        load_delay_trace(str(path), 2)


def test_availability_and_delay_tables_share_one_horizon(tmp_path):
    """docs/async.md: the two consumers of one trace file must cycle with
    the SAME period — a delays-only client line loads fine in load_trace
    (always available), and a long delay list stretches both horizons."""
    path = tmp_path / "t.jsonl"
    with open(path, "w") as f:
        f.write(json.dumps({"client": 0, "up": [[0, 4]],
                            "delay": [1, 2, 3, 4, 5, 6]}) + "\n")
        f.write(json.dumps({"client": 1, "delay": 2}) + "\n")
    up = load_trace(str(path), 2)
    delays = load_delay_trace(str(path), 2)
    assert up.shape == delays.shape == (6, 2)
    np.testing.assert_array_equal(up[:, 0], [1, 1, 1, 1, 0, 0])
    np.testing.assert_array_equal(up[:, 1], 1)   # delay-only: always up
    np.testing.assert_array_equal(delays[:, 0], [1, 2, 3, 4, 5, 6])
    # scalar-delays-only file: both loaders accept it (horizon 1)
    with open(path, "w") as f:
        f.write(json.dumps({"client": 0, "delay": 3}) + "\n")
    assert load_trace(str(path), 2).shape == (1, 2)
    np.testing.assert_array_equal(load_delay_trace(str(path), 2),
                                  [[3, 1]])
