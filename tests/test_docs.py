"""Docs-parity gate (scripts/check_docs.py) as a fast-tier test: the README
CLI flag tables must match the argparse parsers in both directions, and the
docs/ tree the README points into must exist."""
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def test_check_docs_passes():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "check_docs.py")],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr or proc.stdout


def test_docs_tree_exists_and_linked():
    readme = (ROOT / "README.md").read_text()
    for doc in ("docs/ARCHITECTURE.md", "docs/async.md"):
        assert (ROOT / doc).is_file(), doc
        assert doc in readme, f"README must link {doc}"


def test_readme_flag_tables_cover_async_flags():
    """The new async flags are the ones most likely to rot — pin them."""
    readme = (ROOT / "README.md").read_text()
    for flag in ("--max-staleness", "--max-delay", "--delay-eta",
                 "--trace-file", "--population", "--cohort", "--sampler",
                 "--engine"):
        assert f"`{flag}`" in readme, flag
