"""Neumann hypergradient (Eq. 15): closed-form checks on the quadratic
problem + factored/generic equivalence on the LM problem."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.hypergrad as hgm
from repro.core.bilevel import (lm_bilevel_problem, quadratic_bilevel_problem,
                                quadratic_true_grad)
from repro.models.model import ModelCtx, model_specs
from repro.models.params import init_params
from repro.configs import get_arch, reduced


def _quad(seed=0, d=6, p=5):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    A = jax.random.normal(k1, (p, p))
    H = A @ A.T / p + 0.5 * jnp.eye(p)
    Bm = jax.random.normal(k2, (p, d)) * 0.3
    c = jax.random.normal(k3, (p,))
    Q = jnp.eye(d) * 0.2
    x = jax.random.normal(k4, (d,))
    return H, Bm, c, Q, x


def _exact_expectation(prob, x, y, K, theta):
    """Average the estimator over every value of k (U{0..K-1})."""
    batches = {"f": 0, "g0": 0, "g": 0, "gi": jnp.zeros((K,))}
    orig = hgm.sample_k
    try:
        ws = []
        for kk in range(K):
            hgm.sample_k = lambda key, K_, _k=kk: jnp.int32(_k)
            ws.append(hgm.hypergrad(prob, x, y, batches,
                                    jax.random.PRNGKey(0), K, theta))
        return jnp.mean(jnp.stack(ws), 0)
    finally:
        hgm.sample_k = orig


def test_quadratic_closed_form():
    H, Bm, c, Q, x = _quad()
    prob = quadratic_bilevel_problem(H, Bm, c, Q)
    L = float(jnp.linalg.eigvalsh(H)[-1])
    ystar = jnp.linalg.solve(H, Bm @ x)
    w = _exact_expectation(prob, x, ystar, K=64, theta=1.0 / L)
    tg = quadratic_true_grad(H, Bm, c, Q, x)
    np.testing.assert_allclose(np.asarray(w), np.asarray(tg), rtol=1e-4,
                               atol=1e-5)


def test_bias_decays_with_K():
    """Lemma 3: ||E[estimator] - true|| decays geometrically in K."""
    H, Bm, c, Q, x = _quad(seed=1)
    prob = quadratic_bilevel_problem(H, Bm, c, Q)
    L = float(jnp.linalg.eigvalsh(H)[-1])
    ystar = jnp.linalg.solve(H, Bm @ x)
    tg = np.asarray(quadratic_true_grad(H, Bm, c, Q, x))
    errs = []
    for K in (2, 8, 32):
        w = _exact_expectation(prob, x, ystar, K=K, theta=1.0 / L)
        errs.append(np.linalg.norm(np.asarray(w) - tg))
    assert errs[0] > errs[1] > errs[2]
    assert errs[2] < 1e-2 * max(errs[0], 1e-12) + 1e-5


def test_factored_matches_generic_on_lm():
    cfg = reduced(get_arch("qwen1.5-4b"), n_layers=1, d_model=64, n_heads=2,
                  n_kv_heads=2, d_ff=128, vocab=97, head_dim=32,
                  dtype="float32")
    ctx = ModelCtx(rules=None, kind="train")
    prob = lm_bilevel_problem(cfg, ctx, nu=1e-2)
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0), "float32")
    key = jax.random.PRNGKey(3)
    B, S, K = 2, 16, 3
    toks = lambda k: jax.random.randint(k, (B, S), 0, cfg.vocab)
    ks = jax.random.split(key, K + 3)
    batches = {"f": {"tokens": toks(ks[0])},
               "g": {"tokens": toks(ks[1])},
               "g0": {"tokens": toks(ks[2])},
               "gi": {"tokens": jnp.stack([toks(k) for k in ks[3:]])}}
    kk = jax.random.PRNGKey(9)
    w1 = hgm.hypergrad(prob, params["x"], params["y"], batches, kk, K, 0.5)
    w2 = hgm.hypergrad_factored(prob, params["x"], params["y"], batches, kk,
                                K, 0.5)
    flat1 = jnp.concatenate([a.reshape(-1) for a in jax.tree.leaves(w1)])
    flat2 = jnp.concatenate([a.reshape(-1) for a in jax.tree.leaves(w2)])
    np.testing.assert_allclose(np.asarray(flat1), np.asarray(flat2),
                               rtol=2e-4, atol=2e-5)
