"""Pallas kernels (interpret mode) vs pure-jnp oracles: shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.storm_update import adafbio_update, storm_update


@pytest.mark.parametrize("b,h,kv,s,d", [
    (1, 4, 4, 128, 64),     # MHA
    (2, 8, 2, 256, 64),     # GQA
    (1, 8, 1, 256, 128),    # MQA
])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, h, kv, s, d, causal, window, dtype):
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, h, s, d), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (b, kv, s, d), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (b, kv, s, d), jnp.float32).astype(dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("n", [1024, 65536 * 2])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("beta", [0.0, 0.3, 1.0])
def test_storm_update(n, dtype, beta):
    key = jax.random.PRNGKey(1)
    gn, go, est = (jax.random.normal(k, (n,), jnp.float32).astype(dtype)
                   for k in jax.random.split(key, 3))
    got = storm_update(gn, go, est, beta, interpret=True)
    want = ref.storm_update_ref(gn, go, est, beta)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("n", [512, 65536])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_adafbio_update(n, dtype):
    key = jax.random.PRNGKey(2)
    k1, k2, k3 = jax.random.split(key, 3)
    p = jax.random.normal(k1, (n,), jnp.float32).astype(dtype)
    w = jax.random.normal(k2, (n,), jnp.float32).astype(dtype)
    a = jnp.abs(jax.random.normal(k3, (n,), jnp.float32))
    got = adafbio_update(p, w, a, 0.01, 1e-4, interpret=True)
    want = ref.adafbio_update_ref(p, w, a, 0.01, 1e-4)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("b,s,di,n", [(1, 32, 256, 8), (2, 64, 1024, 16)])
def test_mamba_scan(b, s, di, n):
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, di)) * 0.1
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, di)))
    A = -jnp.abs(jax.random.normal(ks[2], (di, n)))
    Bm = jax.random.normal(ks[3], (b, s, n)) * 0.1
    Cm = jax.random.normal(ks[4], (b, s, n)) * 0.1
    y1, h1 = mamba_scan(x, dt, A, Bm, Cm, block_d=min(256, di),
                        interpret=True)
    y2, h2 = ref.mamba_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5,
                               rtol=1e-5)


def test_mamba_scan_matches_model_layer():
    """The kernel's recurrence equals the model's chunked associative scan."""
    from repro.models import ssm as ssm_lib
    b, s, di, n = 1, 64, 128, 8
    key = jax.random.PRNGKey(4)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, di)) * 0.1
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, di)))
    A = -jnp.abs(jax.random.normal(ks[2], (di, n)))
    Bm = jax.random.normal(ks[3], (b, s, n)) * 0.1
    Cm = jax.random.normal(ks[4], (b, s, n)) * 0.1
    # model-internal chunked scan
    a = jnp.exp(dt[..., None] * A)
    bx = (dt * x)[..., None] * Bm[:, :, None, :]
    hs, _ = ssm_lib._selective_scan_chunk(a, bx, jnp.zeros((b, di, n)))
    y_model = jnp.einsum("bcdn,bcn->bcd", hs, Cm)
    y_kernel, _ = mamba_scan(x, dt, A, Bm, Cm, block_d=128, interpret=True)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               atol=1e-4, rtol=1e-4)
