"""Pallas kernels (interpret mode) vs pure-jnp oracles: shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.tree_util import tree_pack, tree_unpack
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.storm_update import adafbio_update, storm_update


@pytest.mark.parametrize("b,h,kv,s,d", [
    (1, 4, 4, 128, 64),     # MHA
    (2, 8, 2, 256, 64),     # GQA
    (1, 8, 1, 256, 128),    # MQA
])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, h, kv, s, d, causal, window, dtype):
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, h, s, d), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (b, kv, s, d), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (b, kv, s, d), jnp.float32).astype(dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("n", [1024, 65536 * 2])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("beta", [0.0, 0.3, 1.0])
def test_storm_update(n, dtype, beta):
    key = jax.random.PRNGKey(1)
    gn, go, est = (jax.random.normal(k, (n,), jnp.float32).astype(dtype)
                   for k in jax.random.split(key, 3))
    got = storm_update(gn, go, est, beta, interpret=True)
    want = ref.storm_update_ref(gn, go, est, beta)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("n", [512, 65536])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_adafbio_update(n, dtype):
    key = jax.random.PRNGKey(2)
    k1, k2, k3 = jax.random.split(key, 3)
    p = jax.random.normal(k1, (n,), jnp.float32).astype(dtype)
    w = jax.random.normal(k2, (n,), jnp.float32).astype(dtype)
    a = jnp.abs(jax.random.normal(k3, (n,), jnp.float32))
    got = adafbio_update(p, w, a, 0.01, 1e-4, interpret=True)
    want = ref.adafbio_update_ref(p, w, a, 0.01, 1e-4)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


# ----------------------------------------------------- non-divisible blocks

@pytest.mark.parametrize("n,block", [
    (1000, 256),       # n not a multiple of the block
    (130, 128),        # barely over one lane
    (65536 + 7, 65536),  # big buffer + ragged tail
    (5, 65536),        # smaller than one lane
])
def test_storm_update_nondivisible(n, block):
    key = jax.random.PRNGKey(5)
    gn, go, est = (jax.random.normal(k, (n,), jnp.float32)
                   for k in jax.random.split(key, 3))
    got = storm_update(gn, go, est, 0.3, block=block, interpret=True)
    want = ref.storm_update_ref(gn, go, est, 0.3)
    assert got.shape == (n,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6,
                               rtol=1e-6)


@pytest.mark.parametrize("n", [1024, 65536 * 2])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bits", [8, 4])
def test_quantize_stoch(n, dtype, bits):
    from repro.kernels.quantize import dequantize, quantize_stoch
    key = jax.random.PRNGKey(11)
    x = jax.random.normal(key, (n,), jnp.float32).astype(dtype)
    u = jax.random.uniform(jax.random.fold_in(key, 1), (n,))
    qmax = (1 << (bits - 1)) - 1
    scale = float(jnp.max(jnp.abs(x.astype(jnp.float32)))) / qmax
    got = quantize_stoch(x, u, scale, qmax, interpret=True)
    want = ref.quantize_stoch_ref(x, u, scale, qmax)
    assert got.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert np.abs(np.asarray(got)).max() <= qmax
    deq = dequantize(got, scale, interpret=True)
    np.testing.assert_array_equal(np.asarray(deq),
                                  np.asarray(ref.dequantize_ref(want,
                                                                scale)))
    # quantize -> dequantize error is at most one step
    err = np.abs(np.asarray(deq) - np.asarray(x, np.float32))
    assert err.max() <= scale + (1e-6 if dtype == jnp.float32 else 2e-2)


@pytest.mark.parametrize("n,block", [
    (1000, 256),       # n not a multiple of the block
    (130, 128),        # barely over one lane
    (5, 65536),        # smaller than one lane
])
def test_quantize_stoch_nondivisible(n, block):
    from repro.kernels.quantize import dequantize, quantize_stoch
    key = jax.random.PRNGKey(12)
    x = jax.random.normal(key, (n,))
    u = jax.random.uniform(jax.random.fold_in(key, 1), (n,))
    scale = float(jnp.max(jnp.abs(x))) / 127
    got = quantize_stoch(x, u, scale, 127, block=block, interpret=True)
    want = ref.quantize_stoch_ref(x, u, scale, 127)
    assert got.shape == (n,)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    deq = dequantize(got, scale, block=block, interpret=True)
    assert deq.shape == (n,) and deq.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(deq), np.asarray(x), atol=scale,
                               rtol=0)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_quantize_ops_wrappers(use_pallas):
    key = jax.random.PRNGKey(13)
    x = jax.random.normal(key, (333,))
    u = jax.random.uniform(jax.random.fold_in(key, 1), (333,))
    scale = float(jnp.max(jnp.abs(x))) / 127
    got = ops.quantize_stoch(x, u, scale, use_pallas=use_pallas)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.quantize_stoch_ref(x, u, scale,
                                                           127)))
    deq = ops.dequantize(got, scale, use_pallas=use_pallas)
    np.testing.assert_array_equal(
        np.asarray(deq), np.asarray(ref.dequantize_ref(got, scale)))


@pytest.mark.parametrize("n,block", [(1000, 256), (131, 128), (77, 65536)])
def test_adafbio_update_nondivisible(n, block):
    key = jax.random.PRNGKey(6)
    k1, k2, k3 = jax.random.split(key, 3)
    p = jax.random.normal(k1, (n,))
    w = jax.random.normal(k2, (n,))
    a = jnp.abs(jax.random.normal(k3, (n,)))
    got = adafbio_update(p, w, a, 0.01, 1e-4, block=block, interpret=True)
    want = ref.adafbio_update_ref(p, w, a, 0.01, 1e-4)
    assert got.shape == (n,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6,
                               rtol=1e-6)


# ----------------------------------------------------- flat-buffer tree path

def _param_tree(key, dtype_x=jnp.float32):
    """Odd leaf sizes on purpose: exercises pack padding + unpack slicing."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {"emb": jax.random.normal(k1, (13, 7), jnp.float32).astype(dtype_x),
            "head": {"w": jax.random.normal(k2, (5, 11), jnp.float32)
                     .astype(dtype_x),
                     "b": jax.random.normal(k3, (3,), jnp.float32)
                     .astype(dtype_x)}}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tree_pack_roundtrip(dtype):
    tree = _param_tree(jax.random.PRNGKey(0), dtype)
    flat, spec = tree_pack(tree)
    assert flat.ndim == 1 and flat.shape[0] % 128 == 0
    out = tree_unpack(flat, spec)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-2 if dtype == jnp.bfloat16 else 0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_storm_update_tree(dtype, use_pallas):
    key = jax.random.PRNGKey(7)
    g_new = _param_tree(jax.random.fold_in(key, 0), dtype)
    g_old = _param_tree(jax.random.fold_in(key, 1), dtype)
    est = _param_tree(jax.random.fold_in(key, 2), dtype)
    got = ops.storm_update_tree(g_new, g_old, est, 0.25,
                                use_pallas=use_pallas, interpret=True,
                                block=128)
    want = jax.tree.map(lambda n, o, e: ref.storm_update_ref(n, o, e, 0.25),
                        g_new, g_old, est)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=tol,
                                   rtol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_adafbio_update_tree(dtype, use_pallas):
    key = jax.random.PRNGKey(8)
    p = _param_tree(jax.random.fold_in(key, 0), dtype)
    w = _param_tree(jax.random.fold_in(key, 1), dtype)
    a = jax.tree.map(jnp.abs, _param_tree(jax.random.fold_in(key, 2)))
    got = ops.adafbio_update_tree(p, w, a, 0.01, 1e-4,
                                  use_pallas=use_pallas, interpret=True,
                                  block=128)
    want = jax.tree.map(
        lambda pi, wi, ai: ref.adafbio_update_ref(pi, wi, ai, 0.01, 1e-4),
        p, w, a)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    for x, y in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert x.dtype == y.dtype and x.shape == y.shape
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=tol,
                                   rtol=tol)


@pytest.mark.parametrize("b,s,di,n", [(1, 32, 256, 8), (2, 64, 1024, 16)])
def test_mamba_scan(b, s, di, n):
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, di)) * 0.1
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, di)))
    A = -jnp.abs(jax.random.normal(ks[2], (di, n)))
    Bm = jax.random.normal(ks[3], (b, s, n)) * 0.1
    Cm = jax.random.normal(ks[4], (b, s, n)) * 0.1
    y1, h1 = mamba_scan(x, dt, A, Bm, Cm, block_d=min(256, di),
                        interpret=True)
    y2, h2 = ref.mamba_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5,
                               rtol=1e-5)


def test_mamba_scan_matches_model_layer():
    """The kernel's recurrence equals the model's chunked associative scan."""
    from repro.models import ssm as ssm_lib
    b, s, di, n = 1, 64, 128, 8
    key = jax.random.PRNGKey(4)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, di)) * 0.1
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, di)))
    A = -jnp.abs(jax.random.normal(ks[2], (di, n)))
    Bm = jax.random.normal(ks[3], (b, s, n)) * 0.1
    Cm = jax.random.normal(ks[4], (b, s, n)) * 0.1
    # model-internal chunked scan
    a = jnp.exp(dt[..., None] * A)
    bx = (dt * x)[..., None] * Bm[:, :, None, :]
    hs, _ = ssm_lib._selective_scan_chunk(a, bx, jnp.zeros((b, di, n)))
    y_model = jnp.einsum("bcdn,bcn->bcd", hs, Cm)
    y_kernel, _ = mamba_scan(x, dt, A, Bm, Cm, block_d=128, interpret=True)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               atol=1e-4, rtol=1e-4)
