"""Mega-scan tier parity matrix (repro.fed.round.make_multi_round and the
FedDriver ``rounds_per_scan`` chunking): compiling R whole rounds into ONE
scanned donated-carry program must reproduce R sequential single-round
programs BIT-identically — client states, server, EF bank, last_sync,
staleness histogram, wire bytes and sample counts — across
{scan, population, async} × {none, int8, topk+EF} × {uniform, tiers delay}
× R ∈ {1, 3, 7} (11 rounds, so R=3 and R=7 both end on a trailing partial
chunk). R=1 must reduce to today's per-round program."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import PopulationConfig
from repro.core.baselines import make_algorithm
from repro.fed.population import (init_async_state, make_async_round,
                                  make_multi_async_round,
                                  make_multi_population_round,
                                  make_population_round)
from repro.fed.round import make_multi_round
from repro.fed.sampling import UniformSampler
from tests.test_system import _quad_driver

STEPS = 44          # 11 rounds of q=4: R=3 → 3+3+3+2 chunks, R=7 → 7+4
R_GRID = (1, 3, 7)

CODECS = {
    "none": {},
    "int8": dict(codec="int8", codec_bits=4),
    "topk": dict(codec="topk", topk_frac=0.5, error_feedback=True),
}


def _driver(codec="none", engine="scan", rounds_per_scan=1, steps=STEPS,
            pop=None):
    d = _quad_driver("adafbio", m=8)
    d.engine = engine
    d.rounds_per_scan = rounds_per_scan
    if CODECS[codec]:
        d.fed = dataclasses.replace(d.alg.fed, **CODECS[codec])
        d.alg = make_algorithm("adafbio", d.fed, d.problem)
    if pop is not None:
        d.population = PopulationConfig(n=8, cohort=4, **pop)
    r = d.run(steps, key=jax.random.PRNGKey(0), eval_every=8)
    return d, r


def _assert_tree_equal(a, b, label):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), label
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"{label}[leaf {i}]")


def _assert_run_equal(ref, got, label, drivers=None):
    """Bit-identity of everything the mega program carries: final states,
    cumulative samples / comms / wire bytes, the recorded metric at the
    final (shared) record, and — via drivers — the final bank and the
    async staleness bookkeeping."""
    _assert_tree_equal(ref.final_avg_state, got.final_avg_state,
                       f"{label}: final_avg_state")
    assert ref.samples[-1] == got.samples[-1], label
    assert ref.comms[-1] == got.comms[-1], label
    assert ref.bytes_up[-1] == got.bytes_up[-1], label
    assert ref.bytes_down[-1] == got.bytes_down[-1], label
    np.testing.assert_array_equal(ref.grad_norm[-1], got.grad_norm[-1],
                                  err_msg=f"{label}: grad_norm")
    if drivers is not None:
        dref, dgot = drivers
        if hasattr(dref, "final_bank"):
            _assert_tree_equal(dref.final_bank, dgot.final_bank,
                               f"{label}: final_bank")
        if hasattr(dref, "staleness_hist"):
            np.testing.assert_array_equal(dref.staleness_hist,
                                          dgot.staleness_hist,
                                          err_msg=f"{label}: hist")
            assert dref.staleness_log == dgot.staleness_log, label
        if hasattr(dref, "staleness_hist_by_tier"):
            assert (dref.staleness_hist_by_tier.keys()
                    == dgot.staleness_hist_by_tier.keys()), label
            for k in dref.staleness_hist_by_tier:
                np.testing.assert_array_equal(
                    dref.staleness_hist_by_tier[k],
                    dgot.staleness_hist_by_tier[k],
                    err_msg=f"{label}: tier hist {k}")


# ------------------------------------------------------- scan engine

@pytest.mark.parametrize("codec", ["none", "int8", "topk"])
def test_scan_engine_parity(codec):
    """Plain scan engine (all M clients every round): mega-scan(R) ≡ R
    sequential rounds for every codec, R=1 included."""
    dref, ref = _driver(codec=codec)
    for R in R_GRID:
        dgot, got = _driver(codec=codec, rounds_per_scan=R)
        _assert_run_equal(ref, got, f"scan/{codec}/R={R}")


@pytest.mark.parametrize("R", [3, 7])
def test_scan_engine_parity_trailing_partial_round(R):
    """46 steps = 11 full rounds + a 2-step partial round: the partial
    round peels out of the chunking and still matches bit-for-bit."""
    _, ref = _driver(steps=46)
    _, got = _driver(steps=46, rounds_per_scan=R)
    _assert_run_equal(ref, got, f"scan/partial-round/R={R}")


# ------------------------------------------------------- population engine

@pytest.mark.parametrize("codec", ["none", "int8", "topk"])
def test_population_engine_parity(codec):
    """Cohort-sampled population rounds: the chunked program fuses cohort
    draw + gather + round + EF scatter + sync and matches exactly —
    including the unique-transmitter wire accounting."""
    dref, ref = _driver(codec=codec, pop={})
    for R in R_GRID:
        dgot, got = _driver(codec=codec, rounds_per_scan=R, pop={})
        _assert_run_equal(ref, got, f"population/{codec}/R={R}",
                          drivers=(dref, dgot))


@pytest.mark.parametrize("sampler", ["roundrobin", "trace"])
def test_population_engine_parity_samplers(sampler):
    """roundrobin re-draws inside the scan; the trace sampler keeps its
    host-side draw and ships the chunk's cohorts as scan inputs."""
    dref, ref = _driver(pop={"sampler": sampler})
    dgot, got = _driver(rounds_per_scan=3, pop={"sampler": sampler})
    _assert_run_equal(ref, got, f"population/{sampler}/R=3",
                      drivers=(dref, dgot))


# ------------------------------------------------------- async engine

ASYNC = dict(max_staleness=3.0, max_delay=3)
TIERS = dict(max_staleness=4.0, max_delay=4, delay_model="tiers",
             delay_eta=0.5)


@pytest.mark.parametrize("pop,codec", [
    (ASYNC, "none"),
    (TIERS, "none"),
    (ASYNC, "topk"),
    pytest.param(TIERS, "topk", marks=pytest.mark.slow),
])
def test_async_engine_parity(pop, codec):
    """Async rounds (pending buffer, bounded-staleness gate, delay-adaptive
    eta): per-round stats come back stacked per chunk and the host-side
    staleness histogram / log rebuild identically."""
    dref, ref = _driver(codec=codec, pop=dict(pop))
    for R in R_GRID:
        dgot, got = _driver(codec=codec, rounds_per_scan=R, pop=dict(pop))
        _assert_run_equal(ref, got, f"async/{codec}/R={R}",
                          drivers=(dref, dgot))


# ------------------------------------------------------- 2-device mesh

@pytest.fixture(scope="module")
def two_devices():
    if len(jax.devices()) < 2:
        pytest.skip("needs the 2-way forced host platform (conftest.py)")
    return jax.make_mesh((2, 1), ("data", "model"))


def _mesh_driver(mesh, rounds_per_scan=1, pop=None, codec="none"):
    d = _quad_driver("adafbio", m=8)
    d.rounds_per_scan = rounds_per_scan
    if CODECS[codec]:
        d.fed = dataclasses.replace(d.alg.fed, **CODECS[codec])
        d.alg = make_algorithm("adafbio", d.fed, d.problem)
    d.population = PopulationConfig(n=8, cohort=4, **(pop or {}))
    d.mesh = mesh
    r = d.run(STEPS, key=jax.random.PRNGKey(0), eval_every=8)
    return d, r


@pytest.mark.parametrize("pop,codec", [
    ({}, "none"),
    (dict(max_staleness=3.0, max_delay=3), "none"),
    pytest.param({}, "topk", marks=pytest.mark.slow),
])
def test_mesh_parity(two_devices, pop, codec):
    """The sharded-bank mega programs (explicit in/out shardings over the
    2-device client mesh) reproduce the mesh R=1 trajectory bit-for-bit —
    population and async engines, trailing partial chunks included."""
    dref, ref = _mesh_driver(two_devices, pop=dict(pop), codec=codec)
    for R in (3, 7):
        dgot, got = _mesh_driver(two_devices, rounds_per_scan=R,
                                 pop=dict(pop), codec=codec)
        _assert_run_equal(ref, got, f"mesh/{codec}/R={R}",
                          drivers=(dref, dgot))


# ------------------------------------------------------- engine-level carry

def _toy_population(q=2):
    def local(states, server, batch, key, ids):
        bump = batch.mean() + 0.01 * ids.sum().astype(jnp.float32)
        return jax.tree.map(lambda a: a + bump, states), server

    def sync(server, avg):
        return avg, server
    return make_population_round(local, sync, q=q)


def test_multi_population_round_matches_sequential_carry():
    """Direct engine check of EVERY carry component: bank, last_sync and
    server out of make_multi_population_round equal R sequential
    make_population_round calls bit-for-bit."""
    q, n, c, R = 2, 6, 2, 4
    round_fn = _toy_population(q)
    mega = jax.jit(make_multi_population_round(round_fn, lossy=False))
    key = jax.random.PRNGKey(3)
    sampler = UniformSampler(n, c, jax.random.fold_in(key, 23))
    ids_R = jnp.stack([sampler.cohort(r) for r in range(R)])
    batches_R = jax.random.normal(key, (R, q, c))

    bank = {"x": jnp.zeros((n, 3))}
    ls = jnp.zeros((n,), jnp.int32)
    server = {"s": jnp.zeros(())}
    seq = (bank, ls, server)
    one = jax.jit(round_fn)
    for r in range(R):
        seq = one(*seq, ids_R[r], batches_R[r], key, jnp.int32(r))
    fused = mega(bank, ls, server, ids_R, batches_R, key, jnp.int32(0))
    for part, a, b in zip(("bank", "last_sync", "server"), seq, fused):
        _assert_tree_equal(a, b, f"carry {part}")

    # in-scan cohort re-draw: ids ride as None and the draw happens inside
    mega_cf = jax.jit(make_multi_population_round(
        round_fn, lossy=False, cohort_fn=sampler.cohort))
    fused2 = mega_cf(bank, ls, server, None, batches_R, key, jnp.int32(0))
    for part, a, b in zip(("bank", "last_sync", "server"), seq, fused2):
        _assert_tree_equal(a, b, f"in-scan carry {part}")


def test_multi_async_round_matches_sequential_carry():
    """Async engine-level check: the full async state dict (bank, pending,
    in_flight, return_round, anchor, last_sync) and the stacked per-round
    stats equal the sequential trajectory."""
    q, n, c, R = 2, 5, 2, 3
    def local(states, server, batch, key, ids):
        return jax.tree.map(lambda a: a + 1.0 + batch.mean(), states), server

    def sync(server, avg):
        return avg, server
    round_fn = make_async_round(local, sync, q=q, max_staleness=float("inf"),
                                max_delay=2)
    key = jax.random.PRNGKey(5)
    sampler = UniformSampler(n, c, jax.random.fold_in(key, 23))
    ids_R = jnp.stack([sampler.cohort(r) for r in range(R)])
    batches_R = jax.random.normal(key, (R, q, c))

    state = init_async_state({"x": jnp.zeros((n,))}, {}, n)
    one = jax.jit(round_fn)
    seq_stats = []
    for r in range(R):
        state, st = one(state, ids_R[r], batches_R[r], key, jnp.int32(r))
        seq_stats.append(st)
    mega = jax.jit(make_multi_async_round(round_fn))
    state2, stats_R = mega(init_async_state({"x": jnp.zeros((n,))}, {}, n),
                           ids_R, batches_R, key, jnp.int32(0))
    _assert_tree_equal(state, state2, "async state")
    for k in seq_stats[0]:
        np.testing.assert_array_equal(
            np.stack([np.asarray(s[k]) for s in seq_stats]),
            np.asarray(stats_R[k]), err_msg=f"stats {k}")


def test_multi_round_length_one_reduces_to_single_call():
    """R=1 is exactly today's program: one scanned iteration returns the
    same carry as calling the round function directly."""
    def round_fn(carry, ids, batch_q, key, rid):
        return carry + batch_q.sum() + rid.astype(jnp.float32), None

    multi = make_multi_round(round_fn)
    batches = jnp.ones((1, 2, 3))
    out, _ = jax.jit(multi)(jnp.float32(0.5), None, batches,
                            jax.random.PRNGKey(0), jnp.int32(4))
    ref, _ = round_fn(jnp.float32(0.5), None, batches[0],
                      jax.random.PRNGKey(0), jnp.int32(4))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_driver_rejects_bad_rounds_per_scan():
    d = _quad_driver("adafbio", m=4)
    with pytest.raises(ValueError, match="rounds_per_scan"):
        dataclasses.replace(d, rounds_per_scan=0)
