"""Mesh-sharded async round: ``jitted("async_population_round")`` on a REAL
multi-device mesh (the host platform is split into 2 CPU devices in
conftest.py) must produce the single-device trajectory — the ROADMAP's
"shardings wired but untested on real meshes" follow-up. Also covers the
codec path's EF-bank shardings on the same mesh."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FedConfig, get_arch, reduced
from repro.configs.base import ShapeConfig
from repro.core.tree_util import tree_stack
from repro.fed.runtime import FederatedTrainer, client_batch_specs

N, C = 4, 2
ASYNC_OPTS = {"max_staleness": float("inf"), "max_delay": 2}


def _batch_at(specs, key, vocab, t):
    kk = jax.random.fold_in(key, t)
    return {k: (jax.random.randint(kk, v.shape, 0, vocab)
                if v.dtype == jnp.int32 else jnp.zeros(v.shape, v.dtype))
            for k, v in specs.items()}


def _run_async(mesh, codec="none", rounds=3):
    # f32 keeps the cross-mesh comparison at tight tolerance (bf16 would
    # only allow 1e-2); the reduced arch still exercises the real model
    cfg = reduced(get_arch("qwen1.5-4b"), dtype="float32")
    fed = FedConfig(q=2, neumann_k=2, lr_x=1e-2, lr_y=1e-1, codec=codec,
                    topk_frac=0.5)
    shape = ShapeConfig("t", 16, 2, "train")
    tr = FederatedTrainer(cfg, fed, shape, mesh=mesh)
    key = jax.random.PRNGKey(3)
    specs_c, axes = client_batch_specs(cfg, shape, C, fed)
    specs_n = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((N,) + s.shape[1:], s.dtype), specs_c)
    state = tr.init_async_population_states(
        key, _batch_at(specs_n, key, cfg.vocab, 0), N)
    round_fn = tr.jitted("async_population_round", specs_c, axes,
                         population_n=N, async_opts=dict(ASYNC_OPTS))
    all_stats = []
    for r in range(rounds):
        ids = jnp.asarray([(r + 1) % N, (r + 3) % N], jnp.int32)
        bq = tree_stack([_batch_at(specs_c, key, cfg.vocab, r * fed.q + j)
                         for j in range(fed.q)])
        state, stats = round_fn(state, ids, bq, key, jnp.int32(r))
        all_stats.append({k: np.asarray(v) for k, v in stats.items()})
    return state, all_stats


@pytest.fixture(scope="module")
def two_devices():
    if len(jax.devices()) < 2:
        pytest.skip("needs the 2-way forced host platform (conftest.py)")
    return jax.make_mesh((2, 1), ("data", "model"))


def test_async_round_on_mesh_matches_single_device(two_devices):
    """Output parity: the 2-device data-sharded async round program computes
    the same states and stats as the unsharded single-device path."""
    s0, st0 = _run_async(None)
    s1, st1 = _run_async(two_devices)
    for pa, (a, b) in zip(
            jax.tree_util.tree_leaves_with_path(s0["bank"]),
            zip(jax.tree.leaves(s0["bank"]), jax.tree.leaves(s1["bank"]))):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-5, rtol=1e-5,
                                   err_msg=f"bank{pa[0]}")
    for k in ("in_flight", "return_round", "last_sync", "dispatch_round"):
        np.testing.assert_array_equal(np.asarray(s0[k]), np.asarray(s1[k]))
    for a, b in zip(st0, st1):
        for k in ("arrived", "accepted", "dropped", "dispatched", "synced"):
            assert int(a[k]) == int(b[k]), k
        np.testing.assert_array_equal(a["staleness"], b["staleness"])


def test_async_state_is_partitioned_on_mesh(two_devices):
    """Layout, not just parity: bank and pending rows (and the [N]
    bookkeeping vectors) PARTITION over the client mesh axis — each device
    holds N/2 rows and half the bytes (docs/sharding.md)."""
    s1, _ = _run_async(two_devices)
    for part in ("bank", "pending"):
        for leaf in jax.tree.leaves(s1[part]):
            shards = leaf.addressable_shards
            assert len(shards) == 2, part
            assert sorted(s.data.shape[0] for s in shards) == [N // 2] * 2
            assert sum(s.data.nbytes for s in shards) == leaf.nbytes
    for vec in ("last_sync", "in_flight", "dispatch_round", "return_round"):
        shards = s1[vec].addressable_shards
        assert sorted(s.data.shape[0] for s in shards) == [N // 2] * 2


def test_async_round_on_mesh_with_codec(two_devices):
    """The lossy-codec async program (EF bank sharded like the state bank)
    runs on the mesh and matches the single-device codec path."""
    s0, _ = _run_async(None, codec="topk")
    s1, _ = _run_async(two_devices, codec="topk")
    assert "ef" in s0 and "ef" in s1
    for a, b in zip(jax.tree.leaves(s0["bank"]), jax.tree.leaves(s1["bank"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-5, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s0["ef"]), jax.tree.leaves(s1["ef"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)


def test_sync_population_round_on_mesh(two_devices):
    """The synchronous population round program also holds parity on the
    mesh (same trainer wiring, no async bookkeeping) — and its lossy-codec
    variant (EF bank sharded + donated alongside the state bank) runs over
    consecutive rounds with the outputs rebound, all finite."""
    cfg = reduced(get_arch("qwen1.5-4b"), dtype="float32")
    shape = ShapeConfig("t", 16, 2, "train")
    key = jax.random.PRNGKey(5)
    outs = []
    for mesh in (None, two_devices):
        fed = FedConfig(q=2, neumann_k=2, lr_x=1e-2, lr_y=1e-1)
        tr = FederatedTrainer(cfg, fed, shape, mesh=mesh)
        specs_c, axes = client_batch_specs(cfg, shape, C, fed)
        specs_n = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((N,) + s.shape[1:], s.dtype),
            specs_c)
        bank, last_sync, server = tr.init_population_states(
            key, _batch_at(specs_n, key, cfg.vocab, 0), N)
        round_fn = tr.jitted("population_round", specs_c, axes,
                             population_n=N)
        bq = tree_stack([_batch_at(specs_c, key, cfg.vocab, j)
                         for j in range(fed.q)])
        bank, last_sync, server = round_fn(
            bank, last_sync, server, jnp.asarray([1, 3], jnp.int32), bq,
            key, jnp.int32(0))
        outs.append(bank)
    for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-5, rtol=1e-5)
    # the mesh bank is genuinely PARTITIONED: every leaf splits its leading
    # population axis across the 2 devices — N/2 rows, half the bytes each
    for leaf in jax.tree.leaves(outs[1]):
        shards = leaf.addressable_shards
        assert len(shards) == 2
        assert sorted(s.data.shape[0] for s in shards) == [N // 2] * 2
        assert sum(s.data.nbytes for s in shards) == leaf.nbytes
    # lossy codec: the jitted program donates bank AND EF bank — run two
    # rounds rebinding the outputs (the only legal use of donated args)
    fed = FedConfig(q=2, neumann_k=2, lr_x=1e-2, lr_y=1e-1, codec="topk",
                    topk_frac=0.5)
    tr = FederatedTrainer(cfg, fed, shape, mesh=two_devices)
    specs_c, axes = client_batch_specs(cfg, shape, C, fed)
    specs_n = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((N,) + s.shape[1:], s.dtype),
        specs_c)
    bank, last_sync, server = tr.init_population_states(
        key, _batch_at(specs_n, key, cfg.vocab, 0), N)
    ef = tr.init_ef_bank(N)
    round_fn = tr.jitted("population_round", specs_c, axes, population_n=N)
    for r in range(2):
        bq = tree_stack([_batch_at(specs_c, key, cfg.vocab, r * fed.q + j)
                         for j in range(fed.q)])
        bank, last_sync, ef, server = round_fn(
            bank, last_sync, ef, server,
            jnp.asarray([r, r + 2], jnp.int32), bq, key, jnp.int32(r))
    for leaf in jax.tree.leaves(bank) + jax.tree.leaves(ef):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()
