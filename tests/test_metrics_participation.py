"""Analysis-quantity metrics (Theorem 1 / Lemmas 20-21) + partial
participation + the extra adaptive-matrix instances."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FedConfig
from repro.core import adaptive as ada
from repro.core.metrics import consensus_error
from tests.test_system import _quad_driver


def test_consensus_error_zero_after_sync_grows_between():
    """Lemma 21's base case: states are equal right after a sync; the
    consensus error grows during the local phase."""
    d = _quad_driver("adafbio")
    d.track_consensus = True
    d.run(17, eval_every=100)
    # logged at each sync BEFORE averaging: should be > 0 (local drift)
    assert len(d.consensus_log) >= 3
    for row in d.consensus_log:
        assert row["x"] > 0.0          # clients drifted between syncs
    # and the driver's final average is well defined / finite
    assert np.isfinite(row["x"])


def test_consensus_grows_with_q():
    """Lemma 20: per-sync consensus error scales with the local-phase length."""
    import dataclasses
    errs = {}
    for q in (2, 8):
        d = _quad_driver("adafbio")
        d.alg = dataclasses.replace(d.alg,
                                    fed=dataclasses.replace(d.alg.fed, q=q))
        d.track_consensus = True
        d.run(33, eval_every=100)
        errs[q] = np.mean([r["x"] for r in d.consensus_log])
    assert errs[8] > errs[2]


def test_partial_participation_still_converges():
    d = _quad_driver("adafbio")
    d.participation = 0.5
    r = d.run(120, eval_every=30)
    assert np.isfinite(r.grad_norm).all()
    assert r.grad_norm[-1] < 0.6 * r.grad_norm[0]


@pytest.mark.parametrize("kind", ["amsgrad", "adagrad"])
def test_extra_adaptive_variants(kind):
    key = jax.random.PRNGKey(0)
    x = {"p": jax.random.normal(key, (8,))}
    st = ada.init_adaptive_state(x, kind)
    prev_amax = None
    for i in range(4):
        w = {"p": jax.random.normal(jax.random.fold_in(key, i), (8,))}
        v = {"p": jax.random.normal(jax.random.fold_in(key, 50 + i), (3,))}
        st = ada.update_adaptive(st, w, v, kind=kind, varrho=0.9)
        if kind == "amsgrad":
            if prev_amax is not None:       # monotone preconditioner
                assert (st["a_max"]["p"] >= prev_amax - 1e-6).all()
            prev_amax = st["a_max"]["p"]
    out = ada.precondition_x(st, w, kind=kind, rho=0.1)
    assert np.isfinite(np.asarray(out["p"])).all()


def test_adaptive_variants_run_end_to_end():
    import dataclasses
    from repro.core.baselines import make_algorithm
    for kind in ("amsgrad", "adagrad"):
        d = _quad_driver("adafbio")
        fed = dataclasses.replace(d.alg.fed, adaptive=kind)
        d.fed = fed
        d.alg = make_algorithm("adafbio", fed, d.problem)
        r = d.run(30, eval_every=29)
        assert np.isfinite(r.grad_norm).all()
