"""Telemetry subsystem (repro.obs): record schema, progress formats, the
strictly-observational parity guarantee, on-device stat accumulation,
profiler annotations, and the async round-timing fence.

The two load-bearing pins:

  * **parity** — attaching a live Telemetry bus (sinks + StatAccum) to a
    FedDriver run must leave the trajectory BIT-identical on all four
    engines: the stats are computed by a separate jitted program on each
    round's output states, never folded into the round programs.
  * **fence** — the async engine's per-round wall-clock must measure
    completion, not dispatch: a forced sleep inside the round program
    lower-bounds every recorded round time.
"""
import json
import pathlib
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import PopulationConfig
from repro.obs import (JsonlSink, MemorySink, StatAccum, Telemetry,
                       progress_line, run_manifest)
from repro.obs.telemetry import SCHEMA
from repro.tasks.driver import FedDriver

sys.path.insert(0, ".")
from tests.test_system import _quad_driver  # noqa: E402

ROOT = pathlib.Path(__file__).resolve().parents[1]


# ------------------------------------------------------------------ manifest

def test_manifest_fields():
    man = run_manifest(config={"steps": 5, "arch": "x"}, seed=7,
                       extra_field="v")
    assert man["kind"] == "manifest"
    assert man["schema"] == SCHEMA
    for k in ("run_id", "created", "argv", "host", "python", "jax_version",
              "platform", "device_count", "devices", "git_sha", "seed"):
        assert k in man, k
    assert man["seed"] == 7
    assert man["config"]["steps"] == 5
    assert man["extra_field"] == "v"
    assert man["jax_version"] == jax.__version__
    assert man["device_count"] == len(jax.devices())
    # the manifest must be JSON-encodable as-is (sinks json.dumps it)
    json.dumps(man)


def test_manifest_emitted_first_and_flushed():
    sink = MemorySink()
    tele = Telemetry([sink], metrics_every=4)
    tele.manifest(config={"a": 1}, seed=0)
    # manifest flushes immediately — no waiting for the round cadence
    assert sink.records and sink.records[0]["kind"] == "manifest"
    tele.close()


def test_round_buffering_flush_cadence():
    sink = MemorySink()
    tele = Telemetry([sink], metrics_every=3)
    tele.round(0, round_seconds=0.1)
    tele.round(1, round_seconds=0.1)
    assert sink.of_kind("round") == []          # buffered, not yet flushed
    tele.round(2, round_seconds=0.1)
    assert len(sink.of_kind("round")) == 3      # flushed at the window
    tele.close()
    summary = sink.of_kind("summary")
    assert len(summary) == 1 and summary[0]["rounds"] == 3


def test_metrics_every_validation():
    with pytest.raises(ValueError):
        Telemetry([], metrics_every=0)


# ------------------------------------------------------------------ progress

def test_progress_line_eager_format():
    # the legacy eager per-step print, character for character
    loss, el, t = 0.123456, 4.25, 7
    assert (progress_line(loss=loss, elapsed=el, step=t)
            == f"step {t:5d}  f(x̄,ȳ) = {loss:.4f}  ({el:.1f}s)")


def test_progress_line_scan_format():
    loss, el, t, r, dt = 5.0 / 3, 12.04, 47, 11, 0.01234
    assert (progress_line(loss=loss, elapsed=el, step=t, round=r,
                          round_seconds=dt)
            == f"round {r:4d} (step {t:5d})  f(x̄,ȳ) = {loss:.4f}  "
               f"round={dt*1e3:.1f}ms  ({el:.1f}s)")


def test_progress_line_population_format():
    loss, el, t, r, dt = 2.5, 100.0, 39, 4, 0.5
    up, dn = 37_850_000, 151_390_000
    ids = [7, 4, 1, 0, 2, 9, 8, 3, 6, 5]       # > 8 ids: truncated display
    assert (progress_line(loss=loss, elapsed=el, step=t, round=r,
                          round_seconds=dt, bytes_up=up, bytes_down=dn,
                          cohort=ids)
            == f"round {r:4d} (step {t:5d})  f(x̄,ȳ) = {loss:.4f}  "
               f"round={dt*1e3:.1f}ms  "
               f"up={up/1e6:.2f}MB down={dn/1e6:.2f}MB  "
               f"cohort={ids[:8]}...  ({el:.1f}s)")


def test_progress_line_async_format():
    loss, el, t, r, dt = 0.9, 3.3, 15, 3, 0.002
    assert (progress_line(loss=loss, elapsed=el, step=t, round=r,
                          round_seconds=dt, arrived=3, dropped=1,
                          mean_staleness=1.5, eta_scale=0.87,
                          bytes_up=1_000_000, bytes_down=2_000_000,
                          cohort=[0, 1])
            == f"round {r:4d} (step {t:5d})  f(x̄,ȳ) = {loss:.4f}  "
               f"round={dt*1e3:.1f}ms  "
               f"arrived=3 dropped=1 tau=1.50 eta_scale=0.870  "
               f"up=1.00MB down=2.00MB  cohort=[0, 1]...  ({el:.1f}s)")


# ------------------------------------------------------------------ devstats

def test_statacc_update_norm_and_ring():
    states = {"x": jnp.ones((4, 3)), "y": jnp.zeros((4, 2))}
    acc = StatAccum.create(states, k=3)
    for _ in range(3):
        acc.update(states)                      # identical states each round
    assert acc.ready
    out = acc.drain()
    assert out["round_start"] == 0
    assert len(out["global_norm"]) == 3
    # avg state is (ones(3), zeros(2)) -> global norm sqrt(3)
    assert out["global_norm"] == pytest.approx([3.0 ** 0.5] * 3)
    # the mean state never moves -> update norm 0 every round
    assert out["update_norm"] == pytest.approx([0.0] * 3, abs=1e-7)
    # partial tail window: round_start advances past the drained rows
    acc.update(jax.tree.map(lambda a: a * 2.0, states))
    assert not acc.ready and acc.pending == 1
    tail = acc.drain()
    assert tail["round_start"] == 3
    assert len(tail["update_norm"]) == 1
    assert tail["update_norm"][0] > 0.0         # the mean moved this time


def test_statacc_consensus_zero_for_identical_rows():
    states = {"x": jnp.ones((5, 2)) * 3.0}
    acc = StatAccum.create(states, k=2, consensus=True)
    assert acc.fields == ("global_norm", "update_norm", "consensus")
    acc.update(states)
    out = acc.drain()
    assert out["consensus"] == pytest.approx([0.0], abs=1e-7)


# ------------------------------------------------------------------ parity

def _result_tuple(r):
    return (r.steps, r.samples, r.comms, r.bytes_up, r.bytes_down)


def _engines():
    yield "eager", {}
    yield "scan", {}
    yield "population", {"population": PopulationConfig(n=8, cohort=2)}
    yield "async", {"population": PopulationConfig(
        n=8, cohort=2, max_staleness=4.0, max_delay=2)}


@pytest.mark.parametrize("name,cfg", list(_engines()))
def test_telemetry_parity_bit_identical(name, cfg):
    """Attaching a live bus (sink + on-device StatAccum) never changes the
    trajectory: every counter and every float of the run is IDENTICAL."""
    def run(with_tele):
        d = _quad_driver("adafbio", m=8)
        if "population" in cfg:
            d.population = cfg["population"]
        elif name == "scan":
            d.engine = "scan"
        tele = None
        if with_tele:
            tele = Telemetry([MemorySink()], metrics_every=2)
            d.telemetry = tele
        r = d.run(12, key=jax.random.PRNGKey(0), eval_every=4)
        if tele is not None:
            tele.close()
        return r, tele

    r_off, _ = run(False)
    r_on, tele = run(True)
    assert _result_tuple(r_on) == _result_tuple(r_off)
    # grad_norm is exact; metric may be NaN (no metric_fn on the quad task)
    assert np.array_equal(np.asarray(r_on.grad_norm),
                          np.asarray(r_off.grad_norm))
    assert np.array_equal(np.asarray(r_on.metric), np.asarray(r_off.metric),
                          equal_nan=True)
    for a, b in zip(jax.tree.leaves(r_on.final_avg_state),
                    jax.tree.leaves(r_off.final_avg_state)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # and the instrumented run actually recorded its rounds + stats
    sink = tele.sinks[0]
    rounds = sink.of_kind("round")
    assert len(rounds) == 3
    assert [r["round"] for r in rounds] == [0, 1, 2]
    stats = sink.of_kind("stats")
    assert stats and all(len(s["update_norm"]) >= 1 for s in stats)
    assert sum(len(s["update_norm"]) for s in stats) == 3


@pytest.mark.parametrize("name,cfg",
                         [(n, c) for n, c in _engines() if n != "eager"])
def test_megascan_telemetry_parity_bit_identical(name, cfg):
    """rounds_per_scan > 1 folds the stat rows INTO the mega program as
    unconditional scan outputs — telemetry on and off run the SAME
    compiled bytes. The trajectory and every counter stay bit-identical,
    and the bus still sees one round record per round and one stats row
    per round, drained once per chunk."""
    def run(with_tele):
        d = _quad_driver("adafbio", m=8)
        d.rounds_per_scan = 3
        if "population" in cfg:
            d.population = cfg["population"]
        else:
            d.engine = "scan"
        tele = None
        if with_tele:
            tele = Telemetry([MemorySink()], metrics_every=2)
            d.telemetry = tele
        r = d.run(12, key=jax.random.PRNGKey(0), eval_every=4)
        if tele is not None:
            tele.close()
        return r, tele

    r_off, _ = run(False)
    r_on, tele = run(True)
    assert _result_tuple(r_on) == _result_tuple(r_off)
    assert np.array_equal(np.asarray(r_on.grad_norm),
                          np.asarray(r_off.grad_norm))
    for a, b in zip(jax.tree.leaves(r_on.final_avg_state),
                    jax.tree.leaves(r_off.final_avg_state)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    sink = tele.sinks[0]
    rounds = sink.of_kind("round")
    assert [rec["round"] for rec in rounds] == [0, 1, 2]
    stats = sink.of_kind("stats")
    assert stats and sum(len(s["update_norm"]) for s in stats) == 3
    starts = [s["round_start"] for s in stats]
    assert starts == sorted(starts)


def test_megascan_rejects_consensus_stat():
    """The O(N) consensus stat reads pre-sync states mid-round and cannot
    fold into the chunked program — asking for both is a loud error, not a
    silent drop."""
    d = _quad_driver("adafbio", m=8)
    d.engine = "scan"
    d.rounds_per_scan = 2
    d.telemetry = Telemetry([MemorySink()], metrics_every=2,
                            consensus=True)
    with pytest.raises(ValueError, match="consensus"):
        d.run(12, key=jax.random.PRNGKey(0), eval_every=4)


# ------------------------------------------------------------------ stream

def test_jsonl_roundtrip_and_report_check(tmp_path):
    out = tmp_path / "run.jsonl"
    d = _quad_driver("adafbio", m=8)
    d.population = PopulationConfig(n=8, cohort=2)
    tele = Telemetry([JsonlSink(str(out))], metrics_every=2)
    d.telemetry = tele
    tele.manifest(config={"task": "quad"}, seed=0)
    d.run(12, key=jax.random.PRNGKey(0), eval_every=4)
    tele.close()

    records = [json.loads(line) for line in out.read_text().splitlines()]
    kinds = [r["kind"] for r in records]
    assert kinds[0] == "manifest"
    assert kinds.count("round") == 3
    assert kinds.count("summary") == 1
    assert kinds[-1] == "summary"
    summary = records[-1]
    assert summary["rounds"] == 3
    assert "round_program" in summary["phases"]
    assert summary["phases"]["round_program"]["count"] == 3

    # scripts/report.py validates and renders the same stream (the CI gate)
    chk = subprocess.run([sys.executable, "scripts/report.py", str(out),
                          "--check"], cwd=ROOT, capture_output=True,
                         text=True)
    assert chk.returncode == 0, chk.stderr
    assert "report: OK" in chk.stdout
    ren = subprocess.run([sys.executable, "scripts/report.py", str(out)],
                         cwd=ROOT, capture_output=True, text=True)
    assert ren.returncode == 0, ren.stderr
    assert "rounds: 3" in ren.stdout
    assert "phase breakdown" in ren.stdout


def test_jsonl_chunked_drain_report_check(tmp_path):
    """A mega-scan run's stream — round records emitted per round but
    drained once per chunk, stats rows stacked per chunk — still satisfies
    every scripts/report.py --check invariant (ordered rounds, equal-length
    stat columns, summary.rounds == #round records)."""
    out = tmp_path / "mega.jsonl"
    d = _quad_driver("adafbio", m=8)
    d.rounds_per_scan = 3
    d.population = PopulationConfig(n=8, cohort=2)
    tele = Telemetry([JsonlSink(str(out))], metrics_every=2)
    d.telemetry = tele
    tele.manifest(config={"task": "quad", "rounds_per_scan": 3}, seed=0)
    d.run(20, key=jax.random.PRNGKey(0), eval_every=4)  # 5 rounds: 1+3+1
    tele.close()

    records = [json.loads(line) for line in out.read_text().splitlines()]
    kinds = [r["kind"] for r in records]
    assert kinds[0] == "manifest"
    assert kinds.count("round") == 5
    assert [r["round"] for r in records if r["kind"] == "round"] == list(
        range(5))
    assert records[-1]["rounds"] == 5
    stats = [r for r in records if r["kind"] == "stats"]
    assert sum(len(s["update_norm"]) for s in stats) == 5

    chk = subprocess.run([sys.executable, "scripts/report.py", str(out),
                          "--check"], cwd=ROOT, capture_output=True,
                         text=True)
    assert chk.returncode == 0, chk.stderr
    assert "report: OK" in chk.stdout


def test_report_check_rejects_malformed_stream(tmp_path):
    bad = tmp_path / "bad.jsonl"
    # no manifest, unknown kind, stats with ragged columns
    bad.write_text(json.dumps({"kind": "round", "round": 1}) + "\n"
                   + json.dumps({"kind": "nonsense"}) + "\n"
                   + json.dumps({"kind": "stats", "round_start": 0,
                                 "a": [1.0, 2.0], "b": [1.0]}) + "\n")
    chk = subprocess.run([sys.executable, "scripts/report.py", str(bad),
                          "--check"], cwd=ROOT, capture_output=True,
                         text=True)
    assert chk.returncode == 1
    assert "manifest" in chk.stderr
    assert "unknown kind" in chk.stderr
    assert "unequal" in chk.stderr


# ------------------------------------------------------------------ profiler

@pytest.mark.slow
def test_profile_trace_contains_named_regions(tmp_path):
    """--profile produces a TensorBoard-loadable trace whose raw bytes
    contain the span names (host TraceAnnotations) and the round/* named
    scopes (XLA op metadata)."""
    d = _quad_driver("adafbio", m=8)
    d.population = PopulationConfig(n=8, cohort=2)
    tele = Telemetry([], metrics_every=4, profile_dir=str(tmp_path))
    d.telemetry = tele
    d.run(8, key=jax.random.PRNGKey(0), eval_every=4)
    tele.close()
    traces = list(tmp_path.rglob("*.xplane.pb"))
    assert traces, "no xplane trace written"
    blob = b"".join(t.read_bytes() for t in traces)
    for name in (b"round_program", b"batch_build", b"round/gather",
                 b"round/local_scan", b"round/aggregate", b"round/scatter"):
        assert name in blob, f"annotation {name!r} missing from trace"


# ------------------------------------------------------------------ fence

def test_async_round_timing_forced_sleep(monkeypatch):
    """The async engine fences (block_until_ready) inside its round timer:
    a sleep injected INTO the jitted round program must show up in every
    recorded round time. Without the fence, dispatch returns immediately
    and the recorded times would be ~0."""
    SLEEP = 0.05
    orig = FedDriver._cohort_local_step

    def slowed(self, n):
        step = orig(self, n)

        def nap(t):
            time.sleep(SLEEP)
            return np.asarray(t)

        def slow_step(states, srv, batch, kk, ids):
            states, srv = step(states, srv, batch, kk, ids)
            srv = dict(srv)
            # thread the sleep through the live carry so it cannot be
            # dead-code-eliminated; runs once per local step
            srv["t"] = jax.pure_callback(
                nap, jax.ShapeDtypeStruct(jnp.shape(srv["t"]),
                                          jnp.result_type(srv["t"])),
                srv["t"])
            return states, srv
        return slow_step

    monkeypatch.setattr(FedDriver, "_cohort_local_step", slowed)
    d = _quad_driver("adafbio", m=8)
    d.population = PopulationConfig(n=8, cohort=2, max_staleness=4.0,
                                    max_delay=2)
    q = d.fed.q
    r = d.run(3 * q, key=jax.random.PRNGKey(0), eval_every=100)
    # every round runs q local steps -> >= q * SLEEP of forced wall-clock
    floor = q * SLEEP * 0.9
    assert r.compile_seconds >= floor, r.compile_seconds
    assert len(d.round_seconds) == 2
    for dt in d.round_seconds:
        assert dt >= floor, (dt, d.round_seconds)
