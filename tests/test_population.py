"""Population subsystem (repro.fed.population / sampling, data.partition):
cohort-sampled gather→scan-round→scatter must reproduce the legacy
masked-participation trajectories exactly when given the same cohort
schedule, samplers must honour their policies, and Dirichlet partitioning
must be deterministic and actually skewed."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import PopulationConfig
from repro.data.hyperclean import HyperCleanData
from repro.data.partition import (dirichlet_class_priors, dirichlet_partition,
                                  label_histogram)
from repro.fed.population import (ClientPopulation, broadcast, gather,
                                  make_population_round, scatter,
                                  staleness_weights, weighted_mean)
from repro.fed.sampling import (AvailabilityTraceSampler, RoundRobinSampler,
                                UniformSampler, make_sampler)
from tests.test_system import _quad_driver


# ------------------------------------------------------ masked ≡ cohort path

@pytest.mark.parametrize("steps", [16, 10])
def test_cohort_path_matches_masked_participation(steps):
    """The acceptance property: with the same sampled cohorts, the O(C)
    population path (gather → fused scan round → scatter, broadcast sync)
    reproduces the O(M) masked-participation trajectories — eager AND scan —
    to 1e-5, including a trailing partial round."""
    sampler = UniformSampler(4, 2, jax.random.PRNGKey(9))
    runs = {}
    for mode in ("eager", "scan", "population"):
        d = _quad_driver("adafbio")
        d.sampler = sampler
        if mode == "population":
            d.population = PopulationConfig(n=4, cohort=2)
        else:
            d.participation = 0.5
            d.engine = mode
        runs[mode] = d.run(steps, eval_every=steps)
    for mode in ("scan", "population"):
        for pa, (a, b) in zip(
                jax.tree_util.tree_leaves_with_path(
                    runs["eager"].final_avg_state),
                zip(jax.tree.leaves(runs["eager"].final_avg_state),
                    jax.tree.leaves(runs[mode].final_avg_state))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5, rtol=1e-5,
                                       err_msg=f"{mode}: {pa[0]}")
        np.testing.assert_allclose(runs["eager"].grad_norm[-1],
                                   runs[mode].grad_norm[-1],
                                   atol=1e-5, rtol=1e-4)
        assert runs["eager"].samples[-1] == runs[mode].samples[-1]


def test_population_scales_past_cohort():
    """N ≫ C population runs converge and stay finite (the whole point of
    the subsystem: N is no longer capped by the per-round vmap)."""
    from repro.core.baselines import make_algorithm
    d = _quad_driver("adafbio", m=64)
    # the default step sizes are calibrated for M=4; the eta_t ∝ M^{1/3}
    # schedule needs smaller base rates at M=64
    d.fed = dataclasses.replace(d.alg.fed, lr_x=0.02, lr_y=0.1)
    d.alg = make_algorithm("adafbio", d.fed, d.problem)
    d.population = PopulationConfig(n=64, cohort=4)
    r = d.run(40, eval_every=8)
    assert np.isfinite(r.grad_norm).all()
    # 4-of-64 participation: the first syncs move the average off the shared
    # init (a jump in the exact grad norm), then the descent takes over
    assert r.grad_norm[-1] < 0.9 * max(r.grad_norm)


def test_participants_sync_mode_runs_and_differs_from_broadcast():
    """participants-only sync (clients keep stale models between
    participations) is a genuinely different regime from broadcast."""
    outs = {}
    for mode in ("broadcast", "participants"):
        d = _quad_driver("adafbio", m=8)
        d.sampler = UniformSampler(8, 2, jax.random.PRNGKey(3))
        d.population = PopulationConfig(n=8, cohort=2, sync_mode=mode,
                                        staleness_decay=0.5)
        outs[mode] = d.run(24, eval_every=24)
        assert np.isfinite(outs[mode].grad_norm).all()
    a = np.concatenate([np.asarray(l).ravel() for l in
                        jax.tree.leaves(outs["broadcast"].final_avg_state)])
    b = np.concatenate([np.asarray(l).ravel() for l in
                        jax.tree.leaves(outs["participants"].final_avg_state)])
    assert not np.allclose(a, b, atol=1e-6)


# ------------------------------------------------------ satellite fixes

def test_participation_draws_depend_on_run_key():
    """Regression: the seed hard-wired PRNGKey(23), so every run drew the
    same participation masks regardless of the run key."""
    masks = {}
    for seed in (0, 1):
        d = _quad_driver("adafbio")
        d.participation = 0.5
        d.run(4, key=jax.random.PRNGKey(seed), eval_every=4)
        masks[seed] = np.stack([np.asarray(d._active_mask(r))
                                for r in range(8)])
    assert (masks[0] != masks[1]).any()


def test_compile_seconds_split_from_round_seconds():
    """The first (compile-including) round lands in RunResult.compile_seconds;
    round_seconds holds only steady-state rounds."""
    for mode in ("eager", "scan", "population"):
        d = _quad_driver("adafbio")
        if mode == "population":
            d.population = PopulationConfig(n=4, cohort=2)
        else:
            d.engine = mode
        r = d.run(12, eval_every=12)     # 3 rounds of q=4
        assert r.compile_seconds > 0.0
        # exactly the 2 post-compile rounds land in the steady-state log
        assert len(d.round_seconds) == 2, mode


# ------------------------------------------------------ samplers

def test_uniform_sampler_no_replacement_and_mask_agrees():
    s = UniformSampler(16, 5, jax.random.PRNGKey(0))
    for r in range(6):
        ids = np.asarray(s.cohort(r))
        assert len(set(ids.tolist())) == 5
        assert (ids >= 0).all() and (ids < 16).all()
        mask = np.asarray(s.mask(r))
        assert mask.sum() == 5 and mask[ids].all()
    assert (np.asarray(s.cohort(0)) != np.asarray(s.cohort(1))).any()


def test_roundrobin_covers_population_exactly():
    s = RoundRobinSampler(12, 4)
    seen = np.concatenate([np.asarray(s.cohort(r)) for r in range(3)])
    assert sorted(seen.tolist()) == list(range(12))


def test_trace_sampler_respects_availability():
    s = AvailabilityTraceSampler(32, 4, jax.random.PRNGKey(1),
                                 period=4, duty=0.5)
    for r in range(8):
        up = np.asarray(s.up_mask(r))
        ids = np.asarray(s.cohort(r))
        if up.sum() >= 4:
            assert up[ids].all()
            assert len(set(ids.tolist())) == 4
    # availability rotates: different rounds see different up sets
    assert (np.asarray(s.up_mask(0)) != np.asarray(s.up_mask(2))).any()


def test_trace_sampler_all_down_falls_back_to_uniform():
    """Defined fallback (docs/async.md): when NO client is available the
    draw is uniform without replacement over all N — never an
    all-duplicates cohort of one arbitrary client."""
    from repro.fed.sampling import draw_from_available
    up = jnp.zeros((12,), bool)
    seen = set()
    for r in range(6):
        ids = np.asarray(draw_from_available(up, jax.random.PRNGKey(2), r, 5))
        assert len(set(ids.tolist())) == 5            # no duplicates
        assert (ids >= 0).all() and (ids < 12).all()
        seen.update(ids.tolist())
    assert len(seen) > 5                              # draws vary per round
    # a TraceFileSampler over an all-down trace hits the same fallback
    from repro.fed.sampling import TraceFileSampler
    tf = TraceFileSampler(12, 5, jax.random.PRNGKey(2),
                          np.zeros((3, 12), bool))
    ids = np.asarray(tf.cohort(0))
    assert len(set(ids.tolist())) == 5


def test_trace_file_save_load_roundtrip(tmp_path):
    """save_trace -> load_trace is the identity on dense tables, absent
    clients default to always-up, and malformed traces are rejected."""
    from repro.fed.sampling import load_trace, save_trace
    rng = np.random.default_rng(3)
    table = rng.random((7, 9)) < 0.4
    path = tmp_path / "trace.jsonl"
    save_trace(str(path), table)
    np.testing.assert_array_equal(load_trace(str(path), 9), table)
    # absent clients are always available
    path2 = tmp_path / "partial.jsonl"
    path2.write_text('{"horizon": 4}\n{"client": 1, "up": [[1, 3]]}\n')
    got = load_trace(str(path2), 3)
    np.testing.assert_array_equal(got[:, 0], True)
    np.testing.assert_array_equal(got[:, 1], [False, True, True, False])
    np.testing.assert_array_equal(got[:, 2], True)
    # an explicit horizon FIXES the length: intervals past it are clipped
    path5 = tmp_path / "clip.jsonl"
    path5.write_text('{"horizon": 4}\n{"client": 0, "up": [[0, 10]]}\n')
    got = load_trace(str(path5), 2)
    assert got.shape == (4, 2)
    np.testing.assert_array_equal(got[:, 0], True)
    # client ids outside the population and empty traces are errors
    path3 = tmp_path / "bad.jsonl"
    path3.write_text('{"client": 7, "up": [[0, 2]]}\n')
    with pytest.raises(ValueError):
        load_trace(str(path3), 3)
    path4 = tmp_path / "empty.jsonl"
    path4.write_text('{"client": 0, "up": []}\n')
    with pytest.raises(ValueError):
        load_trace(str(path4), 3)


def test_trace_file_sampler_drives_population_run(tmp_path):
    """End-to-end: a PopulationConfig(sampler='trace-file') run replays the
    trace — cohorts only name available clients (when any are up)."""
    from repro.fed.sampling import TraceFileSampler, save_trace
    rng = np.random.default_rng(0)
    table = rng.random((6, 4)) < 0.6
    path = tmp_path / "t.jsonl"
    save_trace(str(path), table)
    d = _quad_driver("adafbio")
    d.population = PopulationConfig(n=4, cohort=2, sampler="trace-file",
                                    trace_file=str(path))
    r = d.run(12, eval_every=12)
    assert np.isfinite(r.grad_norm).all()
    assert isinstance(d._run_sampler, TraceFileSampler)
    for rd in range(6):
        up = table[rd % 6]
        ids = np.asarray(d._run_sampler.cohort(rd))
        if up.sum() > 0:
            assert up[ids].all()


def test_make_sampler_validates():
    with pytest.raises(KeyError):
        make_sampler("nope", 8, 2, jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        make_sampler("uniform", 8, 9, jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        PopulationConfig(n=8, cohort=9)
    with pytest.raises(ValueError):
        PopulationConfig(n=8, cohort=2, sync_mode="broadcsat")
    with pytest.raises(ValueError):
        PopulationConfig(n=8, cohort=2, sampler="nope")
    # population.n must match the driver's client/data index space, even
    # when `population` is assigned after construction
    d = _quad_driver("adafbio", m=4)
    d.population = PopulationConfig(n=8, cohort=2)
    with pytest.raises(ValueError):
        d.run(4, eval_every=4)


# ------------------------------------------------------ bank primitives

def test_gather_scatter_roundtrip_and_staleness_weights():
    bank = {"x": jnp.arange(12.0).reshape(6, 2)}
    ids = jnp.asarray([4, 1], jnp.int32)
    cohort = gather(bank, ids)
    np.testing.assert_array_equal(np.asarray(cohort["x"]),
                                  [[8.0, 9.0], [2.0, 3.0]])
    bank2 = scatter(bank, ids, jax.tree.map(lambda a: a * 10.0, cohort))
    np.testing.assert_array_equal(np.asarray(bank2["x"][4]), [80.0, 90.0])
    np.testing.assert_array_equal(np.asarray(bank2["x"][0]), [0.0, 1.0])

    last_sync = jnp.asarray([5, 0, 5, 5, 2, 5], jnp.int32)
    w = np.asarray(staleness_weights(last_sync, ids, jnp.int32(5), 1.0))
    np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-6)
    assert w[0] > w[1]          # client 4 (staleness 3) beats client 1 (5)
    wu = np.asarray(staleness_weights(last_sync, ids, jnp.int32(5), 0.0))
    np.testing.assert_allclose(wu, 0.5, rtol=1e-6)


def test_make_population_round_participants_updates_only_cohort():
    """Toy algorithm through the fused round: participants-mode sync writes
    the aggregate back to cohort rows only and stamps their last_sync."""
    def local(states, server, batch, key, ids):
        return jax.tree.map(lambda a: a + 1.0, states), server

    def sync(server, avg):
        return avg, server

    round_fn = make_population_round(local, sync, q=2,
                                     sync_mode="participants")
    bank = {"x": jnp.zeros((5,))}
    last_sync = jnp.zeros((5,), jnp.int32)
    ids = jnp.asarray([3, 0], jnp.int32)
    bank, last_sync, _ = jax.jit(round_fn)(bank, last_sync, {}, ids,
                                           jnp.zeros((2,)),
                                           jax.random.PRNGKey(0),
                                           jnp.int32(4))
    # cohort rows: 2 local +1 steps then the cohort average (2.0)
    np.testing.assert_array_equal(np.asarray(bank["x"]),
                                  [2.0, 0.0, 0.0, 2.0, 0.0])
    np.testing.assert_array_equal(np.asarray(last_sync), [5, 0, 0, 5, 0])


def test_client_population_create_and_broadcast():
    pop = ClientPopulation.create(
        lambda k, b: {"x": b}, jax.random.PRNGKey(0),
        jnp.arange(4.0), n=4)
    assert pop.n == 4 and pop.states["x"].shape == (4,)
    bank = broadcast(pop.states, {"x": jnp.float32(7.0)})
    np.testing.assert_array_equal(np.asarray(bank["x"]), 7.0)
    w = jnp.asarray([0.25, 0.25, 0.25, 0.25])
    np.testing.assert_allclose(
        float(weighted_mean(pop.states, w)["x"]), 1.5, rtol=1e-6)


# ------------------------------------------------------ dirichlet partition

def test_dirichlet_partition_deterministic_disjoint_and_skewed():
    key = jax.random.PRNGKey(11)
    labels = np.asarray(jax.random.randint(jax.random.PRNGKey(5), (600,),
                                           0, 10))
    p1 = dirichlet_partition(key, labels, 8, 0.1)
    p2 = dirichlet_partition(key, labels, 8, 0.1)
    for a, b in zip(p1, p2):
        np.testing.assert_array_equal(a, b)
    allidx = np.concatenate(p1)
    assert len(allidx) == 600 and len(np.unique(allidx)) == 600
    # strong skew at alpha=0.1: clients concentrate on few classes; near
    # uniform at alpha=100
    def max_share(parts):
        h = label_histogram(labels, parts, 10).astype(float)
        h = h[h.sum(1) > 20]                       # clients with enough data
        return (h.max(1) / np.maximum(h.sum(1), 1)).mean()
    skewed = max_share(p1)
    uniform = max_share(dirichlet_partition(key, labels, 8, 100.0))
    assert skewed > uniform + 0.2, (skewed, uniform)


def test_dirichlet_class_priors_shapes_and_determinism():
    key = jax.random.PRNGKey(2)
    p = dirichlet_class_priors(key, 6, 5, 0.5)
    assert p.shape == (6, 5)
    np.testing.assert_allclose(np.asarray(p.sum(axis=1)), 1.0, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(p),
                                  np.asarray(dirichlet_class_priors(key, 6, 5,
                                                                    0.5)))


def test_synthetic_lm_dirichlet_unigrams():
    """FederatedLMData(dirichlet_alpha=...) swaps the permuted-Zipf unigrams
    for Dirichlet label-skew priors: deterministic, and small alpha
    concentrates each client's token distribution."""
    from repro.data.synthetic import FederatedLMData
    data = FederatedLMData(vocab=64, n_clients=4, dirichlet_alpha=0.05)
    a = np.asarray(data.sample(1, 0, 0, (256,)))
    np.testing.assert_array_equal(a, np.asarray(data.sample(1, 0, 0, (256,))))
    # strong skew: a few tokens dominate each client's stream
    top = np.sort(np.bincount(a, minlength=64))[::-1]
    assert top[:4].sum() > 0.5 * a.size
    # clients are heterogeneous: different dominant tokens
    b = np.asarray(data.sample(2, 0, 0, (256,)))
    assert np.argmax(np.bincount(a, minlength=64)) != \
        np.argmax(np.bincount(b, minlength=64))


def test_cohort_batch_rows_match_population_batch():
    """make_cohort_batch row j must equal full-population row ids[j] for
    every slot — including the non-token modality stubs — so population-mode
    batches reproduce full-population batches."""
    import jax.numpy as jnp2
    from repro.data.synthetic import (FederatedLMData, make_client_batch,
                                      make_cohort_batch)
    data = FederatedLMData(vocab=32, n_clients=6)
    specs_n = {"tokens": jax.ShapeDtypeStruct((6, 2, 8), jnp2.int32),
               "prefix_embeds": jax.ShapeDtypeStruct((6, 2, 4), jnp2.bfloat16)}
    specs_c = {k: jax.ShapeDtypeStruct((2,) + v.shape[1:], v.dtype)
               for k, v in specs_n.items()}
    full = make_client_batch(data, None, specs_n, step=3)
    ids = np.asarray([5, 1])
    cohort = make_cohort_batch(data, None, specs_c, 3, ids)
    for k in specs_n:
        np.testing.assert_array_equal(
            np.asarray(cohort[k], np.float32),
            np.asarray(full[k][ids], np.float32), err_msg=k)


def test_hyperclean_dirichlet_label_skew():
    """label_alpha wires Dirichlet skew into the hyper-cleaning dataset:
    per-client label histograms concentrate, and the default path
    (label_alpha=0) is untouched."""
    base = HyperCleanData(4, 128, 32, 8, 10, 0.0)
    skew = dataclasses.replace(base, label_alpha=0.1)

    def mean_max_share(data):
        shares = []
        for m in range(4):
            b = np.asarray(data.client_data(m)["b_tr"])
            h = np.bincount(b, minlength=10).astype(float)
            shares.append(h.max() / h.sum())
        return np.mean(shares)

    assert mean_max_share(skew) > mean_max_share(base) + 0.2
    # determinism of the skewed path
    a = np.asarray(skew.client_data(1)["b_tr"])
    np.testing.assert_array_equal(a, np.asarray(skew.client_data(1)["b_tr"]))
    # the uniform path's draws are unchanged (exact seed behaviour)
    u = np.asarray(base.client_data(0)["b_tr"])
    k = jax.random.fold_in(jax.random.PRNGKey(0), 0)
    _, k2, *_ = jax.random.split(k, 5)
    ka, _ = jax.random.split(k2)
    expect = np.asarray(jax.random.randint(ka, (128,), 0, 10))
    # corruption is off (corrupt_frac=0) so labels are the raw draws
    np.testing.assert_array_equal(u, expect)


# ------------------------------------------------------ trainer level

def test_trainer_population_round_smoke():
    """FederatedTrainer population path: bank init over N, one fused cohort
    round, scatter leaves non-cohort rows broadcast-synced, all finite."""
    from repro.configs import FedConfig, get_arch, reduced
    from repro.configs.base import ShapeConfig
    from repro.fed.runtime import FederatedTrainer, client_batch_specs

    cfg = reduced(get_arch("qwen1.5-4b"))
    fed = FedConfig(q=2, neumann_k=2, lr_x=1e-2, lr_y=1e-1)
    shape = ShapeConfig("t", 16, 2, "train")
    tr = FederatedTrainer(cfg, fed, shape, mesh=None)
    n, c = 6, 2
    specs_c, _ = client_batch_specs(cfg, shape, c, fed)
    specs_n = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape[1:], s.dtype), specs_c)
    key = jax.random.PRNGKey(0)

    def batch_at(specs, t):
        kk = jax.random.fold_in(key, t)
        return {k: (jax.random.randint(kk, v.shape, 0, cfg.vocab)
                    if v.dtype == jnp.int32 else jnp.zeros(v.shape, v.dtype))
                for k, v in specs.items()}

    bank, last_sync, server = tr.init_population_states(
        key, batch_at(specs_n, 0), n)
    assert jax.tree.leaves(bank)[0].shape[0] == n

    from repro.core.tree_util import tree_stack
    round_fn = jax.jit(tr.population_round_fn(n))
    ids = jnp.asarray([4, 1], jnp.int32)
    batches_q = tree_stack([batch_at(specs_c, t) for t in range(fed.q)])
    bank, last_sync, server = round_fn(bank, last_sync, server, ids,
                                       batches_q, key, jnp.int32(0))
    for leaf in jax.tree.leaves(bank):
        assert leaf.shape[0] == n
        assert np.isfinite(np.asarray(leaf, np.float32)).all()
    assert int(server["t"]) == fed.q + 1     # q locals + the sync's bump
    # broadcast sync: every bank row equals the post-sync client state
    np.testing.assert_array_equal(np.asarray(last_sync), 1)
    x0 = np.asarray(jax.tree.leaves(bank)[0][0], np.float32)
    xn = np.asarray(jax.tree.leaves(bank)[0][-1], np.float32)
    np.testing.assert_array_equal(x0, xn)


def test_trainer_population_init_derives_params_from_run_key():
    """Regression: init_population_states hard-coded PRNGKey(0) for the
    shared (x0, y0), so every run key produced an identical init. Different
    keys must now give different parameters; the same key must reproduce."""
    from repro.configs import FedConfig, get_arch, reduced
    from repro.configs.base import ShapeConfig
    from repro.fed.runtime import FederatedTrainer, client_batch_specs

    cfg = reduced(get_arch("qwen1.5-4b"))
    fed = FedConfig(q=2, neumann_k=2)
    shape = ShapeConfig("t", 16, 2, "train")
    tr = FederatedTrainer(cfg, fed, shape, mesh=None)
    n = 3
    specs_c, _ = client_batch_specs(cfg, shape, 1, fed)
    specs_n = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape[1:], s.dtype), specs_c)

    def batch(key):
        return {k: (jax.random.randint(key, v.shape, 0, cfg.vocab)
                    if v.dtype == jnp.int32 else jnp.zeros(v.shape, v.dtype))
                for k, v in specs_n.items()}

    k1, k2 = jax.random.PRNGKey(1), jax.random.PRNGKey(2)
    b = batch(jax.random.PRNGKey(7))
    bank1, _, _ = tr.init_population_states(k1, b, n)
    bank1b, _, _ = tr.init_population_states(k1, b, n)
    bank2, _, _ = tr.init_population_states(k2, b, n)
    x1 = np.asarray(jax.tree.leaves(bank1["x"])[0], np.float32)
    x1b = np.asarray(jax.tree.leaves(bank1b["x"])[0], np.float32)
    x2 = np.asarray(jax.tree.leaves(bank2["x"])[0], np.float32)
    np.testing.assert_array_equal(x1, x1b)       # same key reproduces
    assert (x1 != x2).any()                      # different keys differ
    # the shared init is still shared: every client starts from the same x
    np.testing.assert_array_equal(x1[0], x1[-1])
