"""Hypothesis property tests on system invariants."""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core.bilevel import softmax_xent
from repro.core.tree_util import (tree_axpy, tree_mean_axis0, tree_sub,
                                  tree_update, tree_vdot)
from repro.data.synthetic import FederatedLMData
from repro.kernels import ref
from repro.sharding import spec_for_axes

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=20,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("ci")

floats = st.floats(-3, 3, allow_nan=False, width=32)


@given(st.integers(2, 64), st.integers(2, 17), st.integers(0, 2 ** 30))
def test_xent_matches_naive(n, v, seed):
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (n, v))
    labels = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, v)
    got = softmax_xent(logits, labels)
    probs = jax.nn.log_softmax(logits, -1)
    want = -jnp.mean(jnp.take_along_axis(probs, labels[:, None], 1))
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5, atol=1e-6)


@given(st.integers(1, 64), st.floats(0, 1), st.integers(0, 2 ** 30))
def test_storm_telescoping(n, beta, seed):
    """If est == g_old (perfect tracking) then est' == g_new exactly."""
    key = jax.random.PRNGKey(seed)
    g_new = jax.random.normal(key, (n,))
    g_old = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    out = ref.storm_update_ref(g_new, g_old, g_old, beta)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g_new), atol=1e-6)


@given(st.integers(1, 32), st.floats(1e-4, 1.0), st.integers(0, 2 ** 30))
def test_tree_update_direction(n, step, seed):
    """tree_update moves opposite to the direction, proportionally to step."""
    key = jax.random.PRNGKey(seed)
    p = {"a": jax.random.normal(key, (n,))}
    d = {"a": jax.random.normal(jax.random.fold_in(key, 1), (n,))}
    out = tree_update(p, d, step)
    np.testing.assert_allclose(np.asarray(out["a"]),
                               np.asarray(p["a"] - step * d["a"]), rtol=1e-5,
                               atol=1e-6)
    # inner product with direction decreased
    assert float(tree_vdot(tree_sub(out, p), d)) <= 1e-6


@given(st.integers(2, 8), st.integers(2, 16), st.integers(0, 2 ** 20))
def test_client_mean_is_linear(m, n, seed):
    key = jax.random.PRNGKey(seed)
    tree = {"x": jax.random.normal(key, (m, n))}
    avg = tree_mean_axis0(tree)
    np.testing.assert_allclose(np.asarray(avg["x"]),
                               np.asarray(tree["x"].mean(0)), rtol=1e-5,
                               atol=1e-6)


@given(st.integers(0, 5), st.integers(0, 1000), st.integers(0, 3))
def test_data_deterministic_and_heterogeneous(client, step, slot):
    data = FederatedLMData(vocab=257, n_clients=8)
    a = data.sample(client, step, slot, (16,))
    b = data.sample(client, step, slot, (16,))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = data.sample(client + 1, step, slot, (16,))
    # different clients see different (non-iid) streams
    assert not np.array_equal(np.asarray(a), np.asarray(c))


@given(st.integers(1, 4), st.integers(1, 4))
def test_spec_never_reuses_mesh_axis(i, j):
    rules = {"_sizes": {"model": 4, "data": 2}, "a": "model", "b": "model",
             "c": "data"}
    spec = spec_for_axes(("a", "b", "c"), rules, None, (4 * i, 4 * j, 2))
    flat = [s for s in spec if s is not None]
    names = []
    for s in flat:
        names.extend([s] if isinstance(s, str) else list(s))
    assert len(names) == len(set(names))


@given(st.integers(1, 64))
def test_spec_respects_divisibility(n):
    rules = {"_sizes": {"model": 16}, "mlp": "model"}
    spec = spec_for_axes(("mlp",), rules, None, (n,))
    if n % 16 == 0 and n >= 16:
        assert spec and spec[0] == "model"
    else:
        assert len(spec) == 0 or spec[0] is None


@given(st.integers(2, 6), st.floats(0.01, 0.99), st.integers(0, 2 ** 20))
def test_ring_buffer_holds_last_window(w_pow, frac, seed):
    from repro.models.decode import _fill_ring
    w = 2 ** w_pow
    s = w + max(1, int(frac * w))
    key = jax.random.PRNGKey(seed)
    k_seq = jax.random.normal(key, (1, s, 2, 4))
    buf = _fill_ring(k_seq, w, window=True)
    # every of the last w positions is present at slot pos % w
    for pos in range(s - w, s):
        np.testing.assert_allclose(np.asarray(buf[0, pos % w]),
                                   np.asarray(k_seq[0, pos]), atol=0)


# ---------------------------------------------------------------- async layer

@given(st.integers(0, 2 ** 30), st.integers(2, 8), st.integers(1, 6))
def test_async_round_bitwise_stable_across_jit_retracing(seed, n, rounds):
    """Bounded-staleness gating at max_staleness=inf with no overlap
    (max_delay=1) must be bitwise-stable across jit re-tracing: two fresh
    jit instances of the same async round program, fed the same inputs,
    produce identical bits round after round."""
    from repro.fed.population import init_async_state, make_async_round

    def local(states, server, batch, key, ids):
        kk = jax.random.fold_in(key, server["t"])
        noise = jax.random.normal(kk, states["x"].shape)
        return ({"x": states["x"] * 0.9 + 0.1 * noise},
                {"t": server["t"] + 1})

    def sync(server, avg):
        return avg, server

    def build():
        # a FRESH trace each time: new closure, new jit cache entry
        return jax.jit(make_async_round(local, sync, q=2,
                                        max_staleness=float("inf"),
                                        max_delay=1))

    key = jax.random.PRNGKey(seed)
    c = max(n // 2, 1)
    init = init_async_state(
        {"x": jax.random.normal(key, (n, 3))}, {"t": jnp.int32(0)}, n)
    outs = []
    for attempt in range(2):
        jax.clear_caches()
        fn = build()
        state = jax.tree.map(lambda a: a, init)
        for r in range(rounds):
            ids = jax.random.permutation(
                jax.random.fold_in(key, r), n)[:c].astype(jnp.int32)
            state, stats = fn(state, ids, jnp.zeros((2, c)), key,
                              jnp.int32(r))
        outs.append((state, stats))
    for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(st.integers(2, 24), st.integers(1, 12), st.integers(1, 7),
       st.integers(0, 2 ** 30), st.sampled_from(["uniform", "roundrobin"]))
def test_in_scan_cohort_draw_matches_host_sampler(n, c_raw, R, seed, name):
    """Mega-scan cohort duality: the jit-traceable in-scan draw
    (``in_scan_cohort_fn``) run inside a scanned program reproduces the
    host-side sampler sequence EXACTLY for random (N, C, R, key) — and
    stays bitwise stable across a full jit re-trace. The chunked driver
    relies on this: the host draws the cohorts for batch building and wire
    accounting while the compiled program re-draws them on device."""
    from repro.fed.sampling import in_scan_cohort_fn, make_sampler
    c = min(c_raw, n)
    key = jax.random.fold_in(jax.random.PRNGKey(seed), 23)
    sampler = make_sampler(name, n, c, key)
    cohort_fn = in_scan_cohort_fn(sampler)
    assert cohort_fn is not None
    host = np.stack([np.asarray(sampler.cohort(r)) for r in range(R)])

    def scanned(round0):
        def body(carry, i):
            return carry, cohort_fn(round0 + i)
        return jax.lax.scan(body, jnp.int32(0),
                            jnp.arange(R, dtype=jnp.int32))[1]

    for attempt in range(2):
        jax.clear_caches()
        got = np.asarray(jax.jit(scanned)(jnp.int32(0)))
        np.testing.assert_array_equal(got, host,
                                      err_msg=f"{name} attempt {attempt}")
    # chunk offsets re-anchor on the absolute round id, not the scan index
    off = np.asarray(jax.jit(scanned)(jnp.int32(3)))
    want = np.stack([np.asarray(sampler.cohort(3 + r)) for r in range(R)])
    np.testing.assert_array_equal(off, want, err_msg=f"{name} offset")


@given(st.integers(2, 24), st.integers(2, 10), st.floats(0.1, 1.0),
       st.integers(0, 2 ** 30))
def test_trace_file_replay_matches_in_memory_trace_sampler(n, period, duty,
                                                          seed):
    """Replaying a trace generated from the periodic schedule reproduces
    the in-memory `trace` sampler's cohorts exactly — same up masks, same
    shared draw — including rounds past the horizon (the trace cycles)."""
    from repro.fed.sampling import AvailabilityTraceSampler, TraceFileSampler
    key = jax.random.PRNGKey(seed)
    c = max(n // 3, 1)
    s = AvailabilityTraceSampler(n, c, key, period=period, duty=duty)
    table = np.stack([np.asarray(s.up_mask(r)) for r in range(period)])
    tf = TraceFileSampler(n, c, key, table)
    for r in range(2 * period + 3):
        np.testing.assert_array_equal(np.asarray(s.up_mask(r)),
                                      np.asarray(tf.up_mask(r)))
        np.testing.assert_array_equal(np.asarray(s.cohort(r)),
                                      np.asarray(tf.cohort(r)),
                                      err_msg=f"round {r}")


@given(st.integers(1, 300), st.integers(2, 8), st.integers(0, 2 ** 30))
def test_int8_quantize_dequantize_error_bound(n, bits, seed):
    """quantize -> dequantize lands within one quantization step of x:
    |deq - x| <= scale = max|x| / (2^(b-1) - 1), for every size and width."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n,))
    u = jax.random.uniform(jax.random.fold_in(key, 1), (n,))
    qmax = (1 << (bits - 1)) - 1
    scale = max(float(jnp.max(jnp.abs(x))), 1e-30) / qmax
    q = ref.quantize_stoch_ref(x, u, scale, qmax)
    deq = ref.dequantize_ref(q, scale)
    assert np.abs(np.asarray(deq) - np.asarray(x)).max() <= scale + 1e-6


@given(st.integers(1, 64), st.integers(0, 2 ** 30))
def test_int8_stochastic_rounding_unbiased(n, seed):
    """E_u[q * scale] = x: the empirical mean over independent noise draws
    converges to x at the Monte-Carlo rate."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n,))
    scale = max(float(jnp.max(jnp.abs(x))), 1e-30) / 127
    reps = 256
    us = jax.random.uniform(jax.random.fold_in(key, 1), (reps, n))
    deq = jax.vmap(lambda u: ref.dequantize_ref(
        ref.quantize_stoch_ref(x, u, scale, 127), scale))(us)
    err = np.abs(np.asarray(deq.mean(0)) - np.asarray(x))
    assert err.max() < 6 * scale / np.sqrt(reps)


@given(st.integers(1, 100), st.floats(0.01, 1.0), st.integers(0, 2 ** 30),
       st.sampled_from(["int8", "topk"]))
def test_ef_residual_telescopes(n, frac, seed, name):
    """Error feedback invariant: transmitted + residual == the true
    (EF-augmented) update, for every codec, size, and level."""
    from repro.fed.compress import client_messages, make_codec
    key = jax.random.PRNGKey(seed)
    cod = make_codec(name, topk_frac=frac)
    ref_t = {"x": jax.random.normal(key, (2, n))}
    cur = {"x": jax.random.normal(jax.random.fold_in(key, 1), (2, n))}
    ef = {"x": 0.1 * jax.random.normal(jax.random.fold_in(key, 2), (2, n))}
    recon, ef_new = client_messages(cod, key, 0, jnp.arange(2), ref_t, cur,
                                    ef)
    sent = recon["x"] - ref_t["x"]
    true_upd = cur["x"] - ref_t["x"] + ef["x"]
    np.testing.assert_allclose(np.asarray(sent + ef_new["x"]),
                               np.asarray(true_upd), atol=1e-5, rtol=1e-5)
