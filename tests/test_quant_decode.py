"""int8 KV cache: kernel-vs-oracle + quantized decode path vs bf16 decode."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.kernels import ref
from repro.kernels.quant_decode import quant_decode_attention, quantize_kv
from repro.models import (ModelCtx, decode_step, init_cache, init_params,
                          model_specs, prefill)


@pytest.mark.parametrize("b,h,kv,s,d", [(1, 4, 4, 256, 64), (2, 8, 2, 512, 64)])
def test_kernel_matches_oracle(b, h, kv, s, d):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, h, d), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, kv, s, d), jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, kv, s, d), jnp.bfloat16)
    k8, ks = quantize_kv(k)
    v8, vs = quantize_kv(v)
    got = quant_decode_attention(q, k8, ks, v8, vs, s - 7, block_s=128,
                                 interpret=True)
    want = ref.quant_decode_ref(q, k8, ks, v8, vs, s - 7)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=2e-2,
                               rtol=2e-2)


def test_quantization_error_small():
    key = jax.random.PRNGKey(1)
    k = jax.random.normal(key, (2, 2, 128, 64), jnp.float32)
    k8, ks = quantize_kv(k)
    back = k8.astype(jnp.float32) * ks[..., None]
    err = np.abs(np.asarray(back - k)).max()
    assert err < np.abs(np.asarray(k)).max() / 100   # <1% of range


def test_quantized_decode_close_to_bf16():
    cfg = reduced(get_arch("granite-20b"), dtype="float32")
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0), "float32")
    B, S = 1, 16
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    cache0 = init_cache(cfg, B, S + 4, dtype=jnp.float32)
    _, cache0 = prefill(cfg, params, {"tokens": tokens[:, :S - 1]}, cache0,
                        ModelCtx(kind="prefill"))
    outs = {}
    for quant in (False, True):
        cache = cache0
        if quant:   # quantize the prefilled bf16 cache (prod: prefill writes q8)
            ck8, cks = quantize_kv(cache0["k"])
            cv8, cvs = quantize_kv(cache0["v"])
            cache = {"k": ck8, "v": cv8, "k_scale": cks, "v_scale": cvs}
        lg, _ = decode_step(cfg, params, cache, tokens[:, S - 1:],
                            jnp.int32(S - 1), ModelCtx(kind="decode"))
        outs[quant] = np.asarray(lg, np.float32)
    # int8 cache changes logits only at quantization-noise level
    scale = np.abs(outs[False]).max()
    assert np.abs(outs[True] - outs[False]).max() < 0.05 * scale
