"""benchmarks/roofline.py smoke: the analytic roofline must work straight
off the real ``repro.configs`` surface (no dry-run artifacts), keep the
row schema ``benchmarks/run.py``'s roofline_summary consumes, and the
fused-round term must amortize the dispatch latency over q*R steps."""
import math
import sys

import pytest

sys.path.insert(0, ".")

from benchmarks.roofline import (load_rows, roofline_row,  # noqa: E402
                                 synth_records)
from repro.configs import INPUT_SHAPES, list_arch_ids  # noqa: E402

ROW_KEYS = ("arch", "shape", "dominant", "t_compute_s", "t_memory_s",
            "t_collective_s", "fits_16g")


def test_load_rows_covers_configs_matrix_without_artifacts(tmp_path):
    """Pointing at an empty artifact dir (the repaired dormant path) yields
    one finite analytic row per (arch x shape) with the consumed schema."""
    rows = load_rows(dryrun_dir=str(tmp_path))
    assert len(rows) == len(list_arch_ids()) * len(INPUT_SHAPES)
    seen = {(r["arch"], r["shape"]) for r in rows}
    assert len(seen) == len(rows)
    for r in rows:
        for k in ROW_KEYS:
            assert k in r, k
        assert r["dominant"] in ("compute", "memory", "collective")
        for k in ("t_compute_s", "t_memory_s", "t_collective_s"):
            assert math.isfinite(r[k]) and r[k] >= 0.0, (r["arch"], k)
        assert (r["t_compute_s"] + r["t_memory_s"]) > 0.0
        assert isinstance(r["fits_16g"], bool)


def test_synth_records_step_structure():
    """Train shapes carry the local+sync pair (so the q / q*R amortization
    applies); prefill/decode carry exactly their own step."""
    recs = synth_records()
    by = {(r["arch"], r["shape"]): r for r in recs}
    assert set(by[("qwen1.5-4b", "train_4k")]["steps"]) == {"local", "sync"}
    assert set(by[("qwen1.5-4b", "prefill_32k")]["steps"]) == {"prefill"}
    assert set(by[("qwen1.5-4b", "decode_32k")]["steps"]) == {"decode"}


@pytest.mark.parametrize("arch", ["qwen1.5-4b", "zamba2-1.2b"])
def test_fused_round_term_amortizes_with_rounds_per_scan(arch):
    """The per-program dispatch latency term shrinks strictly and
    monotonically with R on train shapes, and R=1 reduces to the plain
    scan-engine row; non-train shapes have no sync step and are
    unaffected."""
    rec = next(r for r in synth_records()
               if r["arch"] == arch and r["shape"] == "train_4k")
    t1 = roofline_row(rec)["t_collective_s"]
    assert t1 == roofline_row(rec, rounds_per_scan=1)["t_collective_s"]
    prev = t1
    for R in (2, 4, 16):
        cur = roofline_row(rec, rounds_per_scan=R)["t_collective_s"]
        assert cur < prev, (R, cur, prev)
        prev = cur
    dec = next(r for r in synth_records()
               if r["arch"] == arch and r["shape"] == "decode_32k")
    assert (roofline_row(dec)["t_collective_s"]
            == roofline_row(dec, rounds_per_scan=16)["t_collective_s"])
