"""Round engine (repro.fed.round): the fused lax.scan round must be
numerics-identical to q eager local_step calls + one sync_step, and every
per-client step must batch under jax.vmap (regression for the jax 0.4.x
optimization_barrier batching-rule gap that broke the whole seed suite)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FedConfig
from repro.core import adafbio
from repro.core.baselines import make_algorithm
from repro.core.bilevel import quadratic_bilevel_problem, quadratic_true_grad
from repro.core.tree_util import (tree_bcast_axis0, tree_mean_axis0,
                                  tree_stack)
from repro.fed.round import make_round_step, stack_round_batches
from repro.tasks.driver import FedDriver


def _quad_setup(adaptive="adam", seed=0, d=8, p=6, fused="auto"):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    A = jax.random.normal(k1, (p, p))
    H = A @ A.T / p + 0.5 * jnp.eye(p)
    Bm = jax.random.normal(k2, (p, d)) * 0.3
    c = jax.random.normal(k3, (p,))
    Q = jnp.eye(d) * 0.2
    prob = quadratic_bilevel_problem(H, Bm, c, Q)
    fed = FedConfig(q=4, neumann_k=8, lr_x=0.3, lr_y=0.3,
                    theta=float(1.0 / jnp.linalg.eigvalsh(H)[-1]),
                    adaptive=adaptive, fused=fused)
    batches = {"f": 0.0, "g": 0.0, "g0": 0.0,
               "gi": jnp.zeros((fed.neumann_k,))}
    return prob, fed, batches, (H, Bm, c, Q)


def _init_clients(alg, fed, batches, m, d=8, p=6):
    xp, yp = jnp.ones((d,)) * 2.0, jnp.zeros((p,))
    b_m = jax.tree.map(lambda x: jnp.stack([jnp.asarray(x)] * m), batches)
    states = jax.vmap(lambda k, b: alg.init_client_state(xp, yp, b, k))(
        jax.random.split(jax.random.PRNGKey(7), m), b_m)
    server = alg.init_server_state(xp)
    if fed.adaptive != "none":
        server = adafbio.warm_adaptive(server, tree_mean_axis0(states), fed)
    return states, server, b_m


# ------------------------------------------------------------ vmap regression

def test_local_step_works_under_vmap():
    """Seed-breaking bug: lax.optimization_barrier has no batching rule on
    jax 0.4.x, so a vmapped local_step raised NotImplementedError. The
    tree_barrier wrapper must keep every client step vmap-able."""
    prob, fed, batches, _ = _quad_setup()
    m = 4
    alg = make_algorithm("adafbio", fed, prob)
    states, server, b_m = _init_clients(alg, fed, batches, m)

    def one(st, k):
        return alg.local_step(st, server["adaptive"], batches, k,
                              jnp.int32(0), m)

    out = jax.vmap(one)(states, jax.random.split(jax.random.PRNGKey(0), m))
    for leaf in jax.tree.leaves(out):
        assert leaf.shape[0] == m
        assert np.isfinite(np.asarray(leaf, np.float32)).all()
    # and under jit(vmap(...)), the production composition
    out2 = jax.jit(jax.vmap(one))(states,
                                  jax.random.split(jax.random.PRNGKey(0), m))
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(out2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# ------------------------------------------------------------ scan ≡ eager

@pytest.mark.parametrize("adaptive", ["adam", "none"])
def test_round_step_matches_eager_steps(adaptive):
    """make_round_step(local, sync, q) ≡ q× local_step + sync_step (1e-5)."""
    prob, fed, batches, _ = _quad_setup(adaptive=adaptive)
    m, q = 4, fed.q
    alg = make_algorithm("adafbio", fed, prob)
    states, server, b_m = _init_clients(alg, fed, batches, m)
    key = jax.random.PRNGKey(3)

    def local(states, server, batch, kk):
        t = server["t"]
        def one(st, b, i):
            k2 = jax.random.fold_in(jax.random.fold_in(kk, i), t)
            return alg.local_step(st, server["adaptive"], b, k2, t, m)
        new = jax.vmap(one)(states, batch, jnp.arange(m))
        srv = dict(server)
        srv["t"] = t + 1
        return new, srv

    def sync(states, server):
        new_client, new_server = alg.sync_update(server,
                                                 tree_mean_axis0(states), m)
        return tree_bcast_axis0(new_client, m), new_server

    # eager: q explicit jitted local calls + one sync
    st_e, srv_e = states, server
    local_j, sync_j = jax.jit(local), jax.jit(sync)
    for _ in range(q):
        st_e, srv_e = local_j(st_e, srv_e, b_m, key)
    st_e, srv_e = sync_j(st_e, srv_e)

    # fused: one jitted scan round
    round_fn = jax.jit(make_round_step(local, sync, q))
    batches_q = tree_stack([b_m] * q)
    st_s, srv_s = round_fn(states, server, batches_q, key)

    for pa, (a, b) in zip(
            jax.tree_util.tree_leaves_with_path(st_e),
            zip(jax.tree.leaves(st_e), jax.tree.leaves(st_s))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5,
                                   rtol=1e-5, err_msg=str(pa[0]))
    assert int(srv_e["t"]) == int(srv_s["t"])
    for a, b in zip(jax.tree.leaves(srv_e["adaptive"]),
                    jax.tree.leaves(srv_s["adaptive"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5,
                                   rtol=1e-5)


@pytest.mark.parametrize("steps", [32, 6, 3])
def test_driver_scan_engine_matches_eager(steps):
    """FedDriver(engine='scan') reproduces the eager run end-to-end on the
    quadratic problem: same final averaged state, gradient norm, step count,
    and cost accounting — including a trailing partial round (steps % q != 0)
    and a sub-q run (steps < q)."""
    runs = {}
    for engine in ("eager", "scan"):
        prob, fed, batches, (H, Bm, c, Q) = _quad_setup()
        d = FedDriver(
            prob, fed, 4,
            lambda client, step: dict(batches),
            lambda k: (jnp.ones((8,)) * 2.0, jnp.zeros((6,))),
            grad_norm_fn=lambda x, y: jnp.linalg.norm(
                quadratic_true_grad(H, Bm, c, Q, x)),
            algorithm="adafbio", engine=engine)
        runs[engine] = d.run(steps, eval_every=steps)
    for a, b in zip(jax.tree.leaves(runs["eager"].final_avg_state),
                    jax.tree.leaves(runs["scan"].final_avg_state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5,
                                   rtol=1e-5)
    np.testing.assert_allclose(runs["eager"].grad_norm[-1],
                               runs["scan"].grad_norm[-1], atol=1e-5,
                               rtol=1e-4)
    # identical step / communication / sample accounting at the final record
    assert runs["eager"].steps[-1] == runs["scan"].steps[-1] == steps - 1
    assert runs["eager"].comms[-1] == runs["scan"].comms[-1]
    assert runs["eager"].samples[-1] == runs["scan"].samples[-1]


def test_stack_round_batches_layout():
    got = stack_round_batches(lambda t: {"a": jnp.full((2,), t)}, t0=3, q=4)
    assert got["a"].shape == (4, 2)
    np.testing.assert_array_equal(np.asarray(got["a"][:, 0]),
                                  np.arange(3, 7))


# ------------------------------------------------------------ fused path

def test_fused_flat_buffer_matches_per_leaf():
    """fed.fused='on' (flat-buffer kernels, jnp fallback on CPU) must match
    fed.fused='off' (per-leaf jnp) through a whole local step."""
    outs = {}
    for mode in ("on", "off"):
        prob, fed, batches, _ = _quad_setup(fused=mode)
        alg = make_algorithm("adafbio", fed, prob)
        states, server, b_m = _init_clients(alg, fed, batches, 4)

        def one(st, k):
            return alg.local_step(st, server["adaptive"], batches, k,
                                  jnp.int32(1), 4)
        outs[mode] = jax.jit(jax.vmap(one))(
            states, jax.random.split(jax.random.PRNGKey(0), 4))
    for a, b in zip(jax.tree.leaves(outs["on"]),
                    jax.tree.leaves(outs["off"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5,
                                   rtol=1e-5)


# ------------------------------------------------------------ trainer level

def test_codec_round_none_bit_identical_to_plain():
    """``FederatedTrainer.round_step_codec_fn()`` with codec='none' must be
    BIT-identical to ``round_step_fn()``: the codec leg is the identity and
    the aggregator reduce is op-for-op the plain sync (the satellite
    guarantee docs/compression.md promises for the plain all-clients
    path). Two chained rounds, exact float equality on every leaf."""
    from repro.configs import get_arch, reduced
    from repro.configs.base import ShapeConfig
    from repro.fed.runtime import FederatedTrainer, client_batch_specs

    cfg = reduced(get_arch("qwen1.5-4b"), dtype="float32")
    fed = FedConfig(q=2, neumann_k=2, lr_x=1e-2, lr_y=1e-1)
    shape = ShapeConfig("t", 16, 2, "train")
    tr = FederatedTrainer(cfg, fed, shape, mesh=None)
    assert not tr.codec.lossy and tr.init_ef_bank(tr.m) is None
    specs, _ = client_batch_specs(cfg, shape, tr.m, fed)
    key = jax.random.PRNGKey(0)

    def batch_at(t):
        kk = jax.random.fold_in(key, t)
        return {k: (jax.random.randint(kk, v.shape, 0, cfg.vocab)
                    if v.dtype == jnp.int32 else jnp.zeros(v.shape, v.dtype))
                for k, v in specs.items()}

    states, server = tr.init_states(key, batch_at(0))
    plain = jax.jit(tr.round_step_fn())
    codecf = jax.jit(tr.round_step_codec_fn())
    st_p, srv_p = states, server
    st_c, srv_c, ref, ef = states, server, states, None
    for r in range(2):
        bq = tree_stack([batch_at(r * fed.q + t) for t in range(fed.q)])
        st_p, srv_p = plain(st_p, srv_p, bq, key)
        st_c, srv_c, ref, ef = codecf(st_c, srv_c, ref, ef, bq, key,
                                      jnp.int32(r))
        assert ef is None
    for pa, b in zip(jax.tree_util.tree_leaves_with_path(st_p),
                     jax.tree.leaves(st_c)):
        np.testing.assert_array_equal(np.asarray(pa[1]), np.asarray(b),
                                      err_msg=str(pa[0]))
    for a, b in zip(jax.tree.leaves(srv_p), jax.tree.leaves(srv_c)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the returned ref IS the fresh broadcast: identical to the new states
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(st_c)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_trainer_round_step_matches_eager_lm():
    """FederatedTrainer.round_step_fn() ≡ q× local_step_fn() + sync_step_fn()
    on a reduced LM arch (bf16 params -> bf16-scale tolerance)."""
    from repro.configs import FedConfig, get_arch, reduced
    from repro.configs.base import ShapeConfig
    from repro.fed.runtime import FederatedTrainer, client_batch_specs

    cfg = reduced(get_arch("qwen1.5-4b"))
    fed = FedConfig(q=2, neumann_k=2, lr_x=1e-2, lr_y=1e-1)
    shape = ShapeConfig("t", 32, 2, "train")
    tr = FederatedTrainer(cfg, fed, shape, mesh=None)
    specs, _ = client_batch_specs(cfg, shape, tr.m, fed)
    key = jax.random.PRNGKey(0)

    def batch_at(t):
        kk = jax.random.fold_in(key, t)
        return {k: (jax.random.randint(kk, v.shape, 0, cfg.vocab)
                    if v.dtype == jnp.int32 else jnp.zeros(v.shape, v.dtype))
                for k, v in specs.items()}

    states, server = tr.init_states(key, batch_at(0))

    st_e, srv_e = states, server
    local = jax.jit(tr.local_step_fn())
    sync = jax.jit(tr.sync_step_fn())
    for t in range(fed.q):
        st_e, srv_e = local(st_e, srv_e, batch_at(t), key)
    st_e, srv_e = sync(st_e, srv_e)

    round_fn = jax.jit(tr.round_step_fn())
    batches_q = tree_stack([batch_at(t) for t in range(fed.q)])
    st_s, srv_s = round_fn(states, server, batches_q, key)

    for a, b in zip(jax.tree.leaves(st_e), jax.tree.leaves(st_s)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-2, rtol=2e-2)
    assert int(srv_e["t"]) == int(srv_s["t"])
