"""Serve-engine correctness: the continuous-batching scheduler changes
throughput, never results.

The load-bearing pins:
  * engine output == an independent B=1 sequential greedy loop over the
    same ``build_serve_fns`` programs (token-level: XLA's batched einsum
    reduction order differs from B=1 by ~1 ulp, argmax agrees at the
    fixed seeds);
  * engine output == the SAME engine serving one request at a time —
    the same compiled program plus bitwise row-independence of the
    batched decode makes this exact by construction;
  * the int8 KV-cache pool (Pallas kernel in interpret mode vs the XLA
    reference dequant) serves identical tokens;
  * hypothesis slot-lifecycle invariants: slots never double-book, every
    admitted request completes exactly once with a consistent finish
    reason.

MoE runs with drop-free capacity (finite capacity legitimately makes
token dropping depend on how many tokens share a dispatch).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.configs.base import FedConfig, ShapeConfig
from repro.fed.serve import build_serve_fns
from repro.models import init_params, model_specs
from repro.serve import (Engine, LoadSpec, Request, generate_requests,
                         load_serve_params)
from repro.serve.engine import QUANT_FAMILIES

FAMS = ["qwen1.5-4b",        # dense (MHA, qkv bias)
        "granite-20b",       # dense (MQA)
        "falcon-mamba-7b",   # ssm
        "zamba2-1.2b",       # hybrid
        "qwen3-moe-30b-a3b", # moe
        "whisper-tiny"]      # encdec
FAST = ["qwen1.5-4b", "falcon-mamba-7b"]
MAX_LEN = 24


def _cfg(arch_id):
    cfg = reduced(get_arch(arch_id), dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    return cfg


def _params(cfg):
    return init_params(model_specs(cfg), jax.random.PRNGKey(0), "float32")


def _workload(cfg, n=5, seed=3, max_new=6, max_len=MAX_LEN):
    enc = (max_len, cfg.d_model) if cfg.family == "encdec" else None
    pre = ((cfg.n_prefix_embeds, cfg.d_model) if cfg.n_prefix_embeds
           else None)
    spec = LoadSpec(n_requests=n, prompt_lens=(4, 7), mean_new_tokens=4.0,
                    max_new_cap=max_new, seed=seed)
    return generate_requests(spec, cfg.vocab, enc_shape=enc,
                             prefix_shape=pre)


def _ref_sequential(cfg, params, reqs, max_len, eos_id=None):
    """Independent B=1 greedy loop straight over build_serve_fns — no
    engine, no slot pool, scalar pos. rid -> generated tokens."""
    pre = build_serve_fns(
        cfg, ShapeConfig("ref_pre", max_len, 1, "prefill"), None)
    dec = build_serve_fns(
        cfg, ShapeConfig("ref_dec", max_len, 1, "decode"), None)
    out = {}
    for req in reqs:
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                             pre["cache_abs"])
        batch = {"tokens": jnp.asarray(np.asarray(req.tokens)[None])}
        if "prefix_embeds" in pre["batch_specs"]:
            spec = pre["batch_specs"]["prefix_embeds"]
            pe = req.prefix_embeds
            pe = np.zeros(spec.shape[1:], np.float32) if pe is None else pe
            batch["prefix_embeds"] = jnp.asarray(pe[None]).astype(spec.dtype)
        if "enc_embeds" in pre["batch_specs"]:
            batch["enc_embeds"] = jnp.asarray(req.enc_embeds[None]).astype(
                pre["batch_specs"]["enc_embeds"].dtype)
        logits, cache = pre["prefill"](params, batch, cache)
        toks = [int(jnp.argmax(logits[0, 0]))]
        pos, budget = int(np.shape(req.tokens)[-1]), req.max_new_tokens - 1
        while (budget > 0 and pos < max_len
               and not (eos_id is not None and toks[-1] == eos_id)):
            logits, cache = dec["decode"](
                params, cache, jnp.full((1, 1), toks[-1], jnp.int32),
                jnp.int32(pos))
            toks.append(int(jnp.argmax(logits[0, 0])))
            pos += 1
            budget -= 1
        out[req.rid] = toks
    return out


def _tokens(completions):
    return {c.rid: c.tokens for c in completions}


@pytest.mark.parametrize("arch_id", FAST)
def test_engine_matches_sequential(arch_id):
    """Continuous batching at slots=3 serves exactly what an independent
    one-request-at-a-time B=1 greedy loop produces."""
    cfg = _cfg(arch_id)
    params = _params(cfg)
    reqs = _workload(cfg)
    eng = Engine(cfg, params, slots=3, max_len=MAX_LEN)
    got = _tokens(eng.run(reqs))
    want = _ref_sequential(cfg, params, reqs, MAX_LEN)
    assert got == want


@pytest.mark.slow
@pytest.mark.parametrize("kv_quant", [False, True])
@pytest.mark.parametrize("arch_id", FAMS)
def test_engine_one_at_a_time_matrix(arch_id, kv_quant):
    """Full family x quant matrix: the shared-pool engine vs the SAME
    engine class draining one request at a time. Identical programs plus
    bitwise decode row-independence make this exact."""
    cfg = _cfg(arch_id)
    if kv_quant and cfg.family not in QUANT_FAMILIES:
        pytest.skip(f"{cfg.family} keeps no attention KV cache")
    params = _params(cfg)
    reqs = _workload(cfg)
    shared = Engine(cfg, params, slots=4, max_len=MAX_LEN,
                    kv_quant=kv_quant)
    got = _tokens(shared.run(reqs))
    solo = Engine(cfg, params, slots=4, max_len=MAX_LEN, kv_quant=kv_quant)
    want = {}
    for r in reqs:
        want.update(_tokens(solo.run([r])))
    assert got == want


def test_engine_one_at_a_time_identity():
    """Fast-tier pin of the same-engine identity (dense arch)."""
    cfg = _cfg("qwen1.5-4b")
    params = _params(cfg)
    reqs = _workload(cfg, n=6)
    shared = Engine(cfg, params, slots=4, max_len=MAX_LEN)
    got = _tokens(shared.run(reqs))
    solo = Engine(cfg, params, slots=4, max_len=MAX_LEN)
    want = {}
    for r in reqs:
        want.update(_tokens(solo.run([r])))
    assert got == want


def test_kv_quant_kernel_matches_dequant():
    """int8 pool: the Pallas kernel (interpret mode — the TPU program on
    CPU) and the XLA reference dequant serve identical tokens."""
    cfg = _cfg("qwen1.5-4b")
    params = _params(cfg)
    reqs = _workload(cfg, n=4)
    ref = Engine(cfg, params, slots=3, max_len=MAX_LEN, kv_quant=True,
                 kv_kernel="xla")
    ker = Engine(cfg, params, slots=3, max_len=MAX_LEN, kv_quant=True,
                 kv_kernel="interpret")
    assert _tokens(ref.run(reqs)) == _tokens(ker.run(reqs))


def test_kv_quant_tracks_full_precision():
    """Greedy tokens through the int8 pool match the full-precision pool
    at the fixed seed — an empirical pin that the per-(token, head)
    scales hold quantization error below the argmax margin on this
    workload (the logit-level bound lives in tests/test_quant_decode.py)."""
    cfg = _cfg("qwen1.5-4b")
    params = _params(cfg)
    reqs = _workload(cfg, n=4)
    fp = Engine(cfg, params, slots=3, max_len=MAX_LEN)
    q8 = Engine(cfg, params, slots=3, max_len=MAX_LEN, kv_quant=True)
    assert _tokens(fp.run(reqs)) == _tokens(q8.run(reqs))


def test_eos_truncates_and_frees_slot():
    """With eos_id set to a token the no-eos run generated mid-sequence,
    that request retires at the first occurrence (eos included) and every
    other request's tokens are untouched."""
    cfg = _cfg("qwen1.5-4b")
    params = _params(cfg)
    reqs = _workload(cfg, n=5)
    base = _tokens(Engine(cfg, params, slots=2, max_len=MAX_LEN).run(reqs))
    rid, toks = next((r, t) for r, t in sorted(base.items())
                     if len(t) >= 3)
    eos = toks[1]
    done = Engine(cfg, params, slots=2, max_len=MAX_LEN,
                  eos_id=eos).run(reqs)
    got = _tokens(done)
    cut = base[rid].index(eos) + 1
    assert got[rid] == base[rid][:cut]
    assert next(c for c in done if c.rid == rid).finish_reason == "eos"
    for r, t in base.items():
        if r != rid and eos not in t:
            assert got[r] == t


def test_capacity_retirement():
    """A prompt near max_len truncates generation at the cache edge with
    finish_reason='capacity'."""
    cfg = _cfg("qwen1.5-4b")
    params = _params(cfg)
    max_len = 12
    req = Request(rid=0, tokens=np.arange(10, dtype=np.int32) % cfg.vocab,
                  max_new_tokens=30)
    done = Engine(cfg, params, slots=1, max_len=max_len).run([req])
    assert done[0].finish_reason == "capacity"
    # pos walks plen .. max_len; tokens = first (from prefill) + one per tick
    assert len(done[0].tokens) == max_len - 10 + 1


def test_submit_rejects_bad_requests():
    cfg = _cfg("qwen1.5-4b")
    params = _params(cfg)
    eng = Engine(cfg, params, slots=1, max_len=12)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(rid=0, tokens=np.zeros(0, np.int32)))
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(Request(rid=1, tokens=np.zeros(4, np.int32),
                           max_new_tokens=0))
    with pytest.raises(ValueError, match="prompt_len"):
        eng.submit(Request(rid=2, tokens=np.zeros(12, np.int32)))
    with pytest.raises(ValueError, match="slots"):
        Engine(cfg, params, slots=0, max_len=12)
    with pytest.raises(ValueError, match="kv_kernel"):
        Engine(cfg, params, slots=1, max_len=12, kv_kernel="cuda")


def test_kv_quant_rejects_stateful_families():
    cfg = _cfg("falcon-mamba-7b")
    with pytest.raises(ValueError, match="SSM state"):
        Engine(cfg, _params(cfg), slots=1, max_len=12, kv_quant=True)


# -------------------------------------------------- lifecycle invariants

_MEMO = {}


def _hyp_model():
    if "m" not in _MEMO:
        cfg = _cfg("qwen1.5-4b")
        _MEMO["m"] = (cfg, _params(cfg))
    return _MEMO["m"]


def _check_lifecycle(slots, n, max_new, seed):
    """Scheduler invariants under a random workload: the slot ledger stays
    consistent every tick (free + occupied == slots, no rid in two slots),
    every submitted request completes exactly once, and each completion's
    token count and finish reason are mutually consistent."""
    cfg, params = _hyp_model()
    reqs = _workload(cfg, n=n, seed=seed, max_new=max_new, max_len=16)
    eng = Engine(cfg, params, slots=slots, max_len=16)
    for r in reqs:
        eng.submit(r)
    done = []
    while eng.has_work:
        done.extend(eng.step())
        occupied = [o.rid for o in eng._occupant if o is not None]
        assert len(eng._free) + len(occupied) == slots
        assert len(occupied) == len(set(occupied))
        assert eng.active <= slots
    got = {c.rid: c for c in done}
    assert sorted(got) == [r.rid for r in reqs]
    for r in reqs:
        c = got[r.rid]
        assert 1 <= len(c.tokens) <= r.max_new_tokens
        plen = int(np.shape(r.tokens)[-1])
        assert plen + len(c.tokens) - 1 <= 16
        if c.finish_reason == "length":
            assert len(c.tokens) == r.max_new_tokens
        elif c.finish_reason == "capacity":
            assert plen + len(c.tokens) - 1 == 16
        assert c.finished_s >= c.admitted_s >= 0.0


@pytest.mark.parametrize("slots,n,max_new,seed", [
    (1, 4, 3, 0),       # one-at-a-time: pure queueing
    (3, 7, 4, 1),       # more requests than slots: retire-and-refill
    (4, 2, 1, 2),       # budget 1: retirement at admission
])
def test_slot_lifecycle_invariants(slots, n, max_new, seed):
    _check_lifecycle(slots, n, max_new, seed)


try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given, settings

    @settings(max_examples=6, deadline=None,
              suppress_health_check=list(hypothesis.HealthCheck))
    @given(slots=st.integers(1, 4), n=st.integers(1, 9),
           max_new=st.integers(1, 5), seed=st.integers(0, 2 ** 20))
    def test_slot_lifecycle_hypothesis(slots, n, max_new, seed):
        _check_lifecycle(slots, n, max_new, seed)
except ImportError:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_slot_lifecycle_hypothesis():
        pass


# ---------------------------------------------------------------- bridge

def _materialize(tree, key):
    leaves, td = jax.tree.flatten(tree)
    out = []
    for i, s in enumerate(leaves):
        k = jax.random.fold_in(key, i)
        if jnp.issubdtype(s.dtype, jnp.floating):
            out.append(jax.random.normal(k, s.shape).astype(s.dtype))
        else:
            out.append(jnp.zeros(s.shape, s.dtype))
    return jax.tree.unflatten(td, out)


def _fake_population_ckpt(path, cfg, n=3, step=7):
    """A launch/train.py population-layout checkpoint without training:
    (bank, last_sync, server) materialized from the trainer's own abstract
    templates."""
    from repro.checkpoint import save_checkpoint
    from repro.fed.runtime import FederatedTrainer
    tr = FederatedTrainer(cfg, FedConfig(), ShapeConfig("t", 8, 1, "train"),
                          mesh=None)
    key = jax.random.PRNGKey(5)
    bank = _materialize(tr.abstract_population_states(n), key)
    server = _materialize(tr.abstract_server_state(),
                          jax.random.fold_in(key, 99))
    state = (bank, jnp.zeros((n,), jnp.int32), server)
    save_checkpoint(str(path), state, step)
    return bank


def test_bridge_roundtrip(tmp_path):
    """load_serve_params recovers the client-mean global model from a
    population-layout checkpoint, bit-exact, with layout/step metadata."""
    cfg = _cfg("qwen1.5-4b")
    path = tmp_path / "ck"
    bank = _fake_population_ckpt(path, cfg, n=3, step=7)
    params, info = load_serve_params(str(path), cfg)
    assert info["clients"] == 3 and info["step"] == 7
    assert info["layout"].startswith("population")
    want_x = jax.tree.map(lambda a: jnp.mean(a, axis=0), bank["x"])
    for got, want in zip(jax.tree.leaves(params["x"]),
                         jax.tree.leaves(want_x)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # the result is a servable params pytree
    eng = Engine(cfg, params, slots=1, max_len=12)
    assert eng.run(_workload(cfg, n=1, max_len=12))


def test_bridge_arch_mismatch_names_leaf(tmp_path):
    """A checkpoint trained at one size, served at another: the error
    names the offending leaf path (PR 4 convention), not a generic miss."""
    small = _cfg("qwen1.5-4b")
    path = tmp_path / "ck"
    _fake_population_ckpt(path, small)
    big = get_arch("qwen1.5-4b")     # full-size: same structure, new shapes
    with pytest.raises(ValueError, match=r"leaf \d+ at "):
        load_serve_params(str(path), big)


def test_bridge_missing_sidecar(tmp_path):
    cfg = _cfg("qwen1.5-4b")
    with pytest.raises(ValueError, match="sidecar"):
        load_serve_params(str(tmp_path / "nope"), cfg)
