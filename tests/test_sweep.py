"""benchmarks/sweep.py: the convergence-vs-staleness grid harness emits a
machine-readable BENCH_async_sweep.json with a sync baseline plus one cell
per (max_staleness x delay model x delay_eta) combination."""
import json
import sys

import numpy as np
import pytest


@pytest.fixture(scope="module")
def sweep_main():
    sys.path.insert(0, ".")
    from benchmarks.sweep import main
    return main


def test_tiny_sweep_structure(sweep_main, tmp_path):
    out = tmp_path / "BENCH_async_sweep.json"
    sweep_main(["--task", "hyperclean,hyperrep", "--steps", "32",
                "--population", "8", "--cohort", "2",
                "--staleness-grid", "inf",
                "--delay-models", "tiers", "--delay-eta-grid", "0",
                "--max-delay", "4", "--out", str(out)])
    # spec-valid JSON: bare NaN/Infinity tokens must never appear
    # (hyperrep has no exact-gradient oracle — its grad_normT is null)
    doc = json.loads(out.read_text(),
                     parse_constant=lambda c: pytest.fail(
                         f"non-RFC8259 token {c} in sweep JSON"))
    assert doc["bench"] == "async_sweep"
    assert doc["meta"]["staleness_grid"] == ["inf"]
    cells = doc["cells"]
    # per task: 1 sync baseline + 1 staleness x 1 model x 1 eta
    assert len(cells) == 4
    sync = cells[0]
    assert sync["max_staleness"] == 0.0 and "staleness_hist" not in sync
    for cell in cells:
        for k in ("task", "delay_model", "metricT", "grad_normT",
                  "samples", "comms", "seconds"):
            assert k in cell, k
        if cell["task"] == "hyperclean":
            assert np.isfinite(cell["grad_normT"])
        else:
            assert cell["grad_normT"] is None
    tiers = [c for c in cells if c["delay_model"] == "tiers"
             and c["max_staleness"] == "inf"]
    assert tiers and "staleness_hist_by_tier" in tiers[0]
    by_tier = {int(k): np.asarray(v) for k, v in
               tiers[0]["staleness_hist_by_tier"].items()}
    # the monotone staleness shift: the straggler tier's accepted arrivals
    # are staler on average than the fast tier's
    mean_tau = {k: (np.arange(v.size) * v).sum() / v.sum()
                for k, v in by_tier.items() if v.sum()}
    if 0 in mean_tau and 2 in mean_tau:
        assert mean_tau[0] < mean_tau[2]
