"""benchmarks/sweep.py: the convergence-vs-staleness grid emits a
machine-readable BENCH_async_sweep.json (sync baseline + one cell per
(max_staleness x delay model x delay_eta) combination) and the
bytes-vs-convergence grid emits BENCH_compression.json — both through the
shared run_cell helper, with one schema version field."""
import json
import sys

import numpy as np
import pytest


@pytest.fixture(scope="module")
def sweep_main():
    sys.path.insert(0, ".")
    from benchmarks.sweep import main
    return main


def test_tiny_sweep_structure(sweep_main, tmp_path):
    out = tmp_path / "BENCH_async_sweep.json"
    sweep_main(["--task", "hyperclean,hyperrep", "--steps", "32",
                "--population", "8", "--cohort", "2",
                "--staleness-grid", "inf",
                "--delay-models", "tiers", "--delay-eta-grid", "0",
                "--max-delay", "4", "--out", str(out)])
    # spec-valid JSON: bare NaN/Infinity tokens must never appear
    # (hyperrep has no exact-gradient oracle — its grad_normT is null)
    doc = json.loads(out.read_text(),
                     parse_constant=lambda c: pytest.fail(
                         f"non-RFC8259 token {c} in sweep JSON"))
    assert doc["bench"] == "async_sweep"
    assert doc["schema"] == 3
    # schema 3: every artifact carries the telemetry run manifest header
    assert doc["manifest"]["kind"] == "manifest"
    assert doc["manifest"]["seed"] == 0
    assert doc["meta"]["staleness_grid"] == ["inf"]
    cells = doc["cells"]
    # per task: 1 sync baseline + 1 staleness x 1 model x 1 eta
    assert len(cells) == 4
    sync = cells[0]
    assert sync["max_staleness"] == 0.0 and "staleness_hist" not in sync
    for cell in cells:
        for k in ("task", "delay_model", "metricT", "grad_normT",
                  "samples", "comms", "bytes_up", "bytes_down", "seconds"):
            assert k in cell, k
        if cell["task"] == "hyperclean":
            assert np.isfinite(cell["grad_normT"])
        else:
            assert cell["grad_normT"] is None
    tiers = [c for c in cells if c["delay_model"] == "tiers"
             and c["max_staleness"] == "inf"]
    assert tiers and "staleness_hist_by_tier" in tiers[0]
    by_tier = {int(k): np.asarray(v) for k, v in
               tiers[0]["staleness_hist_by_tier"].items()}
    # the monotone staleness shift: the straggler tier's accepted arrivals
    # are staler on average than the fast tier's
    mean_tau = {k: (np.arange(v.size) * v).sum() / v.sum()
                for k, v in by_tier.items() if v.sum()}
    if 0 in mean_tau and 2 in mean_tau:
        assert mean_tau[0] < mean_tau[2]


def test_tiny_compression_sweep_structure(sweep_main, tmp_path):
    out = tmp_path / "BENCH_compression.json"
    sweep_main(["--bench", "compression", "--task", "hyperclean",
                "--steps", "32", "--population", "8", "--cohort", "2",
                "--codec-grid", "none,int8:4,topk:0.25", "--out", str(out)])
    doc = json.loads(out.read_text(),
                     parse_constant=lambda c: pytest.fail(
                         f"non-RFC8259 token {c} in sweep JSON"))
    assert doc["bench"] == "compression"
    assert doc["schema"] == 3                  # shared with the async bench
    assert doc["manifest"]["kind"] == "manifest"
    cells = doc["cells"]
    assert [c["codec"] for c in cells] == ["none", "int8", "topk"]
    for cell in cells:
        for k in ("task", "metricT", "grad_normT", "samples", "comms",
                  "bytes_up", "bytes_down", "seconds", "level", "ef"):
            assert k in cell, k
        assert np.isfinite(cell["grad_normT"])
        assert cell["comms"] > 0 and cell["bytes_down"] > 0
    none, int4, topk = cells
    assert none["level"] is None and none["ef"] is None
    assert int4["level"] == 4 and topk["level"] == 0.25
    # the wire saving the codecs exist for: both compress the uplink, and
    # all three cells paid the same uncompressed downlink
    assert int4["bytes_up"] < none["bytes_up"]
    assert topk["bytes_up"] < none["bytes_up"]
    assert len({c["bytes_down"] for c in cells}) == 1
    # identical runs up to the codec: same sample/round counters
    assert len({(c["samples"], c["comms"]) for c in cells}) == 1


def test_codec_grid_parsing_errors(sweep_main):
    sys.path.insert(0, ".")
    from benchmarks.sweep import parse_codec_grid
    assert parse_codec_grid("none,int8:4,topk:0.5") == [
        {"codec": "none"}, {"codec": "int8", "codec_bits": 4},
        {"codec": "topk", "topk_frac": 0.5}]
    with pytest.raises(SystemExit):
        parse_codec_grid("gzip")
    with pytest.raises(SystemExit):
        parse_codec_grid("none:8")
    with pytest.raises(SystemExit):
        parse_codec_grid("int8:77")
    with pytest.raises(SystemExit):
        parse_codec_grid("")
