"""End-to-end behaviour: AdaFBiO and every Table-1 baseline drive the paper's
tasks; AdaFBiO converges on the analytic quadratic bilevel problem."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FedConfig
from repro.configs.base import PopulationConfig
from repro.configs.paper_tasks import HyperCleanConfig, HyperRepConfig
from repro.core.baselines import ALGORITHMS
from repro.core.bilevel import quadratic_bilevel_problem, quadratic_true_grad
from repro.tasks.driver import FedDriver
from repro.tasks.hyperclean import build_hyperclean
from repro.tasks.hyperrep import build_hyperrep


def _quad_driver(algorithm, seed=0, d=8, p=6, m=4, **drv_kw):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    A = jax.random.normal(k1, (p, p))
    H = A @ A.T / p + 0.5 * jnp.eye(p)
    Bm = jax.random.normal(k2, (p, d)) * 0.3
    c = jax.random.normal(k3, (p,))
    Q = jnp.eye(d) * 0.2
    prob = quadratic_bilevel_problem(H, Bm, c, Q)
    fed = FedConfig(q=4, neumann_k=8, lr_x=0.3, lr_y=0.3,
                    theta=float(1.0 / jnp.linalg.eigvalsh(H)[-1]),
                    adaptive="adam" if algorithm == "adafbio" else "none")

    def batch_fn(client, step):
        K = fed.neumann_k
        return {"f": 0.0, "g": 0.0, "g0": 0.0, "gi": jnp.zeros((K,))}

    def init_xy(key):
        return jnp.ones((d,)) * 2.0, jnp.zeros((p,))

    def grad_norm(x, y):
        return jnp.linalg.norm(quadratic_true_grad(H, Bm, c, Q, x))

    return FedDriver(prob, fed, m, batch_fn, init_xy,
                     grad_norm_fn=grad_norm, algorithm=algorithm, **drv_kw)


def test_adafbio_converges_on_quadratic():
    d = _quad_driver("adafbio")
    r = d.run(120, eval_every=20)
    assert r.grad_norm[-1] < 0.25 * r.grad_norm[0]


@pytest.mark.slow
@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_all_algorithms_run_and_reduce_grad(algorithm):
    d = _quad_driver(algorithm)
    r = d.run(60, eval_every=20)
    assert np.isfinite(r.grad_norm).all()
    assert r.grad_norm[-1] < 1.2 * r.grad_norm[0]   # no blow-up
    # communication happens exactly every q steps
    assert r.comms[-1] == (r.steps[-1]) // d.fed.q


@pytest.mark.slow
@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_all_algorithms_population_engine(algorithm):
    """Every Table-1 algorithm's server structure rides the population bank
    engine (N-row bank, sampled cohorts, gather→scan→aggregate→scatter):
    finite trajectory, no blow-up, one sync per round."""
    d = _quad_driver(algorithm, m=6,
                     population=PopulationConfig(n=6, cohort=3))
    r = d.run(24, eval_every=8)
    assert np.isfinite(r.grad_norm).all()
    assert r.grad_norm[-1] < 1.2 * r.grad_norm[0]
    assert r.comms[-1] == r.steps[-1] // d.fed.q


@pytest.mark.slow
@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_all_algorithms_async_engine(algorithm):
    """Every algorithm also survives the asynchronous engine (overlapping
    cohorts, bounded-staleness gating, delay-adaptive server steps)."""
    d = _quad_driver(algorithm, m=6,
                     population=PopulationConfig(
                         n=6, cohort=3, max_staleness=4.0, max_delay=2,
                         delay_eta=0.3))
    # 48 steps ride out the adaptive warmup transient the delayed arrivals
    # stretch (adam's early norms overshoot before contracting)
    r = d.run(48, eval_every=8)
    assert np.isfinite(r.grad_norm).all()
    assert r.grad_norm[-1] < 1.5 * r.grad_norm[0]


@pytest.mark.slow
def test_hyperclean_learns_to_downweight_corrupted():
    cfg = HyperCleanConfig(n_clients=4, n_train_per_client=64,
                           n_val_per_client=32)
    hc = build_hyperclean(cfg)
    d = FedDriver(hc["problem"], cfg.fed, 4, hc["batch_fn"], hc["init_xy"],
                  metric_fn=hc["val_loss"], grad_norm_fn=hc["true_grad_norm"])
    r = d.run(60, eval_every=59)
    assert r.grad_norm[-1] < r.grad_norm[0] or r.grad_norm[-1] < 0.05
    # the learned weights should rank clean samples above corrupted ones
    states_x = d  # weights live inside the driver run; re-derive via a probe
    # (statistical check): rerun few more steps and inspect final avg state
    # -> handled in examples; here assert the metric improved.
    assert r.metric[-1] < r.metric[0] * 1.05


@pytest.mark.slow
def test_hyperrep_loss_decreases():
    cfg = HyperRepConfig(n_clients=4)
    hr = build_hyperrep(cfg)
    d = FedDriver(hr["problem"], cfg.fed, 4, hr["batch_fn"], hr["init_xy"],
                  metric_fn=hr["val_loss"])
    r = d.run(60, eval_every=59)
    assert r.metric[-1] < r.metric[0]


@pytest.mark.slow
def test_communication_complexity_scales_with_q():
    """T/q sync rounds (Remark 2): doubling q halves communication."""
    import dataclasses
    base = _quad_driver("adafbio")
    rs = {}
    for q in (2, 8):
        d = _quad_driver("adafbio")
        d.alg = dataclasses.replace(d.alg, fed=dataclasses.replace(
            d.alg.fed, q=q))
        r = d.run(33, eval_every=32)
        rs[q] = r.comms[-1]
    assert rs[2] == 16 and rs[8] == 4
