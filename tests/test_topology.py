"""Aggregation-layer identity matrix (the tentpole refactor's safety net).

The star sync ("client mean + ``sync_update`` + broadcast") moved out of the
four engines into ``repro.fed.topology``'s pluggable ``Aggregator`` layer.
``GOLDEN`` below pins full 24-step trajectories (grad-norm evals, comms,
samples, wire bytes) captured at the pre-refactor HEAD (commit 0c4b355) for
every engine × codec × mega-scan × mesh combination — the star aggregator
must reproduce them BIT-identically, so the values are compared exactly, not
to a tolerance. Do not regenerate these numbers from post-refactor code:
they are only evidence while they predate the refactor.

The gossip half of the matrix pins the payoff: the complete-graph gossip
engine with uniform Metropolis weights equals the star population engine to
1e-6 (they compute the same uniform mean; only vmapped-vs-scalar
``sync_update`` compilation may differ), plus mixing-matrix invariants,
mega-scan parity, and per-edge wire accounting.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FedConfig
from repro.configs.base import PopulationConfig
from repro.core.bilevel import quadratic_bilevel_problem, quadratic_true_grad
from repro.tasks.driver import FedDriver

# Captured at pre-refactor HEAD 0c4b355 (quadratic task below, 24 steps,
# run key PRNGKey(1), eval_every=8).
GOLDEN = {
    "eager": {
        "grad_norm": [4.258377552032471, 10.474803924560547, 9.82275390625, 5.525059700012207],
        "comms": 5, "samples": 280,
        "bytes_up": 2240, "bytes_down": 2240,
    },
    "scan": {
        "grad_norm": [6.718911170959473, 10.950937271118164, 8.414649963378906, 5.525059700012207],
        "comms": 5, "samples": 280,
        "bytes_up": 2240, "bytes_down": 2240,
    },
    "scan_r3": {
        "grad_norm": [6.718911170959473, 10.511040687561035, 5.525059700012207],
        "comms": 5, "samples": 280,
        "bytes_up": 2240, "bytes_down": 2240,
    },
    "eager_int8": {
        "grad_norm": [4.258377552032471, 10.415976524353027, 9.85123062133789, 5.573945999145508],
        "comms": 5, "samples": 280,
        "bytes_up": 600, "bytes_down": 2240,
    },
    "scan_int8": {
        "grad_norm": [6.718911170959473, 10.93971061706543, 8.410233497619629, 5.573945999145508],
        "comms": 5, "samples": 280,
        "bytes_up": 600, "bytes_down": 2240,
    },
    "population": {
        "grad_norm": [4.996356964111328, 9.135046005249023, 6.539945602416992, 3.780060291290283],
        "comms": 5, "samples": 280,
        "bytes_up": 2240, "bytes_down": 4480,
    },
    "population_r3": {
        "grad_norm": [4.996356964111328, 8.53126049041748, 3.780060291290283],
        "comms": 5, "samples": 280,
        "bytes_up": 2240, "bytes_down": 4480,
    },
    "population_int8": {
        "grad_norm": [4.984788417816162, 9.065518379211426, 6.603400230407715, 3.8333396911621094],
        "comms": 5, "samples": 280,
        "bytes_up": 600, "bytes_down": 4480,
    },
    "population_participants": {
        "grad_norm": [4.996356964111328, 8.185358047485352, 8.573365211486816, 8.583778381347656],
        "comms": 5, "samples": 280,
        "bytes_up": 2240, "bytes_down": 2240,
    },
    "async": {
        "grad_norm": [4.996356964111328, 8.442898750305176, 8.703781127929688, 8.87501335144043],
        "comms": 5, "samples": 220,
        "bytes_up": 1344, "bytes_down": 2800,
    },
    "async_r3": {
        "grad_norm": [8.442898750305176, 8.87501335144043],
        "comms": 5, "samples": 220,
        "bytes_up": 1344, "bytes_down": 2800,
    },
    "async_int8": {
        "grad_norm": [4.984788417816162, 8.46338176727295, 8.720943450927734, 8.743760108947754],
        "comms": 5, "samples": 220,
        "bytes_up": 360, "bytes_down": 2800,
    },
    "population_mesh": {
        "grad_norm": [4.996356964111328, 9.135046005249023, 6.539945602416992, 3.780060291290283],
        "comms": 5, "samples": 280,
        "bytes_up": 2240, "bytes_down": 4480,
    },
}

POP = dict(n=8, cohort=4)


def quad_driver(m=4, codec="none", **kw):
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    d, p = 8, 6
    A = jax.random.normal(k1, (p, p))
    H = A @ A.T / p + 0.5 * jnp.eye(p)
    Bm = jax.random.normal(k2, (p, d)) * 0.3
    c = jax.random.normal(k3, (p,))
    Q = jnp.eye(d) * 0.2
    prob = quadratic_bilevel_problem(H, Bm, c, Q)
    fed = FedConfig(q=4, neumann_k=8, lr_x=0.3, lr_y=0.3,
                    theta=float(1.0 / jnp.linalg.eigvalsh(H)[-1]),
                    adaptive="adam", codec=codec, codec_bits=4)

    def batch_fn(client, step):
        return {"f": 0.0, "g": 0.0, "g0": 0.0,
                "gi": jnp.zeros((fed.neumann_k,))}

    def init_xy(key):
        return jnp.ones((d,)) * 2.0, jnp.zeros((p,))

    def grad_norm(x, y):
        return jnp.linalg.norm(quadratic_true_grad(H, Bm, c, Q, x))

    return FedDriver(prob, fed, m, batch_fn, init_xy,
                     grad_norm_fn=grad_norm, algorithm="adafbio", **kw)


def _mesh2():
    if len(jax.devices()) < 2:
        pytest.skip("needs 2 devices (forced-host in tests/conftest.py)")
    return jax.make_mesh((2, 1), ("data", "model"))


CASES = {
    "eager": lambda: quad_driver(engine="eager"),
    "scan": lambda: quad_driver(engine="scan"),
    "scan_r3": lambda: quad_driver(engine="scan", rounds_per_scan=3),
    "eager_int8": lambda: quad_driver(engine="eager", codec="int8"),
    "scan_int8": lambda: quad_driver(engine="scan", codec="int8"),
    "population": lambda: quad_driver(
        m=8, population=PopulationConfig(**POP)),
    "population_r3": lambda: quad_driver(
        m=8, population=PopulationConfig(**POP), rounds_per_scan=3),
    "population_int8": lambda: quad_driver(
        m=8, codec="int8", population=PopulationConfig(**POP)),
    "population_participants": lambda: quad_driver(
        m=8, population=PopulationConfig(
            sync_mode="participants", staleness_decay=0.5, **POP)),
    "async": lambda: quad_driver(m=8, population=PopulationConfig(
        max_staleness=4.0, max_delay=3, delay_eta=0.3, **POP)),
    "async_r3": lambda: quad_driver(m=8, population=PopulationConfig(
        max_staleness=4.0, max_delay=3, delay_eta=0.3, **POP),
        rounds_per_scan=3),
    "async_int8": lambda: quad_driver(m=8, codec="int8",
                                      population=PopulationConfig(
                                          max_staleness=4.0, max_delay=3,
                                          **POP)),
    "population_mesh": lambda: quad_driver(
        m=8, population=PopulationConfig(**POP), mesh=_mesh2()),
}


def _run(drv):
    return drv.run(24, key=jax.random.PRNGKey(1), eval_every=8)


# ---------------------------------------------------------------- star pins

@pytest.mark.parametrize("name", sorted(GOLDEN))
def test_star_bit_identical_to_prerefactor(name):
    """The star aggregator reproduces the pre-refactor trajectory of every
    engine EXACTLY — bitwise equality on each recorded grad-norm eval plus
    the full cost accounting (comms, samples, wire bytes)."""
    r = _run(CASES[name]())
    g = GOLDEN[name]
    np.testing.assert_array_equal(
        np.asarray(r.grad_norm, np.float32),
        np.asarray(g["grad_norm"], np.float32),
        err_msg=f"{name}: star aggregator drifted from pre-refactor HEAD")
    assert r.comms[-1] == g["comms"]
    assert int(round(r.samples[-1])) == g["samples"]
    assert r.bytes_up[-1] == g["bytes_up"]
    assert r.bytes_down[-1] == g["bytes_down"]


# ------------------------------------------------------------- mixing zoo

def _pop(topology="ring", n=8, **kw):
    return PopulationConfig(n=n, cohort=n, topology=topology, **kw)


def _gossip(topology="ring", n=8, codec="none", pop_kw=None, **kw):
    return quad_driver(m=n, codec=codec,
                       population=_pop(topology, n=n, **(pop_kw or {})),
                       engine="gossip", **kw)


@pytest.mark.parametrize("topology", ["ring", "torus2d", "complete",
                                      "erdos"])
def test_mixing_matrix_invariants(topology):
    """Metropolis matrices are symmetric, doubly stochastic, non-negative,
    and connected topologies have a spectral gap in (0, 1]."""
    from repro.fed.topology import mixing_matrix, spectral_gap
    W = mixing_matrix(topology, 8)
    assert W.shape == (8, 8) and (W >= 0).all()
    np.testing.assert_allclose(W, W.T, atol=0)
    np.testing.assert_allclose(W.sum(1), np.ones(8), atol=1e-6)
    np.testing.assert_allclose(W.sum(0), np.ones(8), atol=1e-6)
    gap = spectral_gap(W)
    assert 0.0 < gap <= 1.0 + 1e-12


def test_complete_graph_matrix_is_uniform():
    from repro.fed.topology import mixing_matrix
    W = mixing_matrix("complete", 8)
    np.testing.assert_array_equal(W, np.full((8, 8), 1.0 / 8, np.float32))


def test_prime_torus_rejected():
    from repro.fed.topology import mixing_matrix
    with pytest.raises(ValueError, match="ring"):
        mixing_matrix("torus2d", 7)


def test_spectral_gap_ordering():
    """Denser graphs mix faster: gap(ring) < gap(torus2d) < gap(complete),
    and the complete graph reaches exact consensus in one mix (gap 1)."""
    from repro.fed.topology import mixing_matrix, spectral_gap
    ring = spectral_gap(mixing_matrix("ring", 8))
    torus = spectral_gap(mixing_matrix("torus2d", 8))
    comp = spectral_gap(mixing_matrix("complete", 8))
    assert ring < torus < comp
    assert abs(comp - 1.0) < 1e-9


# ------------------------------------------------------------ gossip engine

def test_gossip_complete_equals_star_population():
    """The payoff identity: on the complete graph the Metropolis matrix is
    uniform, so the gossip engine's trajectory equals the star population
    engine's full-cohort trajectory to float tolerance (the only compile
    difference is vmapped-vs-scalar ``sync_update``)."""
    rg = _run(_gossip("complete"))
    rs = _run(quad_driver(m=8, population=_pop("complete")))
    np.testing.assert_allclose(rg.grad_norm, rs.grad_norm, rtol=0,
                               atol=1e-6)
    assert rg.comms == rs.comms
    assert rg.samples == rs.samples


def test_gossip_megascan_bit_identical():
    """R=3 mega-scan gossip rounds fuse to exactly the per-round program:
    the final bank-mean state and last recorded eval match bit-for-bit."""
    r1 = _run(_gossip("ring"))
    r3 = _run(_gossip("ring", rounds_per_scan=3))
    assert np.float32(r1.grad_norm[-1]) == np.float32(r3.grad_norm[-1])
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)),
        r1.final_avg_state, r3.final_avg_state)
    assert (r1.comms[-1], r1.bytes_up[-1], r1.bytes_down[-1]) == \
        (r3.comms[-1], r3.bytes_up[-1], r3.bytes_down[-1])


def test_gossip_per_edge_wire_accounting():
    """Every sync bills one codec-priced message per DIRECTED edge, both
    legs (peer exchanges are compressed in both directions; no
    full-precision broadcast) — for the 8-ring: 16 edges x 5 syncs."""
    for codec in ("none", "int8"):
        drv = _gossip("ring", codec=codec)
        r = _run(drv)
        msg_b, _ = drv._wire_costs(drv.final_bank)
        edges = drv.gossip_agg.edges(0)
        assert edges == 16
        assert r.bytes_up[-1] == 5 * edges * msg_b
        assert r.bytes_down[-1] == r.bytes_up[-1]


def test_gossip_time_varying_deterministic():
    """Time-varying Erdős–Rényi graphs re-draw per round from the salted
    round_id fold — deterministically: two identical runs coincide
    bitwise, and the mega-scan's in-scan draw matches the per-round
    path's eager draw."""
    kw = dict(pop_kw=dict(er_p=0.6, time_varying=True))
    r1 = _run(_gossip("erdos", **kw))
    r2 = _run(_gossip("erdos", **kw))
    np.testing.assert_array_equal(np.asarray(r1.grad_norm, np.float32),
                                  np.asarray(r2.grad_norm, np.float32))
    r3 = _run(_gossip("erdos", rounds_per_scan=3, **kw))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)),
        r1.final_avg_state, r3.final_avg_state)
    # exact per-round edge billing survives the graph changing every round
    assert r1.bytes_up[-1] == r3.bytes_up[-1]


def test_gossip_mix_preserves_average():
    """One mixing step preserves the network average exactly (doubly
    stochastic W) — the decentralized invariant the convergence analysis
    needs."""
    from repro.fed.topology import GossipAggregator
    agg = GossipAggregator(sync_update=lambda s, a: (a, s), n=8,
                           topology="torus2d")
    bank = {"x": jax.random.normal(jax.random.PRNGKey(3), (8, 5))}
    mixed = agg.mix(bank, agg.matrix(0))
    np.testing.assert_allclose(np.asarray(mixed["x"].mean(0)),
                               np.asarray(bank["x"].mean(0)), atol=1e-5)


def test_gossip_validation():
    with pytest.raises(ValueError, match="full-participation"):
        _run(quad_driver(m=8, population=PopulationConfig(n=8, cohort=4),
                         engine="gossip"))
    with pytest.raises(ValueError, match="synchronous"):
        _run(quad_driver(m=8, population=_pop(max_staleness=4.0),
                         engine="gossip"))
    with pytest.raises(ValueError, match="population"):
        _run(quad_driver(m=8, engine="gossip"))
    with pytest.raises(ValueError, match="time_varying"):
        PopulationConfig(n=8, cohort=8, topology="ring", time_varying=True)
