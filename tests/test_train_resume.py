"""Population-mode checkpoint resume round-trips in the training CLI
(``repro.launch.train.run_population``): a run checkpointed mid-flight and
resumed must land on the same final state as an uninterrupted run —
including the lossy-codec EF-bank template and the ``start_round``
arithmetic — the host-spill runner writes dense-compatible checkpoints,
and the sharded layout (``--ckpt-shards K``) round-trips bit-identically
with the dense single-file layout in both directions."""
import argparse
import json

import jax
import numpy as np
import pytest

from repro.configs import FedConfig, get_arch, reduced
from repro.configs.base import ShapeConfig
from repro.fed.runtime import FederatedTrainer
from repro.launch.train import run_population


def _args(ckpt, steps, resume=False, spill="none", rounds_per_scan=1,
          ckpt_shards=1):
    return argparse.Namespace(
        population=4, cohort=2, sampler="uniform", trace_file=None,
        max_staleness=0.0, max_delay=1, delay_eta=0.0,
        delay_model="uniform", tiers=None, delay_mu=0.0, delay_sigma=0.5,
        spill=spill, resume=resume, ckpt=ckpt, steps=steps, eval_every=100,
        rounds_per_scan=rounds_per_scan, ckpt_shards=ckpt_shards)


def _load_arrays(path):
    """Reassemble a checkpoint's full leaf arrays from either layout —
    the dense single .npz or the base + shard{k} files."""
    with open(path + ".json") as f:
        meta = json.load(f)
    data = dict(np.load(path + ".npz").items())
    for i in meta.get("sharded_leaves", []):
        name = f"leaf_{i}"
        data[name] = np.concatenate(
            [np.load(f"{path}.shard{k}.npz")[name]
             for k in range(meta["shards"])], axis=0)
    return data, meta["step"]


def _run(tmp_path, name, codec="none", steps=8, resume=False,
         spill="none", rounds_per_scan=1, ckpt_shards=1):
    cfg = reduced(get_arch("qwen1.5-4b"), dtype="float32")
    fed = FedConfig(q=2, neumann_k=2, lr_x=1e-2, lr_y=1e-1, codec=codec,
                    topk_frac=0.5)
    shape = ShapeConfig("t", 16, 2, "train")
    tr = FederatedTrainer(cfg, fed, shape, mesh=None)
    path = str(tmp_path / name)
    args = _args(path, steps, resume=resume, spill=spill,
                 rounds_per_scan=rounds_per_scan, ckpt_shards=ckpt_shards)
    run_population(args, cfg, fed, shape, tr, jax.random.PRNGKey(7))
    return _load_arrays(path)


def _assert_same(a, b):
    assert sorted(a) == sorted(b)
    for k in sorted(a):
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


def test_population_resume_matches_uninterrupted(tmp_path):
    """Checkpoint at step 4 of 8, resume, finish: the final checkpoint is
    bit-identical to the uninterrupted 8-step run's."""
    full, step_full = _run(tmp_path, "full", steps=8)
    part, step_part = _run(tmp_path, "part", steps=4)
    assert step_part == 4
    resumed, step_res = _run(tmp_path, "part", steps=8, resume=True)
    assert step_full == step_res == 8
    _assert_same(full, resumed)


@pytest.mark.slow
def test_population_resume_lossy_ef_template(tmp_path):
    """Same round-trip through the lossy checkpoint template — the EF
    residual bank rides in the tuple and must restore exactly."""
    full, _ = _run(tmp_path, "full_topk", codec="topk", steps=8)
    _run(tmp_path, "part_topk", codec="topk", steps=4)
    resumed, step = _run(tmp_path, "part_topk", codec="topk", steps=8,
                         resume=True)
    assert step == 8
    _assert_same(full, resumed)


def test_megascan_resume_mid_chunk_matches_uninterrupted(tmp_path):
    """Mega-scan chunk-offset bookkeeping: checkpoint at round 2 (step 4)
    with q=2 — a round NOT divisible by R=3 — then resume with R=3. The
    resumed run's first chunk is the short 2..2 remainder of nothing in
    particular: chunks re-anchor at start_round, and the final checkpoint
    must still equal BOTH the uninterrupted R=3 run and the R=1 run
    bit-for-bit."""
    full_r1, _ = _run(tmp_path, "full_r1", steps=12)
    full_r3, step_full = _run(tmp_path, "full_r3", steps=12,
                              rounds_per_scan=3)
    assert step_full == 12
    _assert_same(full_r1, full_r3)
    # 6 rounds total; stop after round 1 (steps=4 → 2 rounds), resume at
    # round 2 with R=3 → chunks [2,3,4] and [5] (trailing partial chunk)
    _run(tmp_path, "part_r3", steps=4, rounds_per_scan=3)
    resumed, step_res = _run(tmp_path, "part_r3", steps=12, resume=True,
                             rounds_per_scan=3)
    assert step_res == 12
    _assert_same(full_r1, resumed)


@pytest.mark.slow
def test_megascan_resume_lossy_ef_template(tmp_path):
    """Same mid-chunk round-trip through the lossy template: the EF
    residual bank restores exactly and the chunked codec RNG (folded on
    the absolute round id, not the chunk offset) keeps the trajectory."""
    full, _ = _run(tmp_path, "full_topk_r3", codec="topk", steps=12,
                   rounds_per_scan=3)
    _run(tmp_path, "part_topk_r3", codec="topk", steps=4,
         rounds_per_scan=3)
    resumed, step = _run(tmp_path, "part_topk_r3", codec="topk", steps=12,
                         resume=True, rounds_per_scan=3)
    assert step == 12
    _assert_same(full, resumed)
    ref, _ = _run(tmp_path, "full_topk_r1", codec="topk", steps=12)
    _assert_same(full, ref)


def test_spill_checkpoint_matches_dense(tmp_path):
    """--spill host replays the dense broadcast trajectory and its
    materialized checkpoint interchanges with the dense runner's."""
    dense, _ = _run(tmp_path, "dense", steps=8)
    spilled, step = _run(tmp_path, "spilled", steps=8, spill="host")
    assert step == 8
    _assert_same(dense, spilled)


def test_sharded_checkpoint_roundtrip(tmp_path):
    """--ckpt-shards 3 splits the bank leaves over per-shard files whose
    reassembly is bit-identical to the dense layout, and a run resumes
    ACROSS layouts (sharded checkpoint → dense save and back) onto the
    same final state."""
    dense, _ = _run(tmp_path, "d1", steps=8)
    sharded, step = _run(tmp_path, "s3", steps=8, ckpt_shards=3)
    assert step == 8
    _assert_same(dense, sharded)
    path = str(tmp_path / "s3")
    with open(path + ".json") as f:
        meta = json.load(f)
    assert meta["shards"] == 3 and meta["sharded_leaves"]
    for k in range(3):
        shard = np.load(f"{path}.shard{k}.npz")
        assert len(shard.files) == len(meta["sharded_leaves"])
    # bank rows (N=4) shard; no sharded leaf lingers dense in the base file
    base = np.load(path + ".npz")
    assert not set(base.files) & {f"leaf_{i}"
                                  for i in meta["sharded_leaves"]}
    # resume from the sharded file, finish with the dense layout
    _run(tmp_path, "x", steps=4, ckpt_shards=3)
    resumed, step_res = _run(tmp_path, "x", steps=8, resume=True)
    assert step_res == 8
    full, _ = _run(tmp_path, "d2", steps=8)
    _assert_same(full, resumed)


def test_spill_sharded_checkpoint_matches_dense(tmp_path):
    """The spilled runner's sharded save (LazyRows pulls one shard's row
    range at a time — no dense materialize) reassembles bit-identical to
    the dense runner's checkpoint."""
    dense, _ = _run(tmp_path, "dense_s", steps=8)
    spilled, step = _run(tmp_path, "spill_s", steps=8, spill="host",
                         ckpt_shards=2)
    assert step == 8
    _assert_same(dense, spilled)
